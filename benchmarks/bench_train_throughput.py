"""Training-throughput benchmark: per-step host loop vs fused chunked+ring.

Measures steps/sec on the small-CNN config (a LeNet-shaped net — the
paper's own small benchmark family — downscaled to 8x8 single-channel
inputs) for three input/dispatch regimes:

  * ``per_step_host``  — one jit dispatch per step, batches sliced on the
    host and transferred per step (the pre-ISSUE-2 engine);
  * ``per_step_ring``  — one dispatch per step, batches served from the
    device-resident FCPR ring (isolates H2D transfer from dispatch cost);
  * ``chunked_ring_K{1,4,32}`` — the fused engine: K full ISGD steps per
    dispatch via ``lax.scan`` over the ring.

Emits ``BENCH_train_throughput.json`` — the repo's first perf-trajectory
baseline; the acceptance bar is ≥2x steps/sec for chunked+ring K=32 over
the per-step host loop on CPU.

``--model transformer`` swaps the step body for ``paper-transformer-tiny``
through ``build_model`` (ISSUE 6: the fused engines on an LM body) and
writes ``BENCH_transformer_throughput.json``.  The transformer body is
compute-bound even at the tiny tier on CPU (measured ~1.3x for K=32 at
full length), so its bar is "the fused scan is at least as fast as the
per-step loop" with 10% smoke-noise headroom (0.9x) — the 2x amortization
headline stays pinned to the dispatch-bound CNN regime.

The config is sized for the regime the fused engine targets: per-step
dispatch/transfer overhead comparable to or larger than per-step compute —
which is the small-model CPU reproduction here, and (ROADMAP) any
accelerator where device compute outruns the host.  Caveat worth keeping in
the record: XLA:CPU's thunk runtime (jaxlib 0.4.3x) compiles convolution
*backward* passes inside while/scan bodies to a slow fallback (measured up
to ~50x on 5x5 kernels; see EXPERIMENTS-style probe in this PR), so on CPU
the fused win shrinks — and can invert — as conv feature counts grow.  The
fused engine and the per-step engine run identical HLO per step otherwise
(bit-exact parity is tested), so this is purely a backend codegen gap.

Modes:
  full (default)   spawn one child per device count (1 and 8 forced host
                   devices) and merge into BENCH_train_throughput.json at
                   the repo root (+ a copy under experiments/bench/).
  --single         run in-process on whatever devices exist, write --out.
  --smoke          in-process, reduced step counts (CI: exercises the fused
                   path under both matrix device counts and uploads the
                   JSON artifact).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def run_single(args) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import ISGDConfig
    from repro.data import DeviceRing, FCPRSampler, make_classification
    from repro.distributed import (make_chunked_data_parallel_step,
                                   make_data_parallel_step)
    from repro.launch.mesh import make_data_mesh
    from repro.optim import momentum

    n_dev = len(jax.devices())
    steps = args.steps - args.steps % 32 or 32     # divisible by every K
    if args.model == "transformer":
        # paper-transformer-tiny through build_model: the fused-chunk
        # engine on the zoo's LM step body (reference kernels on CPU;
        # the Pallas path swaps in on TPU via --kernels at the launcher).
        from repro.configs import zoo_config
        from repro.models import build_model

        cfg = zoo_config("transformer", "tiny")
        model = build_model(cfg)
        rng = np.random.RandomState(0)
        toks = rng.randint(
            0, cfg.vocab_size,
            size=(args.batch * args.n_batches, args.seq)).astype(np.int32)
        data = {"tokens": toks}
        loss_fn = model.loss_fn
        params0 = model.init(jax.random.PRNGKey(0), max_seq=args.seq)
        model_name = cfg.name
    else:
        from repro.configs.paper_cnns import CNNConfig, ConvSpec
        from repro.models import cnn_loss_fn, init_cnn

        # LeNet-shaped small CNN at 8x8/1ch — the dispatch-bound regime
        # the fused engine exists for (see module docstring).
        cfg = CNNConfig(name="lenet-8x8", image_size=8, channels=1,
                        num_classes=10,
                        convs=(ConvSpec(4, 3, pool=2),
                               ConvSpec(8, 3, pool=2)),
                        hidden=(24,))
        data = make_classification(0, args.batch * args.n_batches,
                                   cfg.image_size, cfg.channels, 10,
                                   noise=0.6, class_spread=2.0)
        loss_fn = lambda p, b: cnn_loss_fn(p, cfg, b)    # noqa: E731
        params0 = init_cnn(jax.random.PRNGKey(0), cfg)
        model_name = cfg.name
    sampler = FCPRSampler(data, batch_size=args.batch, seed=1)
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=1.5, stop=3,
                      zeta=0.02)
    rule = momentum(0.9)
    lr_fn = lambda _: jnp.asarray(0.05)                  # noqa: E731
    mesh = make_data_mesh() if n_dev > 1 else None

    def fresh():
        return jax.tree.map(jnp.copy, params0)

    def mk_per_step():
        if mesh is None:
            from repro.train import make_train_step
            return make_train_step(loss_fn, rule, icfg, lr_fn=lr_fn)
        return make_data_parallel_step(loss_fn, rule, icfg, mesh,
                                       lr_fn=lr_fn)

    def time_per_step(feed, label):
        init_fn, step = mk_per_step()
        p = fresh()
        s = init_fn(p)
        s, p, m = step(s, p, feed(0))                    # compile
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for j in range(steps):
            s, p, m = step(s, p, feed(j))
        jax.block_until_ready(m["loss"])
        dt = time.perf_counter() - t0
        return {"engine": label, "chunk": 1, "steps": steps,
                "steps_per_sec": steps / dt, "wall_s": dt}

    def time_chunked(ring, K):
        if mesh is None:
            from repro.train import make_chunked_train_step
            init_fn, chunk = make_chunked_train_step(
                loss_fn, rule, icfg, chunk_steps=K, lr_fn=lr_fn)
        else:
            init_fn, chunk = make_chunked_data_parallel_step(
                loss_fn, rule, icfg, mesh, chunk_steps=K, lr_fn=lr_fn)
        p = fresh()
        s = init_fn(p)
        s, p, ms = chunk(s, p, ring.arrays, 0)           # compile
        jax.block_until_ready(ms["loss"])
        t0 = time.perf_counter()
        for c in range(1, 1 + steps // K):
            s, p, ms = chunk(s, p, ring.arrays, c * K)
        jax.block_until_ready(ms["loss"])
        dt = time.perf_counter() - t0
        return {"engine": f"chunked_ring_K{K}", "chunk": K, "steps": steps,
                "steps_per_sec": steps / dt, "wall_s": dt}

    host_feed = lambda j: {k: jnp.asarray(v)             # noqa: E731
                           for k, v in sampler(j).items()}
    ring = DeviceRing(sampler.epoch_arrays(), sampler.batch_size, mesh=mesh)

    runs = [time_per_step(host_feed, "per_step_host"),
            time_per_step(ring, "per_step_ring")]
    runs += [time_chunked(ring, K) for K in (1, 4, 32)]
    for r in runs:
        r["devices"] = n_dev
        print(f"devices={n_dev} {r['engine']:>18s} "
              f"{r['steps_per_sec']:8.1f} steps/s", flush=True)

    base = runs[0]["steps_per_sec"]
    k32 = next(r for r in runs if r["chunk"] == 32)["steps_per_sec"]
    return {
        "config": {"model": model_name, "batch": args.batch,
                   "n_batches": sampler.n_batches, "steps": steps,
                   "devices": n_dev, "ring_bytes": ring.nbytes},
        "runs": runs,
        "speedup_chunked32_vs_per_step_host": k32 / base,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", choices=("cnn", "transformer"), default="cnn")
    ap.add_argument("--steps", type=int, default=192)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--n-batches", type=int, default=8, dest="n_batches")
    ap.add_argument("--seq", type=int, default=64,
                    help="sequence length (transformer only)")
    ap.add_argument("--smoke", action="store_true",
                    help="in-process reduced run (CI)")
    ap.add_argument("--single", action="store_true",
                    help="in-process run on current devices")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        args.out = (f"BENCH_{args.model}_throughput.json"
                    if args.model != "cnn" else
                    "BENCH_train_throughput.json")
    # the 2x amortization bar is for the dispatch-bound CNN; the
    # transformer tiny body is compute-bound even on CPU (full-length
    # 1-device run measures ~1.3x for K=32), so the bar there is "the
    # fused scan is not slower than the per-step loop", with 10% head-
    # room because the 64-step smoke is timer-noise-limited on CI
    bar = {"cnn": 2.0, "transformer": 0.9}[args.model]

    if args.smoke:
        args.steps = min(args.steps, 64)

    if args.smoke or args.single:
        payload = {"mode": "smoke" if args.smoke else "single",
                   "results": [run_single(args)]}
    else:
        results = []
        for n in (1, 8):
            env = dict(os.environ)
            env["XLA_FLAGS"] = (
                f"--xla_force_host_platform_device_count={n}"
                if n > 1 else "")
            child_out = os.path.join(ROOT, f".bench_child_{n}.json")
            cmd = [sys.executable, os.path.abspath(__file__), "--single",
                   "--model", args.model, "--seq", str(args.seq),
                   "--steps", str(args.steps), "--batch", str(args.batch),
                   "--n-batches", str(args.n_batches), "--out", child_out]
            subprocess.run(cmd, check=True, env=env)
            with open(child_out) as f:
                results.append(json.load(f)["results"][0])
            os.remove(child_out)
        payload = {"mode": "full", "results": results}

    for res in payload["results"]:
        res["speedup_bar"] = bar
        res["speedup_ok"] = res["speedup_chunked32_vs_per_step_host"] >= bar
        if res["config"]["devices"] > 1:
            res["note"] = (
                "forced host devices oversubscribe the physical cores "
                f"{res['config']['devices']}x, so per-step cost is compute/"
                "collective-bound and dispatch amortization is a small "
                "fraction; the 2x acceptance bar applies to the 1-device "
                "run, this leg checks the fused shard_map path end-to-end")

    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {args.out}")
    try:
        from common import save_json
        save_json(f"{args.model}_throughput" if args.model != "cnn"
                  else "train_throughput", payload)
    except Exception:
        pass
    for res in payload["results"]:
        s = res["speedup_chunked32_vs_per_step_host"]
        print(f"devices={res['config']['devices']}: chunked+ring K=32 is "
              f"{s:.2f}x the per-step host loop "
              f"({'OK' if s >= bar else f'BELOW {bar}x BAR'})")


if __name__ == "__main__":
    main()
