"""Benchmark harness entry point — one benchmark per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig1,fig6,...]

Emits ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).
REPRO_BENCH_SCALE / REPRO_BENCH_RUNS control workload size.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (fig1_loss_traces, fig3_control_limit,
                        fig6_inconsistent_training, fig8_batch_size,
                        fig8_scaling, fig9_nesterov, kernels_bench,
                        roofline_bench, table1_time_to_accuracy)

ALL = {
    "fig1": fig1_loss_traces.run,
    "fig3": fig3_control_limit.run,
    "fig6": fig6_inconsistent_training.run,
    "table1": table1_time_to_accuracy.run,
    "fig8": fig8_batch_size.run,
    "fig8_scaling": fig8_scaling.run,
    "fig9": fig9_nesterov.run,
    "kernels": kernels_bench.run,
    "roofline": roofline_bench.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(ALL))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(ALL)
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        try:
            ALL[name]()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"FAILED: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
