"""Batch-schedule policy comparison: fcpr vs loss-prop vs rank (ISSUE 5).

Convergence + throughput on an **imbalanced** synthetic config built to
reward loss-aware selection: 8-class softmax regression where six easy,
well-separated "common" classes fill 14 of 16 class-sorted batches (near-
duplicate information once learned) and two hard, nearly-coincident "rare"
classes live ONLY in the last 2 batches.  FCPR gives the rare batches a
fixed 2/16 of the update budget; ``loss-prop``/``rank`` keep revisiting
them while their loss stays above the rest, so the full-dataset loss
reaches the target in fewer steps — the acceptance check
(``loss_prop_beats_fcpr``) asserts exactly that ordering.

Every policy runs the SAME fused chunked engine (``repro.sched`` selection
inside the ``lax.scan``, K steps per host dispatch, device-resident ring):
the comparison is single-factor in the selection policy.  ``dispatches``
in the record is the host-dispatch count — ``steps/K`` by construction
(selection never leaves the device; the per-chunk eval is one extra jit) —
and ``steps_per_sec`` shows the policies pay no measurable selection
overhead over the FCPR baseline (a categorical draw + table write per
step vs the integer mod).

Modes (same shape as bench_train_throughput):
  default          full run, write --out (+ a copy under experiments/bench)
  --smoke          reduced steps/target (CI: both matrix device counts,
                   uploads BENCH_sched_policies.<matrix>.json)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def make_imbalanced_epoch(batch_size: int, n_batches: int, dim: int = 16,
                          n_classes: int = 8, seed: int = 0):
    """Class-sorted epoch arrays: batches [0, n_b-2) hold the 6 common
    classes, the last 2 batches hold ONLY the two rare (and mutually
    hard-to-separate) classes."""
    import numpy as np

    rng = np.random.RandomState(seed)
    means = rng.randn(n_classes, dim).astype(np.float32) * 1.5
    # rare pair nearly coincident: separating them needs many updates
    means[n_classes - 1] = (means[n_classes - 2]
                            + 0.5 * rng.randn(dim).astype(np.float32))

    def batch_of(classes):
        ys = rng.choice(classes, size=batch_size)
        xs = means[ys] + rng.randn(batch_size, dim).astype(np.float32)
        return xs.astype(np.float32), ys.astype(np.int32)

    common = list(range(n_classes - 2))
    rare = [n_classes - 2, n_classes - 1]
    xs, ys = zip(*[batch_of(rare if t >= n_batches - 2 else common)
                   for t in range(n_batches)])
    return ({"x": np.concatenate(xs), "y": np.concatenate(ys)},
            {"common_batches": n_batches - 2, "rare_batches": 2})


def run_single(args) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import ISGDConfig
    from repro.data import DeviceRing
    from repro.distributed import make_chunked_data_parallel_step
    from repro.launch.mesh import make_data_mesh
    from repro.optim import momentum
    from repro.sched import (FCPRSchedule, LossPropSchedule, RankSchedule,
                             schedule_from_spec)
    from repro.train import make_chunked_train_step

    n_dev = len(jax.devices())
    bs, nb, K = args.batch, args.n_batches, args.chunk_steps
    assert bs % n_dev == 0, (bs, n_dev)
    steps = args.steps - args.steps % K
    epoch, imbalance = make_imbalanced_epoch(bs, nb)
    dim = epoch["x"].shape[1]
    n_classes = int(epoch["y"].max()) + 1

    def loss_fn(p, b):
        logits = b["x"] @ p["W"] + p["b"]
        ll = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(ll, b["y"][:, None], axis=1))
        return loss, loss

    params0 = {"W": jnp.zeros((dim, n_classes), jnp.float32),
               "b": jnp.zeros((n_classes,), jnp.float32)}
    full = {k: jnp.asarray(v) for k, v in epoch.items()}
    eval_loss = jax.jit(lambda p: loss_fn(p, full)[0])
    icfg = ISGDConfig(n_batches=nb, k_sigma=2.0, stop=3)
    lr_fn = lambda _: jnp.asarray(0.05)
    rule = momentum(0.9)
    mesh = make_data_mesh() if n_dev > 1 else None
    ring = DeviceRing(epoch, bs, mesh=mesh)

    policies = [("fcpr", FCPRSchedule()),
                ("loss-prop", schedule_from_spec("loss-prop:eps=0.2")),
                ("rank", RankSchedule())]
    assert isinstance(policies[1][1], LossPropSchedule)

    runs = []
    for name, sched in policies:
        if mesh is None:
            cinit, chunk = make_chunked_train_step(
                loss_fn, rule, icfg, chunk_steps=K, lr_fn=lr_fn,
                schedule=sched)
        else:
            cinit, chunk = make_chunked_data_parallel_step(
                loss_fn, rule, icfg, mesh, chunk_steps=K, lr_fn=lr_fn,
                schedule=sched)
        p = jax.tree.map(jnp.copy, params0)
        s = cinit(p)
        ss = sched.init(nb)
        # compile outside the timed region (jit caches are per chunk fn,
        # so warm the instance that gets timed — see kernels_bench note)
        s0, p0, ss0, ms = chunk(s, p, ss, ring.arrays, 0)
        jax.block_until_ready(ms["loss"])
        jax.block_until_ready(eval_loss(p0))
        p = jax.tree.map(jnp.copy, params0)
        s, ss = cinit(jax.tree.map(jnp.copy, params0)), sched.init(nb)

        dispatches = 0
        visits = np.zeros(nb, np.int64)
        trace = []
        t0 = time.perf_counter()
        for c in range(steps // K):
            s, p, ss, ms = chunk(s, p, ss, ring.arrays, c * K)
            dispatches += 1
            # ONE metrics fetch per chunk (wall_est semantics of the fused
            # engine) + one eval: no per-step host sync anywhere
            visits += np.bincount(np.asarray(ms["batch_idx"]), minlength=nb)
            trace.append(float(eval_loss(p)))
        dt = time.perf_counter() - t0
        # sustained convergence: first chunk boundary after which the
        # full-data loss never exceeds the target again (a first-crossing
        # metric would reward transient momentum dips)
        last_above = max((i for i, v in enumerate(trace) if v > args.target),
                         default=-1)
        to_target = ((last_above + 2) * K
                     if last_above + 1 < len(trace) else None)
        runs.append({
            "policy": name, "steps": steps, "steps_per_sec": steps / dt,
            "wall_s": dt, "dispatches": dispatches,
            "host_dispatches_per_step": dispatches / steps,
            "steps_to_target_sustained": to_target,
            "final_loss": trace[-1],
            "rare_batch_visit_share":
                float(visits[-2:].sum() / max(visits.sum(), 1)),
            "visits": visits.tolist(),
        })
        print(f"devices={n_dev} {name:>10s} steps_to_target="
              f"{to_target} (sustained) final={trace[-1]:.4f} "
              f"{steps / dt:7.1f} steps/s rare_share="
              f"{runs[-1]['rare_batch_visit_share']:.2f}", flush=True)

    by = {r["policy"]: r for r in runs}
    ok = (by["loss-prop"]["steps_to_target_sustained"] is not None
          and by["fcpr"]["steps_to_target_sustained"] is not None
          and (by["loss-prop"]["steps_to_target_sustained"]
               < by["fcpr"]["steps_to_target_sustained"]))
    return {
        "config": {"model": "softmax-regression", "dim": dim,
                   "classes": n_classes, "batch": bs, "n_batches": nb,
                   "chunk_steps": K, "steps": steps,
                   "target_loss": args.target, "devices": n_dev,
                   "imbalance": imbalance,
                   "note": ("rare classes only in the last 2 of "
                            f"{nb} class-sorted batches; FCPR visits them "
                            "2/n_b of the time, loss-aware policies "
                            "proportionally to their (higher) loss")},
        "runs": runs,
        "loss_prop_beats_fcpr": ok,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--n-batches", type=int, default=16, dest="n_batches")
    ap.add_argument("--chunk-steps", type=int, default=8, dest="chunk_steps")
    ap.add_argument("--target", type=float, default=0.05,
                    help="full-dataset loss defining steps_to_target")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced run (CI): fewer steps, looser target")
    ap.add_argument("--out", default="BENCH_sched_policies.json")
    args = ap.parse_args()

    if args.smoke:
        args.steps = min(args.steps, 480)
        args.target = max(args.target, 0.1)

    payload = {"mode": "smoke" if args.smoke else "full",
               "results": [run_single(args)]}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {args.out}")
    try:
        from common import save_json
        save_json("sched_policies", payload)
    except Exception:
        pass
    for res in payload["results"]:
        by = {r["policy"]: r for r in res["runs"]}
        print(f"devices={res['config']['devices']}: loss-prop reached "
              f"{res['config']['target_loss']} (sustained) in "
              f"{by['loss-prop']['steps_to_target_sustained']} steps vs "
              f"fcpr {by['fcpr']['steps_to_target_sustained']} "
              f"({'OK' if res['loss_prop_beats_fcpr'] else 'NOT FASTER'})")
    if not all(r["loss_prop_beats_fcpr"] for r in payload["results"]):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
