"""Paper Fig.8 (§6): data-parallel throughput vs per-device batch size.

Sweeps per-device batch under the shard_map ISGD engine for each device
count, measuring ms/step and samples/s, then fits the paper's Eq.21 cost
model t_iter = B_global/C1 + C2 per device count.  The paper's claim under
test: per-step overhead C2 (sync + launch) is amortized by larger
per-device batches, so the time-optimal batch grows with device count —
"batch size is the key to scalability".

``--engine async-ps`` reruns the same sweep on the asynchronous
parameter-server engine (paper §6.2): N *worker threads* instead of N
forced devices, ``--per-device-batch`` becomes the per-worker (= per
update) batch, and the fitted C2 is the per-update server/coordination
overhead rather than the sync+launch barrier — putting Eq.21's sync cost
and the async staleness cost side by side on the same configs
(``fig8_scaling_async-ps.json`` vs ``fig8_scaling.json``).

``--engine hybrid`` runs the unified DP × TP engine on a 2-D
``(data, model)`` host mesh (``--model-parallel``, default 2 when the
device count divides): the N forced devices split into data × model,
``--per-device-batch`` is per *data* shard, and the fitted C2 now also
carries the tensor-parallel collectives — the cost the ROADMAP's
multi-host item will amortize.  ``--smoke`` is the CI mode: a reduced
(devices × batch) grid, few steps, JSON to ``--out``.

Every cell records its process topology in the JSON schema —
``num_processes`` and ``local_device_count`` next to ``devices`` — so
multi-host cells (worker run with ``--coordinator``; the mesh comes from
the process-aware ``make_training_mesh`` factory) can never be conflated
with single-host ones in the Eq.21 fits.

Each (devices, batch) cell runs in a fresh child interpreter because
``--xla_force_host_platform_device_count`` (the flag that splits the host
CPU into N XLA devices) must be set before jax initializes; the parent
never imports jax.  (async-ps cells need no device flag — workers are
threads — but keep the same isolation.)  Standalone worker invocation:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m benchmarks.fig8_scaling --worker --per-device-batch 16
  PYTHONPATH=src python -m benchmarks.fig8_scaling --worker \
      --engine async-ps --workers 4 --per-device-batch 16

NOTE: on this container every "device"/worker shares the same host cores,
so absolute samples/s does NOT scale with N — the run exercises the real
engine code path and the C1/C2 fit shape, not real speedup.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

from benchmarks.common import emit, save_json, scaled

DEVICE_COUNTS = (1, 2, 8)
PER_DEVICE_BATCHES = (4, 16, 64)


def _worker(args) -> None:
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import ISGDConfig
    from repro.data import DeviceRing, FCPRSampler, make_classification
    from repro.distributed import (make_hybrid_step, prefetched,
                                   tensor_axes)
    from repro.distributed.data_parallel import data_axis_size
    from repro.launch import env as ENV
    from repro.launch.mesh import make_training_mesh
    from repro.models import cnn_loss_fn, init_cnn
    from repro.optim import momentum
    import dataclasses

    from repro.configs import CIFAR_QUICK

    if args.engine == "async-ps":
        _worker_async(args)
        return

    n_dev = len(jax.devices())
    # process-aware factory: single-process -> the historical (data, model)
    # host mesh; with --coordinator the same cell runs on a (pod, data,
    # model) mesh over the global device set
    mesh = make_training_mesh(
        model=args.model_parallel if args.engine == "hybrid" else 1)
    n_data = data_axis_size(mesh)
    global_batch = args.per_device_batch * n_data
    cfg = dataclasses.replace(CIFAR_QUICK, image_size=16, channels=3,
                              num_classes=10)
    data = make_classification(0, max(global_batch * 4, 256), 16, 3, 10,
                               noise=0.6)
    sampler = FCPRSampler(data, batch_size=global_batch, seed=1)
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=2.0, stop=3)
    init_fn, step = make_hybrid_step(
        lambda p, b: cnn_loss_fn(p, cfg, b), momentum(0.9), icfg, mesh,
        lr_fn=lambda _: jnp.asarray(0.05))
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    if tensor_axes(mesh):
        from repro.launch import shardings as SH
        params, _ = SH.hybrid_params_placement(mesh, params)
    state = init_fn(params)
    topo = ENV.topology()
    if topo.num_processes > 1:
        # per-step host uploads would be a cross-process coordination
        # point every step; stripe the epoch onto the ring once instead
        prefetch = DeviceRing(sampler.epoch_arrays(), global_batch,
                              mesh=mesh, axis=None, relayout=True)
    else:
        prefetch = prefetched(sampler, mesh)

    # warmup (compile) then timed steps
    state, params, m = step(state, params, prefetch(0))
    jax.block_until_ready(m["loss"])
    steps = args.steps
    t0 = time.perf_counter()
    for j in range(1, steps + 1):
        state, params, m = step(state, params, prefetch(j))
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / steps
    # trailing 1 = measured flag: the wall above is bracketed by
    # block_until_ready, never a dispatch-time estimate (Eq.21 fit guard)
    print(f"RESULT {n_dev} {args.per_device_batch} {dt*1e3:.3f} "
          f"{global_batch/dt:.1f} {global_batch} "
          f"{topo.num_processes} {jax.local_device_count()} 1", flush=True)


def _worker_async(args) -> None:
    """One async-ps cell: N worker threads, per-worker batch b — the cost
    per *update* is what Eq.21's t_iter becomes without the sync barrier."""
    import time

    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import CIFAR_QUICK
    from repro.core import ISGDConfig
    from repro.data import FCPRSampler, make_classification
    from repro.distributed import AsyncPSCoordinator
    from repro.models import cnn_loss_fn, init_cnn
    from repro.optim import momentum

    n = args.workers
    b = args.per_device_batch
    cfg = dataclasses.replace(CIFAR_QUICK, image_size=16, channels=3,
                              num_classes=10)
    # same sample budget shape as the sync cell, rounded so every worker
    # owns a whole FCPR shard
    n_batches = max(4, -(-max(b * n * 4, 256) // b // n)) * n
    data = make_classification(0, n_batches * b, 16, 3, 10, noise=0.6)
    sampler = FCPRSampler(data, batch_size=b, seed=1)
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=2.0, stop=3)
    coord = AsyncPSCoordinator(
        lambda p, bb: cnn_loss_fn(p, cfg, bb), momentum(0.9), icfg,
        workers=n, max_staleness=args.max_staleness,
        lr_fn=lambda _: jnp.asarray(0.05))
    params0 = init_cnn(jax.random.PRNGKey(0), cfg)
    coord.warmup(params0, sampler)                  # compile off the clock
    pushes = args.steps * n                         # N updates per "round"
    t0 = time.perf_counter()
    _, _, records = coord.run(params0, sampler, pushes)
    dt = (time.perf_counter() - t0) / len(records)
    print(f"RESULT {n} {b} {dt*1e3:.3f} {b/dt:.1f} {b} "
          f"1 {jax.local_device_count()} 1", flush=True)


def _spawn(engine: str, devices: int, per_device_batch: int, steps: int,
           max_staleness: int, model_parallel: int = 1):
    env = dict(os.environ)
    if engine in ("sync", "hybrid"):
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={devices}").strip()
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    root = os.path.join(os.path.dirname(__file__), "..")
    env["PYTHONPATH"] = os.pathsep.join(
        [src, root, env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fig8_scaling", "--worker",
         "--engine", engine, "--workers", str(devices),
         "--max-staleness", str(max_staleness),
         "--model-parallel", str(model_parallel),
         "--per-device-batch", str(per_device_batch), "--steps", str(steps)],
        capture_output=True, text=True, env=env, cwd=root, timeout=1200)
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            fields = line.split()
            _, n, b, ms, sps, gb, nproc, ldev = fields[:8]
            # older workers had no measured flag; their walls were synced
            measured = bool(int(fields[8])) if len(fields) > 8 else True
            return {"engine": engine, "devices": int(n),
                    "model_parallel": model_parallel,
                    "per_device_batch": int(b), "ms_per_step": float(ms),
                    "samples_per_s": float(sps), "global_batch": int(gb),
                    "num_processes": int(nproc),
                    "local_device_count": int(ldev),
                    "measured": measured}
    raise RuntimeError(
        f"worker engine={engine} devices={devices} b={per_device_batch} "
        f"failed:\n{proc.stdout}\n{proc.stderr}")


def _fit_c1_c2(cells):
    """Least-squares Eq.21 fit t_iter = B/C1 + C2 for one device/worker
    count; returns (C1 samples/s, C2 s).  B is the batch one update
    consumes (the worker reports it: global batch for sync/hybrid, the
    per-worker batch for async-ps — each push is one update)."""
    import numpy as np

    from repro.obs.timing import require_measured_walls
    require_measured_walls([not c.get("measured", True) for c in cells],
                           context="fig8_scaling Eq.21 fit")
    bs = np.array([c["global_batch"] for c in cells], float)
    ts = np.array([c["ms_per_step"] * 1e-3 for c in cells])
    A = np.stack([bs, np.ones_like(bs)], axis=1)
    (inv_c1, c2), *_ = np.linalg.lstsq(A, ts, rcond=None)
    return 1.0 / max(inv_c1, 1e-9), max(c2, 0.0)


def _model_parallel_for(engine: str, devices: int) -> int:
    """hybrid sweep: split even device counts 2-way over 'model' so the
    cell actually exercises DP × TP; odd/1-device cells stay pure DP."""
    return 2 if engine == "hybrid" and devices % 2 == 0 else 1


def run(engine: str = "sync", max_staleness: int = 1, *,
        device_counts=DEVICE_COUNTS, per_device_batches=PER_DEVICE_BATCHES,
        steps=None, out=None, smoke: bool = False):
    steps = scaled(8, lo=3) if steps is None else steps
    cells = []
    for n in device_counts:
        for b in per_device_batches:
            cells.append(_spawn(engine, n, b, steps, max_staleness,
                                _model_parallel_for(engine, n)))
    fits = {}
    # sync keeps the historical "fig8_scaling_n{n}" emit/JSON names so the
    # checked-in perf trajectory stays one continuous series
    prefix = "fig8_scaling" if engine == "sync" else f"fig8_scaling_{engine}"
    for n in device_counts:
        mine = [c for c in cells if c["devices"] == n]
        c1, c2 = _fit_c1_c2(mine)
        fits[n] = {"c1_samples_per_s": c1, "c2_s": c2}
        best = max(mine, key=lambda c: c["samples_per_s"])
        emit(f"{prefix}_n{n}",
             best["ms_per_step"] * 1e3,
             best_per_device_batch=best["per_device_batch"],
             best_samples_per_s=f"{best['samples_per_s']:.1f}",
             fitted_C1=f"{c1:.0f}", fitted_C2_ms=f"{c2*1e3:.2f}")
    payload = {"engine": engine, "cells": cells, "fits": fits,
               "steps_per_cell": steps, "mode": "smoke" if smoke else "full"}
    if engine == "async-ps":
        payload["max_staleness"] = max_staleness
    if not smoke:
        # smoke grids must not overwrite the full-sweep record — the
        # emit/JSON names above are one continuous perf series
        save_json(prefix, payload)
    if out:
        import json
        with open(out, "w") as f:
            json.dump(payload, f, indent=1, default=float)
        print(f"wrote {out}")
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--engine", default="sync",
                    choices=["sync", "hybrid", "async-ps"],
                    help="sync = shard_map data-parallel; hybrid = the "
                         "DP x TP engine on a (data, model) mesh; async-ps "
                         "= parameter-server worker threads")
    ap.add_argument("--workers", type=int, default=2,
                    help="worker mode, async-ps: thread count (parent "
                         "passes the device-count axis here)")
    ap.add_argument("--max-staleness", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="worker mode, hybrid: devices on the 'model' axis "
                         "(the parent sweep sets 2 for even device counts)")
    ap.add_argument("--per-device-batch", type=int, default=16)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="CI: reduced grid (devices 1,2 x batch 4,16), "
                         "few steps")
    ap.add_argument("--out", default=None,
                    help="also dump the payload JSON to this path "
                         "(CI artifact)")
    from repro.launch import env as ENV      # jax-free import (parent-safe)
    ENV.add_process_args(ap)
    args = ap.parse_args()
    if args.worker:
        ENV.initialize_from_args(args)
        _worker(args)
    elif args.smoke:
        run(args.engine, args.max_staleness, device_counts=(1, 2),
            per_device_batches=(4, 16), steps=min(args.steps, 4),
            out=args.out, smoke=True)
    else:
        run(args.engine, args.max_staleness, out=args.out)


if __name__ == "__main__":
    main()
