"""Paper Fig.8 (§6): data-parallel throughput vs per-device batch size.

Sweeps per-device batch under the shard_map ISGD engine for each device
count, measuring ms/step and samples/s, then fits the paper's Eq.21 cost
model t_iter = B_global/C1 + C2 per device count.  The paper's claim under
test: per-step overhead C2 (sync + launch) is amortized by larger
per-device batches, so the time-optimal batch grows with device count —
"batch size is the key to scalability".

``--engine async-ps`` reruns the same sweep on the asynchronous
parameter-server engine (paper §6.2): N *worker threads* instead of N
forced devices, ``--per-device-batch`` becomes the per-worker (= per
update) batch, and the fitted C2 is the per-update server/coordination
overhead rather than the sync+launch barrier — putting Eq.21's sync cost
and the async staleness cost side by side on the same configs
(``fig8_scaling_async-ps.json`` vs ``fig8_scaling.json``).

Each (devices, batch) cell runs in a fresh child interpreter because
``--xla_force_host_platform_device_count`` (the flag that splits the host
CPU into N XLA devices) must be set before jax initializes; the parent
never imports jax.  (async-ps cells need no device flag — workers are
threads — but keep the same isolation.)  Standalone worker invocation:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m benchmarks.fig8_scaling --worker --per-device-batch 16
  PYTHONPATH=src python -m benchmarks.fig8_scaling --worker \
      --engine async-ps --workers 4 --per-device-batch 16

NOTE: on this container every "device"/worker shares the same host cores,
so absolute samples/s does NOT scale with N — the run exercises the real
engine code path and the C1/C2 fit shape, not real speedup.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

from benchmarks.common import emit, save_json, scaled

DEVICE_COUNTS = (1, 2, 8)
PER_DEVICE_BATCHES = (4, 16, 64)


def _worker(args) -> None:
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import ISGDConfig
    from repro.data import FCPRSampler, make_classification
    from repro.distributed import make_data_parallel_step, prefetched
    from repro.launch.mesh import make_data_mesh
    from repro.models import cnn_loss_fn, init_cnn
    from repro.optim import momentum
    import dataclasses

    from repro.configs import CIFAR_QUICK

    if args.engine == "async-ps":
        _worker_async(args)
        return

    n_dev = len(jax.devices())
    global_batch = args.per_device_batch * n_dev
    cfg = dataclasses.replace(CIFAR_QUICK, image_size=16, channels=3,
                              num_classes=10)
    data = make_classification(0, max(global_batch * 4, 256), 16, 3, 10,
                               noise=0.6)
    sampler = FCPRSampler(data, batch_size=global_batch, seed=1)
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=2.0, stop=3)
    mesh = make_data_mesh()
    init_fn, step = make_data_parallel_step(
        lambda p, b: cnn_loss_fn(p, cfg, b), momentum(0.9), icfg, mesh,
        lr_fn=lambda _: jnp.asarray(0.05))
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    state = init_fn(params)
    prefetch = prefetched(sampler, mesh)

    # warmup (compile) then timed steps
    state, params, m = step(state, params, prefetch(0))
    jax.block_until_ready(m["loss"])
    steps = args.steps
    t0 = time.perf_counter()
    for j in range(1, steps + 1):
        state, params, m = step(state, params, prefetch(j))
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / steps
    print(f"RESULT {n_dev} {args.per_device_batch} {dt*1e3:.3f} "
          f"{global_batch/dt:.1f}", flush=True)


def _worker_async(args) -> None:
    """One async-ps cell: N worker threads, per-worker batch b — the cost
    per *update* is what Eq.21's t_iter becomes without the sync barrier."""
    import time

    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import CIFAR_QUICK
    from repro.core import ISGDConfig
    from repro.data import FCPRSampler, make_classification
    from repro.distributed import AsyncPSCoordinator
    from repro.models import cnn_loss_fn, init_cnn
    from repro.optim import momentum

    n = args.workers
    b = args.per_device_batch
    cfg = dataclasses.replace(CIFAR_QUICK, image_size=16, channels=3,
                              num_classes=10)
    # same sample budget shape as the sync cell, rounded so every worker
    # owns a whole FCPR shard
    n_batches = max(4, -(-max(b * n * 4, 256) // b // n)) * n
    data = make_classification(0, n_batches * b, 16, 3, 10, noise=0.6)
    sampler = FCPRSampler(data, batch_size=b, seed=1)
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=2.0, stop=3)
    coord = AsyncPSCoordinator(
        lambda p, bb: cnn_loss_fn(p, cfg, bb), momentum(0.9), icfg,
        workers=n, max_staleness=args.max_staleness,
        lr_fn=lambda _: jnp.asarray(0.05))
    params0 = init_cnn(jax.random.PRNGKey(0), cfg)
    coord.warmup(params0, sampler)                  # compile off the clock
    pushes = args.steps * n                         # N updates per "round"
    t0 = time.perf_counter()
    _, _, records = coord.run(params0, sampler, pushes)
    dt = (time.perf_counter() - t0) / len(records)
    print(f"RESULT {n} {b} {dt*1e3:.3f} {b/dt:.1f}", flush=True)


def _spawn(engine: str, devices: int, per_device_batch: int, steps: int,
           max_staleness: int):
    env = dict(os.environ)
    if engine == "sync":
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={devices}").strip()
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    root = os.path.join(os.path.dirname(__file__), "..")
    env["PYTHONPATH"] = os.pathsep.join(
        [src, root, env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fig8_scaling", "--worker",
         "--engine", engine, "--workers", str(devices),
         "--max-staleness", str(max_staleness),
         "--per-device-batch", str(per_device_batch), "--steps", str(steps)],
        capture_output=True, text=True, env=env, cwd=root, timeout=1200)
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            _, n, b, ms, sps = line.split()
            return {"engine": engine, "devices": int(n),
                    "per_device_batch": int(b), "ms_per_step": float(ms),
                    "samples_per_s": float(sps)}
    raise RuntimeError(
        f"worker engine={engine} devices={devices} b={per_device_batch} "
        f"failed:\n{proc.stdout}\n{proc.stderr}")


def _fit_c1_c2(cells):
    """Least-squares Eq.21 fit t_iter = B/C1 + C2 for one device/worker
    count; returns (C1 samples/s, C2 s).  B is the batch one update
    consumes: the global batch for the sync engine, the per-worker batch
    for async-ps (each push is one update)."""
    import numpy as np
    bs = np.array([c["per_device_batch"] *
                   (c["devices"] if c["engine"] == "sync" else 1)
                   for c in cells], float)
    ts = np.array([c["ms_per_step"] * 1e-3 for c in cells])
    A = np.stack([bs, np.ones_like(bs)], axis=1)
    (inv_c1, c2), *_ = np.linalg.lstsq(A, ts, rcond=None)
    return 1.0 / max(inv_c1, 1e-9), max(c2, 0.0)


def run(engine: str = "sync", max_staleness: int = 1):
    steps = scaled(8, lo=3)
    cells = []
    for n in DEVICE_COUNTS:
        for b in PER_DEVICE_BATCHES:
            cells.append(_spawn(engine, n, b, steps, max_staleness))
    fits = {}
    # sync keeps the historical "fig8_scaling_n{n}" emit/JSON names so the
    # checked-in perf trajectory stays one continuous series
    prefix = "fig8_scaling" if engine == "sync" else f"fig8_scaling_{engine}"
    for n in DEVICE_COUNTS:
        mine = [c for c in cells if c["devices"] == n]
        c1, c2 = _fit_c1_c2(mine)
        fits[n] = {"c1_samples_per_s": c1, "c2_s": c2}
        best = max(mine, key=lambda c: c["samples_per_s"])
        emit(f"{prefix}_n{n}",
             best["ms_per_step"] * 1e3,
             best_per_device_batch=best["per_device_batch"],
             best_samples_per_s=f"{best['samples_per_s']:.1f}",
             fitted_C1=f"{c1:.0f}", fitted_C2_ms=f"{c2*1e3:.2f}")
    payload = {"engine": engine, "cells": cells, "fits": fits,
               "steps_per_cell": steps}
    if engine == "async-ps":
        payload["max_staleness"] = max_staleness
    save_json(prefix, payload)
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--engine", default="sync", choices=["sync", "async-ps"],
                    help="sync = shard_map data-parallel; async-ps = "
                         "parameter-server worker threads")
    ap.add_argument("--workers", type=int, default=2,
                    help="worker mode, async-ps: thread count (parent "
                         "passes the device-count axis here)")
    ap.add_argument("--max-staleness", type=int, default=1)
    ap.add_argument("--per-device-batch", type=int, default=16)
    ap.add_argument("--steps", type=int, default=8)
    args = ap.parse_args()
    if args.worker:
        _worker(args)
    else:
        run(args.engine, args.max_staleness)


if __name__ == "__main__":
    main()
