"""E3 — paper Fig.3: the dynamic upper control limit identifies
under-trained (outlier) batches on the fly.

Claim under test: with a 3σ (here kσ) limit over the epoch window, a
minority of batches is flagged, and flagged batches have losses above the
running average.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, save_json, scaled
from repro.configs import CIFAR_QUICK
from repro.core import ISGDConfig
from repro.data import FCPRSampler, make_classification
from repro.models import cnn_loss_fn, init_cnn
from repro.optim import momentum
from repro.train import train


def run():
    n = scaled(1500, lo=400)
    data = make_classification(0, n, 16, 3, 10, noise=0.5, class_skew=0.5,
                               class_spread=3.0)
    sampler = FCPRSampler(data, batch_size=50, seed=1, shuffle_quality=0.2)
    import dataclasses
    cfg = dataclasses.replace(CIFAR_QUICK, image_size=16, channels=3, num_classes=10)
    loss_fn = lambda p, b: cnn_loss_fn(p, cfg, b)     # noqa: E731
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    steps = scaled(14, lo=7) * sampler.n_batches
    t0 = time.perf_counter()
    _, state, log, _ = train(
        params, loss_fn, momentum(0.9), sampler, steps=steps, lr=0.08,
        inconsistent=True,
        isgd_cfg=ISGDConfig(n_batches=sampler.n_batches, k_sigma=1.5, stop=3, zeta=0.02))
    us = (time.perf_counter() - t0) / steps * 1e6

    flagged = np.array(log.accelerated)
    losses = np.array(log.losses)
    psi_bar = np.array(log.psi_bar)
    frac = float(flagged.mean())
    above = bool((losses[flagged] > psi_bar[flagged]).all()) if flagged.any() else False
    emit("fig3_control_limit", us,
         outlier_frac=f"{frac:.3f}",
         n_outliers=int(flagged.sum()),
         all_outliers_above_mean=above,
         sub_iters_total=int(state.sub_iters))
    save_json("fig3_control_limit", {
        "losses": losses.tolist(), "limits": log.limits,
        "psi_bar": psi_bar.tolist(), "flagged": flagged.tolist()})
    return {"outlier_frac": frac, "all_above": above}


if __name__ == "__main__":
    run()
