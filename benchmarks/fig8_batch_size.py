"""E6 — paper Fig.5/Fig.8: time-domain convergence vs batch size.

Claims under test:
  1. Eq.24's predicted training time has an interior optimum: too-small
     batches pay sync cost C2 per update, unwieldy batches starve updates;
  2. the measured time-to-loss curve on this machine shows the same shape
     once C1 (throughput) and C2 (per-step overhead) are fitted from
     measured iteration times;
  3. a faster system (higher C1) prefers a larger batch.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, save_json, scaled
from repro.configs import CIFAR_QUICK
from repro.core import ISGDConfig, batch_model
from repro.data import FCPRSampler, make_classification
from repro.models import cnn_loss_fn, init_cnn
from repro.obs.timing import require_measured_walls
from repro.optim import momentum
from repro.train import train


def run():
    n = scaled(2400, lo=600)
    data = make_classification(0, n, 16, 3, 10, noise=0.6)
    import dataclasses
    cfg = dataclasses.replace(CIFAR_QUICK, image_size=16, channels=3, num_classes=10)
    loss_fn = lambda p, b: cnn_loss_fn(p, cfg, b)     # noqa: E731
    params0 = init_cnn(jax.random.PRNGKey(0), cfg)
    target_loss = 0.7

    batch_sizes = [30, 60, 120, 300, 600]
    measured = {}
    iter_times = {}
    for bs in batch_sizes:
        sampler = FCPRSampler(data, batch_size=bs, seed=1)
        steps = scaled(10, lo=5) * sampler.n_batches
        t0 = time.perf_counter()
        _, _, log, _ = train(
            params0, loss_fn, momentum(0.9), sampler,
            steps=min(steps, scaled(400, lo=120)), lr=0.05,
            inconsistent=False,
            isgd_cfg=ISGDConfig(n_batches=sampler.n_batches),
            step_sync=True)   # Eq.21 fit needs true per-step wall deltas
        require_measured_walls(log.wall_est,
                               context=f"fig8_batch_size bs={bs}")
        wall = np.array(log.wall)
        psi = np.array(log.psi_bar)
        hit = np.where(psi <= target_loss)[0]
        measured[bs] = float(wall[hit[0]]) if len(hit) else float("inf")
        # per-iteration time from the steady-state tail
        its = np.diff(wall)
        iter_times[bs] = float(np.median(its))

    # fit Eq.21: t_iter = bs/C1 + C2 (least squares on measured iteration times)
    bs_arr = np.array(batch_sizes, float)
    t_arr = np.array([iter_times[b] for b in batch_sizes])
    A = np.stack([bs_arr, np.ones_like(bs_arr)], axis=1)
    (inv_c1, c2), *_ = np.linalg.lstsq(A, t_arr, rcond=None)
    c1 = 1.0 / max(inv_c1, 1e-9)

    predicted = batch_model.predicted_time_to_loss(
        bs_arr, psi=0.02, c1=c1, c2=max(c2, 1e-4))
    best_measured = min((v, k) for k, v in measured.items())[1]
    best_predicted = int(bs_arr[int(np.argmin(predicted))])
    opt_slow = batch_model.optimal_batch_size(0.02, c1=c1, c2=max(c2, 1e-4))
    opt_fast = batch_model.optimal_batch_size(0.02, c1=c1 * 8, c2=max(c2, 1e-4))

    emit("fig8_batch_size", np.median(t_arr) * 1e6,
         fitted_C1_img_per_s=f"{c1:.0f}", fitted_C2_s=f"{max(c2,0):.4f}",
         best_bs_measured=best_measured, best_bs_predicted=best_predicted,
         faster_system_prefers_larger_batch=opt_fast >= opt_slow,
         measured="|".join(f"{k}:{v:.1f}" for k, v in measured.items()))
    save_json("fig8_batch_size", {
        "measured_time_to_loss": measured,
        "iter_times": iter_times, "c1": c1, "c2": float(c2),
        "predicted": dict(zip(map(int, bs_arr), map(float, predicted))),
        "opt_slow": opt_slow, "opt_fast": opt_fast})
    return measured


if __name__ == "__main__":
    run()
