"""E1/E2 — paper Fig.1: loss traces of 10 single-class batches (Sampling
Bias) and 10 i.i.d. batches (Intrinsic Image Difference) under plain SGD.

Claim under test: batch losses degrade at DIFFERENT rates in both settings —
the contribution of a batch's gradient update is non-uniform.
Metric: spread (max-min) and std of final per-batch losses; Spearman-free
proxy: ratio of slowest/fastest batch loss at the end.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, save_json, scaled
from repro.configs import CIFAR_QUICK
from repro.core import ISGDConfig
from repro.data import ExplicitBatches, iid_batches, single_class_batches
from repro.models import cnn_loss_fn, init_cnn
from repro.optim import momentum
from repro.train import train


THRESHOLD = 1.2       # loss level defining "trained" for the rate metric


def _trace(batches, steps, tag, lr=0.005):
    import dataclasses
    sampler = ExplicitBatches(batches)
    img = batches[0]["images"].shape[1]
    cfg = dataclasses.replace(CIFAR_QUICK, image_size=img, channels=3,
                              num_classes=10)
    loss_fn = lambda p, b: cnn_loss_fn(p, cfg, b)     # noqa: E731
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    t0 = time.perf_counter()
    _, _, log, _ = train(params, loss_fn, momentum(0.9), sampler,
                         steps=steps, lr=lr, inconsistent=False,
                         isgd_cfg=ISGDConfig(n_batches=sampler.n_batches))
    us = (time.perf_counter() - t0) / steps * 1e6
    n_b = sampler.n_batches
    losses = np.array(log.losses).reshape(-1, n_b)     # (epochs, n_b)
    # epoch at which each batch first crosses THRESHOLD (-1 = never)
    t2t = [int(np.argmax(losses[:, b] < THRESHOLD))
           if (losses[:, b] < THRESHOLD).any() else -1 for b in range(n_b)]
    hit = [t for t in t2t if t >= 0]
    # mid-training spread: std at the epoch where the FASTEST batch converged
    mid = min(hit) if hit else losses.shape[0] // 2
    spread = float(losses[mid].max() - losses[mid].min())
    return us, {"epochs_to_threshold": t2t,
                "n_converged": len(hit),
                "mid_epoch": int(mid),
                "mid_spread": spread,
                "mid_std": float(losses[mid].std()),
                "per_epoch": losses[::5].tolist()}


def run():
    epochs = scaled(150, lo=30)
    out = {}
    sc = single_class_batches(0, batch_size=64, num_classes=10, image_size=16,
                              noise=0.8, class_spread=3.0)
    us, d = _trace(sc, steps=epochs * 10, tag="single_class")
    emit("fig1a_single_class_batches", us,
         epochs_to_threshold="|".join(map(str, d["epochs_to_threshold"])),
         mid_spread=f"{d['mid_spread']:.3f}",
         rates_differ=len(set(d["epochs_to_threshold"])) > 1)
    out["single_class"] = d

    iid = iid_batches(1, n_batches=10, per_class=8, num_classes=10,
                      image_size=16, noise=0.8)
    us, d = _trace(iid, steps=epochs * 10, tag="iid", lr=0.01)
    emit("fig1b_iid_batches", us,
         epochs_to_threshold="|".join(map(str, d["epochs_to_threshold"])),
         mid_spread=f"{d['mid_spread']:.3f}",
         rates_differ=len(set(d["epochs_to_threshold"])) > 1)
    out["iid"] = d
    save_json("fig1_loss_traces", out)
    return out


if __name__ == "__main__":
    run()
