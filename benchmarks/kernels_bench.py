"""E8 — kernel microbenchmarks.

On this CPU container the Pallas kernels run in interpret mode (correctness
only — their wall time is meaningless), so the timings reported here are the
XLA reference paths; the kernels are asserted allclose against the oracles at
benchmark shapes.  On TPU the same harness times the Mosaic kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json, scaled, timeit
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.fused_xent import fused_xent, xent_ref
from repro.kernels.ssd_scan import ssd_chunked_pallas, ssd_ref

KEY = jax.random.PRNGKey(0)

# Module-level jitted references: jit caches live on the jitted function
# object, so a fresh ``jax.jit(lambda ...)`` built inside the bench fn
# starts cold every call — a repeat ``run()`` (warm-up pass, aggregate
# driver) would re-trace and re-compile inside the measured region.
# Hoisting them here makes the compile a once-per-process cost; ``timeit``
# still warms the *timed instance* before its timed iterations, so compile
# never lands in the timed region either way.
_XENT_REF = jax.jit(xent_ref, static_argnames=("vocab_size",))
_ATTN_REF = jax.jit(attention_ref, static_argnames=("causal", "window"))
_SSD_REF = jax.jit(ssd_ref, static_argnames=("chunk",))


def run():
    out = {}
    # fused xent — bench shape: 2048 tokens x 8k vocab (scaled)
    N, d, V = scaled(2048, lo=256), 256, scaled(8192, lo=1024)
    h = jax.random.normal(KEY, (N, d), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (d, V)) * 0.05
    labels = jax.random.randint(jax.random.fold_in(KEY, 2), (N,), 0, V)
    ref = lambda *a: _XENT_REF(*a, vocab_size=V)
    us = timeit(ref, h, w, labels, iters=3)
    kern = fused_xent(h[:256], w, labels[:256], vocab_size=V, bn=128, bv=512)
    np.testing.assert_allclose(kern, xent_ref(h[:256], w, labels[:256],
                                              vocab_size=V), rtol=1e-3, atol=1e-3)
    emit("kernel_fused_xent", us, shape=f"{N}x{d}x{V}",
         ref_path="xla", kernel_validated=True)
    out["fused_xent"] = us

    # flash attention — 8 heads x 1k seq
    BH, S, hd = 8, scaled(1024, lo=256), 64
    q = jax.random.normal(KEY, (BH, S, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (BH, S, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (BH, S, hd))
    ref = lambda *a: _ATTN_REF(*a, causal=True)
    us = timeit(ref, q, k, v, iters=3)
    kern = flash_attention(q[:2, :256], k[:2, :256], v[:2, :256],
                           causal=True, bq=128, bk=128)
    np.testing.assert_allclose(
        kern, attention_ref(q[:2, :256], k[:2, :256], v[:2, :256],
                            causal=True), rtol=2e-5, atol=2e-5)
    emit("kernel_flash_attention", us, shape=f"{BH}x{S}x{hd}",
         ref_path="xla", kernel_validated=True)
    out["flash_attention"] = us

    # SSD — mamba2-ish head block
    b, S2, nh, hd2, ds = 2, scaled(512, lo=128), 8, 64, 64
    x = jax.random.normal(KEY, (b, S2, nh, hd2))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 5), (b, S2, nh)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 6), (nh,)) * 0.3)
    B = jax.random.normal(jax.random.fold_in(KEY, 7), (b, S2, 1, ds))
    C = jax.random.normal(jax.random.fold_in(KEY, 8), (b, S2, 1, ds))
    ref = lambda *a: _SSD_REF(*a, chunk=128)
    us = timeit(ref, x, dt, A, B, C, iters=3)
    y1, s1 = ssd_chunked_pallas(x[:1, :128], dt[:1, :128], A, B[:1, :128],
                                C[:1, :128], chunk=64)
    y2, s2 = ssd_ref(x[:1, :128], dt[:1, :128], A, B[:1, :128], C[:1, :128],
                     chunk=64)
    np.testing.assert_allclose(y1, y2, rtol=1e-3, atol=1e-3)
    emit("kernel_ssd_scan", us, shape=f"{b}x{S2}x{nh}x{hd2}x{ds}",
         ref_path="xla", kernel_validated=True)
    out["ssd_scan"] = us
    save_json("kernels_bench", out)
    return out


if __name__ == "__main__":
    run()
