"""E4 — paper Fig.6: qualitative effect of inconsistent training.

Claims under test (paper §5.1):
  1. ISGD's running average loss ψ̄ descends at least as fast as SGD's;
  2. the std of the batch-loss distribution is REDUCED vs SGD mid-training
     (ISGD pulls under-trained batches back toward the mean);
  3. validation accuracy of ISGD ≥ SGD at matched iteration budget.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json, scaled
from repro.configs import CIFAR_QUICK
from repro.core import ISGDConfig
from repro.data import FCPRSampler, make_classification
from repro.models import cnn_accuracy, cnn_loss_fn, init_cnn
from repro.optim import momentum
from repro.train import train


def run():
    n = scaled(2000, lo=500)
    data = make_classification(0, n, 16, 3, 10, noise=0.3, class_skew=0.3,
                               class_spread=0.5)
    test = make_classification(123, 500, 16, 3, 10, noise=0.3, class_spread=0.5)
    sampler = FCPRSampler(data, batch_size=100, seed=1, shuffle_quality=0.4)
    import dataclasses
    cfg = dataclasses.replace(CIFAR_QUICK, image_size=16, channels=3, num_classes=10)
    loss_fn = lambda p, b: cnn_loss_fn(p, cfg, b)     # noqa: E731
    params0 = init_cnn(jax.random.PRNGKey(1), cfg)
    steps = scaled(16, lo=8) * sampler.n_batches
    Xt, yt = jnp.asarray(test["images"]), jnp.asarray(test["labels"])

    results = {}
    for name, inconsistent in (("sgd", False), ("isgd", True)):
        t0 = time.perf_counter()
        params, state, log, _ = train(
            params0, loss_fn, momentum(0.9), sampler, steps=steps, lr=0.05,
            inconsistent=inconsistent,
            isgd_cfg=ISGDConfig(n_batches=sampler.n_batches, k_sigma=1.5,
                                stop=3, zeta=0.02))
        us = (time.perf_counter() - t0) / steps * 1e6
        acc = cnn_accuracy(params, cfg, Xt, yt)
        results[name] = {
            "psi_bar": log.psi_bar, "psi_std": log.psi_std,
            "acc": acc, "us": us,
            "accel": int(state.accel_count)}

    n_b = sampler.n_batches
    mid = slice(steps // 3, 2 * steps // 3)
    std_sgd = float(np.mean(results["sgd"]["psi_std"][mid]))
    std_isgd = float(np.mean(results["isgd"]["psi_std"][mid]))
    final_sgd = float(np.mean(results["sgd"]["psi_bar"][-n_b:]))
    final_isgd = float(np.mean(results["isgd"]["psi_bar"][-n_b:]))
    emit("fig6_inconsistent_training", results["isgd"]["us"],
         psi_bar_sgd=f"{final_sgd:.4f}", psi_bar_isgd=f"{final_isgd:.4f}",
         mid_std_sgd=f"{std_sgd:.4f}", mid_std_isgd=f"{std_isgd:.4f}",
         std_reduced=std_isgd <= std_sgd * 1.05,
         acc_sgd=f"{results['sgd']['acc']:.3f}",
         acc_isgd=f"{results['isgd']['acc']:.3f}",
         accelerated=results["isgd"]["accel"])
    save_json("fig6_inconsistent_training", results)
    return results


if __name__ == "__main__":
    run()
