"""E9/E10 — aggregate the dry-run + roofline JSONs into the EXPERIMENTS.md
tables.  Reads experiments/dryrun/*.json (full-depth compiles: memory proof)
and experiments/roofline/*.json (trip-honest extrapolated terms)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, save_json

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load(pattern):
    recs = []
    for path in sorted(glob.glob(pattern)):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _key(r):
    return (r["arch"], SHAPE_ORDER.index(r["shape"]) if r["shape"] in SHAPE_ORDER else 9)


def markdown_table(recs, title):
    lines = [f"### {title}", "",
             "| arch | shape | mesh | GFLOP/dev | HBM GB/dev | coll GB/dev | "
             "compute ms | memory ms | coll ms | bottleneck | useful |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=_key):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['hlo_gflops']:.0f} | {r['hlo_gbytes']:.1f} | "
            f"{r['collective_gbytes']:.2f} | {r['compute_s']*1e3:.1f} | "
            f"{r['memory_s']*1e3:.1f} | {r['collective_s']*1e3:.1f} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.2f} |")
    return "\n".join(lines)


def run():
    dry = [r for r in _load("experiments/dryrun/*.json")
           if "16data" in r["mesh"]]
    ana = _load("experiments/roofline/*.json")
    pods = [r for r in _load("experiments/dryrun/*.json")
            if "pod" in r["mesh"]]

    n_dry = len({(r['arch'], r['shape']) for r in dry})
    n_pod = len({(r['arch'], r['shape']) for r in pods})
    emit("dryrun_singlepod_pairs", 0, passed=n_dry)
    emit("dryrun_multipod_pairs", 0, passed=n_pod)

    for r in sorted(ana, key=_key):
        if r.get("fsdp", True) and r.get("inconsistent", True):
            emit(f"roofline_{r['arch']}_{r['shape']}",
                 max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
                 bottleneck=r["bottleneck"],
                 compute_ms=f"{r['compute_s']*1e3:.1f}",
                 memory_ms=f"{r['memory_s']*1e3:.1f}",
                 collective_ms=f"{r['collective_s']*1e3:.1f}",
                 useful=f"{r['useful_flops_ratio']:.2f}")

    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline_tables.md", "w") as f:
        f.write(markdown_table(dry, "Dry-run (full depth, single pod; "
                               "cost_analysis counts loop bodies once)") + "\n\n")
        f.write(markdown_table(pods, "Dry-run (full depth, 2 pods)") + "\n\n")
        f.write(markdown_table(
            [r for r in ana if r.get("fsdp", True)],
            "Roofline (trip-honest extrapolated, single pod)") + "\n")
    save_json("roofline_summary", {
        "singlepod_pairs": n_dry, "multipod_pairs": n_pod,
        "analysis_pairs": len(ana)})
    return dry, ana


if __name__ == "__main__":
    run()
