"""Serving benchmark: continuous batching vs batch-blocking one-shot
generate, plus hot-snapshot-swap latency impact.

Workload: requests with 4x-varying prompt lengths ({1,2,4}x base) and
4x-varying token budgets ({1,4}x base), decorrelated so every prompt-length
bucket mixes short and long budgets — the regime where static batching
pays head-of-line blocking.

Legs (same model, same params, same request set):

  * ``oneshot``    — the seed engine with the best static policy available
                     to it: arrival-order chunks of ``max_batch``, length-
                     bucketed into rectangular sub-batches, each sub-batch
                     decoding to its *longest* member's budget (short rows
                     block until the longest finishes).  tok/s counts only
                     useful (requested) tokens.
  * ``continuous`` — ``repro.serve.ContinuousScheduler``: per-request
                     admission into preallocated KV slots, retire on budget,
                     no head-of-line blocking.  Also records per-token
                     latency p50/p95.
  * ``swap``       — the continuous leg re-run while the driver publishes a
                     fresh snapshot every ``--publish-every-steps`` scheduler
                     steps (the train-and-serve loop with a deterministic
                     publisher).  Records swap count, generations served,
                     per-token p50/p95, and the latency of swap-adjacent
                     decode steps vs quiet steps — the stall a request sees
                     when params are hot-swapped under it.

``--smoke`` is the CI leg: reduced workload, asserts continuous tok/s beats
the one-shot baseline (exit 1 otherwise).  Writes ``BENCH_serve.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def make_workload(n, base_plen, base_steps, vocab, seed=0):
    """Prompt lengths {1,2,4}x by i%3; budgets {1,4}x by i%2 — decorrelated
    (gcd(2,3)=1), so every length bucket mixes short and long budgets."""
    from repro.serve import Request
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        plen = base_plen * (1, 2, 4)[i % 3]
        steps = base_steps * (1, 4)[i % 2]
        prompt = rng.randint(0, vocab, size=(plen,)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=steps))
    return reqs


def run_oneshot_bucketed(engine, reqs, max_batch):
    """Static batching baseline.  -> (useful_tokens, wall_seconds)."""
    def once():
        useful = 0
        for c in range(0, len(reqs), max_batch):
            groups = {}
            for r in reqs[c:c + max_batch]:
                groups.setdefault(len(r.prompt), []).append(r)
            for rs in groups.values():
                prompts = np.stack([r.prompt for r in rs])
                engine.generate(prompts,
                                steps=max(r.max_new_tokens for r in rs))
                useful += sum(r.max_new_tokens for r in rs)
        return useful
    once()                                   # warmup: identical shapes
    t0 = time.perf_counter()
    useful = once()
    return useful, time.perf_counter() - t0


from repro.obs.stats import percentile as pct


def lat_stats(comps):
    gaps = [t for c in comps for t in c.token_times[1:]]
    return {"p50_ms": pct(gaps, 50) * 1e3, "p95_ms": pct(gaps, 95) * 1e3}


def run_continuous(model, params, reqs, args, *, watcher=None,
                   publish=None, publish_every_steps=0):
    """-> (scheduler, result dict).  With ``publish`` set, a new snapshot is
    published every ``publish_every_steps`` scheduler steps (between timed
    steps — writer cost is not serving cost) and per-step walls are split
    into swap-adjacent vs quiet."""
    from repro.serve import ContinuousScheduler, Request
    sched = ContinuousScheduler(
        model, params, max_batch=args.max_batch, max_seq=args.max_seq,
        watcher=watcher, swap_poll_every=2)
    plens = sorted({len(r.prompt) for r in reqs})
    sched.warmup([Request(rid=-1 - i, prompt=np.zeros(p, np.int32),
                          max_new_tokens=2) for i, p in enumerate(plens)])
    for r in reqs:
        assert sched.submit(r)
    swap_walls, quiet_walls = [], []
    t0 = time.perf_counter()
    while sched.pending:
        if publish is not None and sched.step_count % publish_every_steps == 0:
            publish()
        n_swaps = len(sched.swap_events)
        ts = time.perf_counter()
        sched.step()
        (swap_walls if len(sched.swap_events) > n_swaps
         else quiet_walls).append(time.perf_counter() - ts)
    wall = time.perf_counter() - t0
    comps = sorted(sched.completions, key=lambda c: c.rid)
    n_tok = sum(len(c.tokens) for c in comps)
    res = {"tokens": n_tok, "wall_s": wall, "tok_s": n_tok / wall,
           **lat_stats(comps)}
    if publish is not None:
        res.update({
            "n_swaps": len(sched.swap_events),
            "generations_served": sorted({c.gen_finished for c in comps}),
            "swap_load_s": [ev.load_seconds for ev in sched.swap_events],
            "swap_step_p50_ms": pct(swap_walls, 50) * 1e3,
            "swap_step_p95_ms": pct(swap_walls, 95) * 1e3,
            "quiet_step_p50_ms": pct(quiet_walls, 50) * 1e3,
            "quiet_step_p95_ms": pct(quiet_walls, 95) * 1e3,
            "swap_step_p95_delta_ms":
                (pct(swap_walls, 95) - pct(quiet_walls, 95)) * 1e3,
        })
    return sched, res


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="transformer",
                    help="paper_transformer zoo family")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--publish-every-steps", type=int, default=10)
    ap.add_argument("--smoke", action="store_true",
                    help="CI: reduced workload, assert continuous beats "
                         "oneshot")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 12)

    import jax
    from repro.configs import zoo_config
    from repro.models import build_model
    from repro.serve import ServeEngine, SnapshotWatcher, publish_pointer
    from repro.train.checkpoints import save as ckpt_save

    cfg = zoo_config(args.model, "tiny")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=args.max_seq)
    reqs = make_workload(args.requests, args.prompt_len, args.decode_steps,
                         cfg.vocab_size)

    useful, wall = run_oneshot_bucketed(
        ServeEngine(model, params, max_seq=args.max_seq), reqs,
        args.max_batch)
    oneshot = {"tokens": useful, "wall_s": wall, "tok_s": useful / wall}
    print(f"oneshot(bucketed): {useful} useful tok in {wall:.2f}s "
          f"({oneshot['tok_s']:.1f} tok/s)")

    _, cont = run_continuous(model, params, reqs, args)
    print(f"continuous: {cont['tokens']} tok in {cont['wall_s']:.2f}s "
          f"({cont['tok_s']:.1f} tok/s) p50={cont['p50_ms']:.2f}ms "
          f"p95={cont['p95_ms']:.2f}ms")

    # swap leg: deterministic publisher — a fresh snapshot every
    # publish_every_steps scheduler steps, picked up by the watcher poll
    with tempfile.TemporaryDirectory() as pub:
        n_pub = [0]

        def publish():
            n_pub[0] += 1
            path = os.path.join(pub, f"ckpt_{n_pub[0]:08d}.npz")
            ckpt_save(path, {"params": params},
                      extra={"step": n_pub[0] * 100})
            publish_pointer(pub, path)

        publish()
        watcher = SnapshotWatcher(pub, params_like=params)
        sched, swap = run_continuous(
            model, params, reqs, args, watcher=watcher, publish=publish,
            publish_every_steps=args.publish_every_steps)
    print(f"swap leg: {swap['n_swaps']} swaps, generations "
          f"{swap['generations_served']}, p95 {swap['p95_ms']:.2f}ms, "
          f"swap-step p95 {swap['swap_step_p95_ms']:.2f}ms vs quiet "
          f"{swap['quiet_step_p95_ms']:.2f}ms "
          f"(delta {swap['swap_step_p95_delta_ms']:+.2f}ms)")

    speedup = cont["tok_s"] / oneshot["tok_s"]
    payload = {
        "mode": "smoke" if args.smoke else "full",
        "config": {"model": cfg.name, "requests": args.requests,
                   "prompt_lens": sorted({len(r.prompt) for r in reqs}),
                   "budgets": sorted({r.max_new_tokens for r in reqs}),
                   "max_batch": args.max_batch, "max_seq": args.max_seq,
                   "devices": jax.device_count()},
        "oneshot": oneshot, "continuous": cont, "swap": swap,
        "speedup_continuous_vs_oneshot": speedup,
        "speedup_bar": 1.0,
        "speedup_ok": speedup >= 1.0,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    print(f"wrote {args.out}")
    print(f"continuous is {speedup:.2f}x the bucketed one-shot baseline "
          f"({'OK' if speedup >= 1.0 else 'BELOW 1.0x BAR'})")
    try:
        from common import save_json
        save_json("serve", payload)
    except Exception:
        pass
    if args.smoke and speedup < 1.0:
        sys.exit(1)


if __name__ == "__main__":
    main()
