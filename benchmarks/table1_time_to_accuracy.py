"""E5 — paper Table 1 / Fig.7: time-to-accuracy, ISGD vs SGD, on the three
dataset scales (MNIST-like/LeNet, CIFAR-like/CIFAR-quick,
downscaled-ImageNet-like/AlexNet-small).

Claim under test: ISGD reaches the target accuracy in less wall time /
fewer effective epochs than SGD (paper: 25.6% / 22.78% / 14.53% faster).
We report normalized time-to-target (SGD = 1.0) over REPRO_BENCH_RUNS runs.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json, scaled
from repro.configs import CIFAR_QUICK, LENET, ALEXNET_SMALL
from repro.core import ISGDConfig
from repro.data import FCPRSampler, make_classification
from repro.models import cnn_accuracy, cnn_loss_fn, init_cnn
from repro.optim import momentum
from repro.train import train

RUNS = int(os.environ.get("REPRO_BENCH_RUNS", "3"))

CASES = {
    "mnist_lenet": dict(cfg=LENET, image=16, ch=1, classes=10, n=1500,
                        noise=0.3, bs=100, lr=0.05, target=0.95,
                        max_epochs=20),
    "cifar_quick": dict(cfg=CIFAR_QUICK, image=16, ch=3, classes=10, n=1500,
                        noise=0.5, bs=100, lr=0.05, target=0.85,
                        max_epochs=20),
    "imagenet_alexnet": dict(cfg=ALEXNET_SMALL, image=32, ch=3, classes=100,
                             n=1000, noise=0.4, bs=100, lr=0.05, target=0.50,
                             max_epochs=20),
}


def _time_to_target(case, seed, inconsistent):
    c = case
    data = make_classification(seed, scaled(c["n"], lo=400), c["image"],
                               c["ch"], c["classes"], noise=c["noise"],
                               class_skew=0.2, class_spread=0.5)
    test = make_classification(seed + 777, 400, c["image"], c["ch"],
                               c["classes"], noise=c["noise"])
    sampler = FCPRSampler(data, batch_size=c["bs"], seed=seed,
                          shuffle_quality=0.5)
    import dataclasses
    cfg = dataclasses.replace(c["cfg"], image_size=c["image"],
                              channels=c["ch"], num_classes=c["classes"])
    loss_fn = lambda p, b: cnn_loss_fn(p, cfg, b)     # noqa: E731
    params = init_cnn(jax.random.PRNGKey(seed), cfg)
    Xt, yt = jnp.asarray(test["images"]), jnp.asarray(test["labels"])
    eval_fn = lambda p: cnn_accuracy(p, cfg, Xt, yt)  # noqa: E731
    steps = scaled(c["max_epochs"], lo=6) * sampler.n_batches
    _, _, log, evals = train(
        params, loss_fn, momentum(0.9), sampler, steps=steps, lr=c["lr"],
        inconsistent=inconsistent,
        isgd_cfg=ISGDConfig(n_batches=sampler.n_batches, k_sigma=1.5, stop=3, zeta=0.02),
        eval_fn=eval_fn, eval_every=sampler.n_batches)
    best = max(acc for _, _, acc in evals)
    hit = [(t, acc) for _, t, acc in evals if acc >= c["target"]]
    t_hit = hit[0][0] if hit else float("inf")
    return t_hit, best, log


def run():
    all_results = {}
    for name, case in CASES.items():
        rows = {"sgd": [], "isgd": []}
        accs = {"sgd": [], "isgd": []}
        for r in range(RUNS):
            for mode, key in ((False, "sgd"), (True, "isgd")):
                t, best, _ = _time_to_target(case, seed=100 + r,
                                             inconsistent=mode)
                rows[key].append(t)
                accs[key].append(best)
        t_sgd = float(np.mean([t for t in rows["sgd"] if np.isfinite(t)] or [np.inf]))
        t_isgd = float(np.mean([t for t in rows["isgd"] if np.isfinite(t)] or [np.inf]))
        imp = (t_sgd - t_isgd) / t_sgd * 100 if np.isfinite(t_sgd) and np.isfinite(t_isgd) else float("nan")
        emit(f"table1_{name}", t_isgd * 1e6 if np.isfinite(t_isgd) else -1,
             time_sgd_s=f"{t_sgd:.1f}", time_isgd_s=f"{t_isgd:.1f}",
             normalized_isgd=f"{t_isgd/t_sgd:.3f}" if np.isfinite(t_sgd / t_isgd) else "nan",
             improvement_pct=f"{imp:.1f}",
             best_acc_sgd=f"{np.mean(accs['sgd']):.3f}",
             best_acc_isgd=f"{np.mean(accs['isgd']):.3f}",
             runs=RUNS)
        all_results[name] = {"t_sgd": rows["sgd"], "t_isgd": rows["isgd"],
                             "acc_sgd": accs["sgd"], "acc_isgd": accs["isgd"]}
    save_json("table1_time_to_accuracy", all_results)
    return all_results


if __name__ == "__main__":
    run()
