"""Shared benchmark utilities.

Every benchmark emits ``name,us_per_call,derived`` CSV rows via ``emit`` and
returns a dict for the aggregate report.  REPRO_BENCH_SCALE scales workload
sizes (1.0 = the defaults used in EXPERIMENTS.md; CI smoke can use 0.25).
"""
from __future__ import annotations

import json
import os
import time

SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))
OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")


def scaled(n: int, lo: int = 1) -> int:
    return max(lo, int(n * SCALE))


def emit(name: str, us_per_call: float, **derived):
    d = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{d}", flush=True)


def save_json(name: str, payload: dict):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def timeit(fn, *args, warmup: int = 1, iters: int = 5):
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6      # us
