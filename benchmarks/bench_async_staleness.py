"""Async parameter-server staleness study: workers × max_staleness sweep.

The paper's §6.2 scaling mode trades synchronization cost for gradient
staleness; this benchmark measures both sides of that trade on the
lenet-8x8 config (the dispatch-bound small-CNN regime the fused-engine
bench established):

  * **throughput** — total server pushes per second for each
    (workers, max_staleness) cell, plus the synchronous per-step engine as
    the zero-staleness/zero-parallelism baseline;
  * **statistical cost** — final-epoch mean ψ̄ on the same global FCPR
    cycle and step budget, with the observed version-staleness τ
    distribution (mean/max vs the gate's ``(s+1)·N − 1`` bound) and the
    ISGD accelerate count, so the JSON records how much the control loop
    still fires as staleness grows.

Writes ``BENCH_async_staleness.json`` (checked in at the repo root) — the
async twin of ``BENCH_train_throughput.json``.  ``--smoke`` is the CI mode:
reduced cells/steps under both matrix device counts, artifact uploaded.

  PYTHONPATH=src python benchmarks/bench_async_staleness.py
  PYTHONPATH=src python benchmarks/bench_async_staleness.py --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

WORKERS = (1, 2, 4)
STALENESS = (0, 1, 4)


def _setup(args):
    import jax
    import jax.numpy as jnp

    from repro.configs.paper_cnns import CNNConfig, ConvSpec
    from repro.core import ISGDConfig
    from repro.data import FCPRSampler, make_classification
    from repro.models import cnn_loss_fn, init_cnn
    from repro.optim import momentum

    cfg = CNNConfig(name="lenet-8x8", image_size=8, channels=1,
                    num_classes=10,
                    convs=(ConvSpec(4, 3, pool=2), ConvSpec(8, 3, pool=2)),
                    hidden=(24,))
    data = make_classification(0, args.batch * args.n_batches,
                               cfg.image_size, cfg.channels, 10,
                               noise=0.6, class_spread=2.0)
    sampler = FCPRSampler(data, batch_size=args.batch, seed=1)
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=1.5, stop=3,
                      zeta=0.02)
    loss_fn = lambda p, b: cnn_loss_fn(p, cfg, b)
    # ψ̄-driven LR so the async one-step queue lag is on the measured path
    lr_fn = lambda pb: jnp.asarray(0.05) * jnp.clip(pb / 2.3, 0.5, 1.0)
    params0 = init_cnn(jax.random.PRNGKey(0), cfg)
    return loss_fn, momentum(0.9), icfg, lr_fn, params0, sampler


def _sync_cell(args, setup):
    import jax

    from repro.train import make_train_step

    loss_fn, rule, icfg, lr_fn, params0, sampler = setup
    init_fn, step = make_train_step(loss_fn, rule, icfg, lr_fn=lr_fn,
                                    donate=False)
    feed = lambda j: {k: jax.numpy.asarray(v) for k, v in sampler(j).items()}
    _, _, m = step(init_fn(params0), params0, feed(0))  # compile off-trajectory
    jax.block_until_ready(m["loss"])
    p = jax.tree.map(lambda x: x, params0)
    s = init_fn(p)
    psi = []
    t0 = time.perf_counter()
    for j in range(args.steps):
        s, p, m = step(s, p, feed(j))
        psi.append(m["psi_bar"])
    jax.block_until_ready(psi[-1])
    dt = time.perf_counter() - t0
    n_b = sampler.n_batches
    final = sum(float(x) for x in psi[-n_b:]) / n_b
    return {"engine": "sync", "workers": 1, "max_staleness": 0,
            "steps": args.steps, "updates_per_s": args.steps / dt,
            "wall_s": dt, "final_psi_bar": final,
            "accelerated": int(s.accel_count), "mean_tau": 0.0, "max_tau": 0}


def _async_cell(args, setup, workers, max_staleness):
    from repro.distributed import AsyncPSCoordinator, staleness_reduce_from_spec

    loss_fn, rule, icfg, lr_fn, params0, sampler = setup
    coord = AsyncPSCoordinator(
        loss_fn, rule, icfg, workers=workers, max_staleness=max_staleness,
        lr_fn=lr_fn, reduce_ctx=staleness_reduce_from_spec(args.decay))
    # compile propose + the accelerate subproblem + server ops off the clock
    coord.warmup(params0, sampler)
    t0 = time.perf_counter()
    _, state, records = coord.run(params0, sampler, args.steps)
    dt = time.perf_counter() - t0
    n_b = sampler.n_batches
    taus = [r["tau"] for r in records]
    final = sum(r["psi_bar"] for r in records[-n_b:]) / n_b
    return {"engine": "async-ps", "workers": workers,
            "max_staleness": max_staleness, "steps": len(records),
            "updates_per_s": len(records) / dt, "wall_s": dt,
            "final_psi_bar": final, "accelerated": int(state.accel_count),
            "mean_tau": sum(taus) / len(taus), "max_tau": max(taus),
            "tau_bound": (2 * max_staleness + 1) * (workers - 1)}


def run(args) -> dict:
    import jax

    setup = _setup(args)
    cells = [_sync_cell(args, setup)]
    workers = args.workers or WORKERS
    staleness = args.staleness or STALENESS
    for n in workers:
        for s in staleness:
            if n == 1 and s > 0:
                continue                     # 1 worker never waits: s is moot
            cells.append(_async_cell(args, setup, n, s))
            c = cells[-1]
            print(f"workers={c['workers']} s={c['max_staleness']} "
                  f"{c['updates_per_s']:7.1f} upd/s "
                  f"final_psi={c['final_psi_bar']:.3f} "
                  f"mean_tau={c['mean_tau']:.2f} max_tau={c['max_tau']}",
                  flush=True)
    sync = cells[0]
    print(f"sync baseline {sync['updates_per_s']:7.1f} upd/s "
          f"final_psi={sync['final_psi_bar']:.3f}")
    return {
        "config": {"model": "lenet-8x8", "batch": args.batch,
                   "n_batches": args.n_batches, "steps": args.steps,
                   "decay": args.decay, "devices": len(jax.devices())},
        "cells": cells,
        "note": ("worker threads share this host's cores, so updates/s "
                 "measures engine/coordination overhead, not parallel "
                 "speedup; the statistical columns (final_psi_bar, taus, "
                 "accelerated) are the staleness study proper"),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=192,
                    help="total server pushes per cell")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--n-batches", type=int, default=8, dest="n_batches")
    ap.add_argument("--decay", default="inverse")
    ap.add_argument("--workers", type=int, nargs="*", default=None)
    ap.add_argument("--staleness", type=int, nargs="*", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI: reduced sweep (workers 1,2 × staleness 0,2)")
    ap.add_argument("--out", default="BENCH_async_staleness.json")
    args = ap.parse_args()

    if args.smoke:
        args.steps = min(args.steps, 64)
        args.workers = args.workers or [1, 2]
        args.staleness = args.staleness or [0, 2]

    payload = {"mode": "smoke" if args.smoke else "full", "results": run(args)}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {args.out}")
    try:
        from common import save_json
        save_json("async_staleness", payload)
    except Exception:
        pass


if __name__ == "__main__":
    main()
