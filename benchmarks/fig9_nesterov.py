"""E7 — paper Fig.9: inconsistent training composes with Nesterov.

Claim under test: inconsistent-Nesterov reaches the target accuracy in
fewer tests (fixed-interval evaluations) than plain Nesterov (paper: 65 vs
75 tests = 13.4% gain).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_json, scaled
from repro.configs import CIFAR_QUICK
from repro.core import ISGDConfig
from repro.data import FCPRSampler, make_classification
from repro.models import cnn_accuracy, cnn_loss_fn, init_cnn
from repro.optim import nesterov
from repro.train import train


def run():
    n = scaled(1500, lo=500)
    data = make_classification(0, n, 16, 3, 10, noise=0.3, class_skew=0.3,
                               class_spread=0.5)
    test = make_classification(321, 400, 16, 3, 10, noise=0.3, class_spread=0.5)
    sampler = FCPRSampler(data, batch_size=100, seed=1, shuffle_quality=0.5)
    import dataclasses
    cfg = dataclasses.replace(CIFAR_QUICK, image_size=16, channels=3, num_classes=10)
    loss_fn = lambda p, b: cnn_loss_fn(p, cfg, b)     # noqa: E731
    params0 = init_cnn(jax.random.PRNGKey(1), cfg)
    Xt, yt = jnp.asarray(test["images"]), jnp.asarray(test["labels"])
    eval_fn = lambda p: cnn_accuracy(p, cfg, Xt, yt)  # noqa: E731
    steps = scaled(16, lo=8) * sampler.n_batches
    target = 0.80

    out = {}
    for name, inconsistent in (("nesterov", False), ("inconsistent_nesterov", True)):
        t0 = time.perf_counter()
        _, state, log, evals = train(
            params0, loss_fn, nesterov(0.9), sampler, steps=steps, lr=0.05,
            inconsistent=inconsistent,
            isgd_cfg=ISGDConfig(n_batches=sampler.n_batches, k_sigma=1.5,
                                stop=3, zeta=0.02),
            eval_fn=eval_fn, eval_every=max(sampler.n_batches // 2, 1))
        us = (time.perf_counter() - t0) / steps * 1e6
        tests_to_target = next((i + 1 for i, (_, _, a) in enumerate(evals)
                                if a >= target), None)
        out[name] = {"tests_to_target": tests_to_target,
                     "final_acc": evals[-1][2], "us": us,
                     "accel": int(state.accel_count)}

    a = out["inconsistent_nesterov"]["tests_to_target"]
    b = out["nesterov"]["tests_to_target"]
    gain = ((b - a) / b * 100) if a and b else float("nan")
    emit("fig9_nesterov", out["inconsistent_nesterov"]["us"],
         tests_nesterov=b, tests_inconsistent=a,
         gain_pct=f"{gain:.1f}",
         final_acc_nesterov=f"{out['nesterov']['final_acc']:.3f}",
         final_acc_inconsistent=f"{out['inconsistent_nesterov']['final_acc']:.3f}")
    save_json("fig9_nesterov", out)
    return out


if __name__ == "__main__":
    run()
