"""Fault-recovery study: elastic async-PS pool under injected worker faults.

ISSUE 7's end-state check: run the lenet-8x8 async engine twice on the same
global FCPR cycle —

  * **anchor** — the fault-free elastic pool (N workers, bounded
    staleness);
  * **faulted** — the same pool under a seeded :class:`repro.fault
    .FaultPlan`: 1 worker crash + 1 worker hang (hang > heartbeat deadline
    ⇒ evicted mid-sleep) drawn from the middle of the run, plus a one-shot
    corrupt push and a one-shot transient push failure on a surviving
    worker (absorbed by checksum-verify + bounded retry)

— and report **time-to-target**: the wall time at which each run's
trailing-epoch mean ψ̄ first reaches a target fixed from the anchor's
mid-run trajectory.  The recovery claim is the ratio: eviction +
re-striping keeps the faulted pool's time-to-target within a bounded
factor of the fault-free pool (the run *completes* and keeps converging on
survivors instead of deadlocking or failing).

Writes ``BENCH_fault_recovery.json`` (checked in at the repo root) with the
eviction/crash event log embedded.  ``--smoke`` is the CI mode: reduced
steps under both matrix device counts, artifact uploaded.

  PYTHONPATH=src python benchmarks/bench_fault_recovery.py
  PYTHONPATH=src python benchmarks/bench_fault_recovery.py --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def _setup(args):
    import jax
    import jax.numpy as jnp

    from repro.configs.paper_cnns import CNNConfig, ConvSpec
    from repro.core import ISGDConfig
    from repro.data import FCPRSampler, make_classification
    from repro.models import cnn_loss_fn, init_cnn
    from repro.optim import momentum

    cfg = CNNConfig(name="lenet-8x8", image_size=8, channels=1,
                    num_classes=10,
                    convs=(ConvSpec(4, 3, pool=2), ConvSpec(8, 3, pool=2)),
                    hidden=(24,))
    data = make_classification(0, args.batch * args.n_batches,
                               cfg.image_size, cfg.channels, 10,
                               noise=0.6, class_spread=2.0)
    sampler = FCPRSampler(data, batch_size=args.batch, seed=1)
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=1.5, stop=3,
                      zeta=0.02)
    loss_fn = lambda p, b: cnn_loss_fn(p, cfg, b)
    # ψ̄-driven LR so the async one-step queue lag is on the measured path
    lr_fn = lambda pb: jnp.asarray(0.05) * jnp.clip(pb / 2.3, 0.5, 1.0)
    params0 = init_cnn(jax.random.PRNGKey(0), cfg)
    return loss_fn, momentum(0.9), icfg, lr_fn, params0, sampler


def _make_plan(args):
    """Seeded plan: 1 crash + 1 hang (> deadline ⇒ evicted mid-sleep) in
    the middle of the run, plus a corrupt and a transient push on a
    SURVIVING worker so the checksum-reject/retry path is on the measured
    run too."""
    from repro.fault import FaultEvent, FaultPlan

    base = FaultPlan.random(args.workers, args.steps // args.workers,
                            seed=args.seed, crashes=1, hangs=1,
                            hang_seconds=args.hang)
    doomed = {e.worker for e in base.events}
    survivor = next(w for w in range(args.workers) if w not in doomed)
    events = list(base.events) + [
        FaultEvent(kind="corrupt", worker=survivor, step=1),
        FaultEvent(kind="transient", worker=survivor, step=2),
    ]
    return FaultPlan(events)


def _trailing_psi(records, n_b: int):
    """-> list of (wall, trailing-n_b mean ψ̄) per push, skipping warm-up."""
    out = []
    for i in range(n_b, len(records) + 1):
        window = records[i - n_b:i]
        out.append((window[-1]["wall"],
                    sum(r["psi_bar"] for r in window) / n_b))
    return out


def _time_to(series, target: float):
    for wall, psi in series:
        if psi <= target:
            return wall
    return None


def _cell(args, setup, *, faults=None, label: str):
    from repro.distributed import AsyncPSCoordinator
    from repro.fault import NO_FAULTS

    loss_fn, rule, icfg, lr_fn, params0, sampler = setup
    coord = AsyncPSCoordinator(
        loss_fn, rule, icfg, workers=args.workers,
        max_staleness=args.staleness, lr_fn=lr_fn, elastic=True,
        deadline_s=args.deadline, faults=faults or NO_FAULTS,
        verify_pushes=faults is not None)
    coord.warmup(params0, sampler)
    t0 = time.perf_counter()
    _, state, records = coord.run(params0, sampler, args.steps)
    dt = time.perf_counter() - t0
    series = _trailing_psi(records, sampler.n_batches)
    return {"cell": label, "workers": args.workers,
            "max_staleness": args.staleness, "pushes": len(records),
            "wall_s": dt, "updates_per_s": len(records) / dt,
            "final_psi_bar": series[-1][1] if series else None,
            "accelerated": int(state.accel_count),
            "events": coord.events, "series": series}


def run(args) -> dict:
    import jax

    setup = _setup(args)
    anchor = _cell(args, setup, label="anchor")
    plan = _make_plan(args)
    faulted = _cell(args, setup, faults=plan, label="faulted")

    # target: the anchor's trailing ψ̄ halfway through its own push stream —
    # comfortably reachable by the faulted run even though it loses ~40% of
    # its pushes to the two evictions
    mid = anchor["series"][len(anchor["series"]) // 2]
    target = mid[1]
    t_anchor = _time_to(anchor["series"], target)
    t_faulted = _time_to(faulted["series"], target)
    for c in (anchor, faulted):
        c.pop("series")
        c["time_to_target_s"] = {"anchor": t_anchor,
                                 "faulted": t_faulted}[c["cell"]]
    overhead = (t_faulted / t_anchor
                if t_faulted is not None and t_anchor else None)
    evicted = [e["worker"] for e in faulted["events"]
               if e["event"] == "evict"]
    print(f"anchor : {anchor['pushes']} pushes in {anchor['wall_s']:.2f}s, "
          f"time_to_target={t_anchor and round(t_anchor, 3)}s")
    print(f"faulted: {faulted['pushes']} pushes in "
          f"{faulted['wall_s']:.2f}s, "
          f"time_to_target={t_faulted and round(t_faulted, 3)}s, "
          f"evicted workers {evicted}")
    print(f"overhead ratio (faulted/anchor time-to-target): "
          f"{overhead and round(overhead, 2)}")
    return {
        "config": {"model": "lenet-8x8", "batch": args.batch,
                   "n_batches": args.n_batches, "steps": args.steps,
                   "workers": args.workers, "max_staleness": args.staleness,
                   "deadline_s": args.deadline, "hang_s": args.hang,
                   "seed": args.seed, "devices": len(jax.devices())},
        "plan": [repr(e) for e in plan.events],
        "target_psi_bar": target,
        "overhead_ratio": overhead,
        "cells": [anchor, faulted],
        "note": ("time-to-target compares the fault-free elastic pool with "
                 "the same pool losing 2/4 workers mid-run (crash + "
                 "hang-past-deadline): eviction + FCPR re-striping keeps "
                 "the run converging on survivors.  Worker threads share "
                 "this host's cores, so wall ratios measure recovery "
                 "overhead, not parallel speedup."),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=960,
                    help="total server pushes per cell (fault-free count; "
                         "the faulted cell completes fewer).  Long enough "
                         "that the fixed recovery cost (~deadline_s of "
                         "stall before eviction) amortizes visibly")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--n-batches", type=int, default=8, dest="n_batches")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--staleness", type=int, default=1)
    ap.add_argument("--deadline", type=float, default=1.0,
                    help="heartbeat deadline (s); the injected hang must "
                         "exceed it to trigger eviction")
    ap.add_argument("--hang", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--smoke", action="store_true",
                    help="CI: reduced steps, shorter deadline/hang")
    ap.add_argument("--out", default="BENCH_fault_recovery.json")
    args = ap.parse_args()

    if args.smoke:
        args.steps = min(args.steps, 96)
        args.deadline = min(args.deadline, 0.6)
        args.hang = min(args.hang, 2.0)

    payload = {"mode": "smoke" if args.smoke else "full", "results": run(args)}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {args.out}")
    try:
        from common import save_json
        save_json("fault_recovery", payload)
    except Exception:
        pass


if __name__ == "__main__":
    main()
