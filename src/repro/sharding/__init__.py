from repro.sharding.ctx import activation_sharding, constrain
from repro.sharding import rules  # noqa: F401

__all__ = ["constrain", "activation_sharding", "rules"]
