"""Logical -> physical sharding rules with divisibility fallbacks.

``param_spec(path, leaf, mesh_axes)`` maps every parameter leaf to a
PartitionSpec; ``activation_rules(...)`` builds the constrain() table used by
the launcher.  The rule engine is dumb on purpose: try the preferred axes in
order, keep the first whose dim is divisible by the mesh axis size, else
replicate — that single rule absorbs every oddity in the assigned archs
(mixtral's 8 experts vs model=16, starcoder2's kv=2, whisper's odd vocab).
"""
from __future__ import annotations

from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def pick_spec(mesh: Mesh, shape: Sequence[int], candidates) -> P:
    """First candidate PartitionSpec whose sharded dims all divide evenly."""
    for spec in candidates:
        ok = True
        for dim, axis in zip(shape, spec):
            if axis is None:
                continue
            if dim % _axis_size(mesh, axis) != 0:
                ok = False
                break
        if ok:
            return P(*spec)
    return P()


# ---------------------------------------------------------------------------
# parameter rules — Megatron row/column tensor parallelism + ZeRO over 'data'
# ---------------------------------------------------------------------------
# Projections that CONSUME a model-sharded activation (FFN down-proj,
# attention output, SSM output) are ROW-parallel: contraction dim on
# 'model', output resolved by a single all-reduce.  Everything else is
# COLUMN-parallel (output features on 'model').  Getting this wrong
# all-gathers the d_ff-wide hidden every layer — see EXPERIMENTS.md §Perf.
_ROW_PARALLEL = ("wo", "out_proj", "swo")


def _is_row(path: str) -> bool:
    leaf = path.rsplit("/", 1)[-1].strip("[]'\"")
    return leaf in _ROW_PARALLEL


def param_spec(mesh: Mesh, path: str, shape, *, fsdp: bool = True) -> P:
    nd = len(shape)
    d = "data" if fsdp else None
    if nd == 0 or max(shape) < 128:
        return P()
    if "embed" in path or "head" in path:
        # (V, d) or (d, V): shard vocab over model, other dim over data
        big = 0 if shape[0] >= shape[-1] else nd - 1
        cands = []
        if nd == 2:
            if big == 0:
                cands = [("model", d), ("model", None), (None, d), (None, None)]
            else:
                cands = [(d, "model"), (None, "model"), (d, None), (None, None)]
        return pick_spec(mesh, shape, cands)
    if "pos_embed" in path or "enc_pos" in path:
        return pick_spec(mesh, shape, [(None, "model"), (None, None)])
    if nd == 1:
        return P()
    row = _is_row(path)
    if nd == 2:
        if row:
            return pick_spec(mesh, shape, [
                ("model", d), ("model", None), (None, d), (None, None)])
        return pick_spec(mesh, shape, [
            (d, "model"), (None, "model"), (d, None), (None, None)])
    if nd == 3:
        # stacked blocks (n_blocks, in, out)
        if row:
            return pick_spec(mesh, shape, [
                (None, "model", d), (None, "model", None), (None, None, d),
                (None, None, None)])
        return pick_spec(mesh, shape, [
            (None, d, "model"), (None, None, "model"), (None, d, None),
            (None, None, None)])
    if nd == 4:
        # (n_blocks, E, in, out): expert-parallel over 'model' when divisible
        # (within-expert dims then use 'data'); else row/col over 'model'.
        if row:
            return pick_spec(mesh, shape, [
                (None, "model", d, None), (None, None, "model", d),
                (None, None, "model", None), (None, None, None, None)])
        return pick_spec(mesh, shape, [
            (None, "model", d, None), (None, None, d, "model"),
            (None, None, None, "model"), (None, None, None, None)])
    return P()


def params_shardings(mesh: Mesh, params_shapes, *, fsdp: bool = True):
    """Map a pytree of ShapeDtypeStruct -> pytree of NamedSharding."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(k) for k in path)
        spec = param_spec(mesh, pstr, leaf.shape, fsdp=fsdp)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, [s for s in out])


# ---------------------------------------------------------------------------
# activation / input rules
# ---------------------------------------------------------------------------
def batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def data_specs(mesh: Mesh, global_batch: int, *, seq_shard: bool = False):
    """PartitionSpecs for model inputs.

    If the batch doesn't divide the dp axes (long_500k B=1), shard the
    sequence dim over 'data' instead (context parallelism).
    """
    dp = batch_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if global_batch % dp_size == 0 and not seq_shard:
        return P(dp, None), P(dp)
    return P(None, "data"), P(None)


def activation_rule_table(mesh: Mesh, global_batch: int, *, seq_shard=False):
    dp = batch_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    batch_ok = global_batch % dp_size == 0 and not seq_shard
    b = dp if batch_ok else None
    s = None if batch_ok else "data"
    return {
        "hidden": P(b, s, "model"),
        "decode_hidden": P(b, None, "model"),
        "logits": P(b, s, "model"),
    }


def make_constrain(mesh: Mesh, table):
    def fn(x, kind):
        spec = table.get(kind)
        if spec is None:
            return x
        # drop axes that don't divide
        fixed = []
        for dim, axis in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
            if axis is not None and dim % _axis_size(mesh, axis) == 0:
                fixed.append(axis)
            else:
                fixed.append(None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*fixed)))
    return fn
