"""Activation-sharding context.

Model code calls ``constrain(x, kind)`` at a few canonical points ("tokens",
"hidden", "logits", "kv_cache", ...).  Outside a mesh context this is a
no-op, so models stay mesh-agnostic; the launcher installs a rule table
(kind -> PartitionSpec) for the production mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Callable, Optional

_state = threading.local()


def _current() -> Optional[Callable]:
    return getattr(_state, "fn", None)


@contextlib.contextmanager
def activation_sharding(fn: Callable):
    """fn(x, kind) -> x (typically jax.lax.with_sharding_constraint)."""
    prev = _current()
    _state.fn = fn
    try:
        yield
    finally:
        _state.fn = prev


def constrain(x, kind: str):
    fn = _current()
    if fn is None:
        return x
    return fn(x, kind)
