"""Fixed-Cycle Pseudo-Random (FCPR) sampling — the paper's §3.4.

The dataset is permuted ONCE, sliced into n_d/n_b batches, and iteration j
retrieves batch t = j mod (n_d/n_b) — a fixed ring.  Batch identity is
therefore deterministic, which is what gives the ISGD loss queue its
"one window = one epoch" semantics.

``shuffle_quality`` < 1 deliberately under-shuffles the permutation
(paper §3.3 "insufficient shuffling" form of Sampling Bias): only that
fraction of elements participate in the permutation, the rest stay in
class-sorted order.
"""
from __future__ import annotations

from typing import Dict

import numpy as np


class FCPRSampler:
    def __init__(self, arrays: Dict[str, np.ndarray], batch_size: int,
                 seed: int = 0, shuffle_quality: float = 1.0):
        n = len(next(iter(arrays.values())))
        for v in arrays.values():
            assert len(v) == n
        self.n_data = n
        self.batch_size = batch_size
        self.n_batches = n // batch_size
        assert self.n_batches > 0
        rng = np.random.RandomState(seed)
        perm = np.arange(n)
        if shuffle_quality >= 1.0:
            rng.shuffle(perm)
        elif shuffle_quality > 0.0:
            k = int(n * shuffle_quality)
            idx = rng.choice(n, size=k, replace=False)
            sub = perm[idx].copy()
            rng.shuffle(sub)
            perm[idx] = sub
        usable = self.n_batches * batch_size
        self.arrays = {k: np.ascontiguousarray(v[perm[:usable]])
                       for k, v in arrays.items()}

    def batch_index(self, j: int) -> int:
        """t = j mod (n_d / n_b) — the paper's fixed cycle."""
        return j % self.n_batches

    def __call__(self, j: int) -> Dict[str, np.ndarray]:
        t = self.batch_index(j)
        lo, hi = t * self.batch_size, (t + 1) * self.batch_size
        return {k: v[lo:hi] for k, v in self.arrays.items()}


class ExplicitBatches:
    """Pre-built batches cycled in fixed order (for the Fig.1 controlled
    experiments: single-class and i.i.d. batches)."""

    def __init__(self, batches):
        self.batches = list(batches)
        self.n_batches = len(self.batches)
        self.batch_size = len(next(iter(self.batches[0].values())))

    def batch_index(self, j: int) -> int:
        return j % self.n_batches

    def __call__(self, j: int):
        return self.batches[self.batch_index(j)]
