"""Fixed-Cycle Pseudo-Random (FCPR) sampling — the paper's §3.4.

The dataset is permuted ONCE, sliced into n_d/n_b batches, and iteration j
retrieves batch t = j mod (n_d/n_b) — a fixed ring.  Batch identity is
therefore deterministic, which is what gives the ISGD loss queue its
"one window = one epoch" semantics.

``shuffle_quality`` < 1 deliberately under-shuffles the permutation
(paper §3.3 "insufficient shuffling" form of Sampling Bias): only that
fraction of elements participate in the permutation, the rest stay in
class-sorted order.

Zero-copy contract: the permuted epoch is materialised ONCE as C-contiguous
arrays (``np.ascontiguousarray`` in ``__init__``), so every batch
``__call__`` returns is a contiguous leading-axis *view* — no per-batch copy
on the host, and ``jax.device_put`` can transfer it without a staging copy.
``epoch_arrays()`` exposes the whole permuted epoch for consumers that want
to upload it once (the device-resident ring in ``repro.data.device_ring``)
instead of re-slicing per batch.
"""
from __future__ import annotations

import warnings
from typing import Dict

import numpy as np


class FCPRSampler:
    def __init__(self, arrays: Dict[str, np.ndarray], batch_size: int,
                 seed: int = 0, shuffle_quality: float = 1.0):
        n = len(next(iter(arrays.values())))
        for v in arrays.values():
            assert len(v) == n
        self.n_data = n
        self.batch_size = batch_size
        self.n_batches = n // batch_size
        assert self.n_batches > 0
        # the fixed cycle needs whole batches: the n mod batch_size rows
        # past the last full batch never enter the epoch.  Which rows land
        # there is permutation- (i.e. seed-) dependent, so this is sampling
        # noise, not a fixed exclusion — but it is still data silently left
        # on the floor, hence the loud warning.
        self.n_dropped = n - self.n_batches * batch_size
        if self.n_dropped:
            warnings.warn(
                f"FCPRSampler drops {self.n_dropped} of {n} rows "
                f"(n_data mod batch_size != 0); pad the dataset or pick a "
                f"divisor batch size to train on every row", stacklevel=2)
        rng = np.random.RandomState(seed)
        perm = np.arange(n)
        if shuffle_quality >= 1.0:
            rng.shuffle(perm)
        elif shuffle_quality > 0.0:
            k = int(n * shuffle_quality)
            idx = rng.choice(n, size=k, replace=False)
            sub = perm[idx].copy()
            rng.shuffle(sub)
            perm[idx] = sub
        usable = self.n_batches * batch_size
        self.arrays = {k: np.ascontiguousarray(v[perm[:usable]])
                       for k, v in arrays.items()}

    def batch_index(self, j: int) -> int:
        """t = j mod (n_d / n_b) — the paper's fixed cycle."""
        return j % self.n_batches

    def epoch_arrays(self) -> Dict[str, np.ndarray]:
        """The whole permuted epoch (``n_batches * batch_size`` rows per key)
        as C-contiguous arrays; batch t is rows [t*bs, (t+1)*bs).  This is
        the ingestion point for ``DeviceRing`` — one upload, no per-batch
        re-slicing."""
        return self.arrays

    def epoch_nbytes(self) -> int:
        """Host bytes of one permuted epoch (ring byte-budget check)."""
        return sum(v.nbytes for v in self.arrays.values())

    def __call__(self, j: int) -> Dict[str, np.ndarray]:
        """Batch ``t = j mod n_b`` as zero-copy C-contiguous views.

        Leading-axis slices of C-contiguous arrays are themselves
        C-contiguous, so these views feed ``jax.device_put`` directly."""
        t = self.batch_index(j)
        lo, hi = t * self.batch_size, (t + 1) * self.batch_size
        return {k: v[lo:hi] for k, v in self.arrays.items()}


class ExplicitBatches:
    """Pre-built batches cycled in fixed order (for the Fig.1 controlled
    experiments: single-class and i.i.d. batches)."""

    def __init__(self, batches):
        self.batches = list(batches)
        self.n_batches = len(self.batches)
        self.batch_size = len(next(iter(self.batches[0].values())))

    def batch_index(self, j: int) -> int:
        return j % self.n_batches

    def epoch_arrays(self):
        """Concatenated fixed cycle (batch t = rows [t*bs, (t+1)*bs)), so
        ``DeviceRing`` can ingest explicit batches too."""
        keys = self.batches[0].keys()
        return {k: np.ascontiguousarray(
                    np.concatenate([np.asarray(b[k]) for b in self.batches]))
                for k in keys}

    def epoch_nbytes(self) -> int:
        return sum(np.asarray(v).nbytes
                   for b in self.batches for v in b.values())

    def __call__(self, j: int):
        return self.batches[self.batch_index(j)]
