from repro.data.device_ring import DeviceRing, ring_or_prefetch
from repro.data.fcpr import ExplicitBatches, FCPRSampler
from repro.data.synthetic import (
    cifar_like,
    iid_batches,
    imagenet_like,
    make_classification,
    make_lm_tokens,
    mnist_like,
    single_class_batches,
)

__all__ = [
    "FCPRSampler", "ExplicitBatches", "DeviceRing", "ring_or_prefetch",
    "make_classification", "mnist_like",
    "cifar_like", "imagenet_like", "single_class_batches", "iid_batches",
    "make_lm_tokens",
]
