"""Synthetic datasets (no network access in this container).

Image classification: each class c is a Gaussian prototype image; samples are
prototype + noise (+ per-sample deformation), so both of the paper's factors
exist by construction:
  * Sampling Bias — via ``class_skew`` (uneven class frequencies) or
    single-class batches;
  * Intrinsic Image Difference — per-sample noise/deformation makes i.i.d.
    batches differ at the pixel level.

Scales mirror the paper's three regimes: mnist-like (28×28×1, 10 classes),
cifar-like (32×32×3, 10), imagenet-like (64×64×3, 1000 — downscaled).
"""
from __future__ import annotations

import numpy as np


def make_classification(seed: int, n: int, image_size: int, channels: int,
                        num_classes: int, noise: float = 0.35,
                        class_skew: float = 0.0, difficulty: float = 1.0,
                        class_spread: float = 0.0, proto_seed: int = 1234):
    """-> dict(images (n,H,W,C) f32, labels (n,) i32).

    ``class_spread`` > 0 makes later classes intrinsically harder (smaller
    prototype magnitude ⇒ noise-dominated) — the heterogeneity behind the
    paper's Fig.1 batch-wise training variations.

    ``proto_seed`` fixes the class prototypes INDEPENDENTLY of ``seed`` so
    different draws (train/test splits, per-batch draws) share one task."""
    rng = np.random.RandomState(seed)
    prng = np.random.RandomState(proto_seed + 31 * num_classes + image_size)
    protos = prng.randn(num_classes, image_size, image_size, channels).astype(np.float32)
    protos /= np.sqrt(difficulty)
    if class_spread > 0:
        mags = 1.0 / (1.0 + class_spread * np.arange(num_classes)
                      / max(num_classes - 1, 1))
        protos *= mags[:, None, None, None].astype(np.float32)
    if class_skew > 0:
        w = np.exp(-class_skew * np.arange(num_classes))
        w /= w.sum()
        labels = rng.choice(num_classes, size=n, p=w)
    else:
        labels = rng.randint(0, num_classes, size=n)
    imgs = protos[labels] + noise * rng.randn(n, image_size, image_size, channels).astype(np.float32)
    # per-sample brightness/contrast jitter = intrinsic image difference
    gain = (1.0 + 0.2 * rng.randn(n, 1, 1, 1)).astype(np.float32)
    bias = (0.1 * rng.randn(n, 1, 1, 1)).astype(np.float32)
    imgs = imgs * gain + bias
    return {"images": imgs.astype(np.float32), "labels": labels.astype(np.int32)}


def mnist_like(seed=0, n=6000):
    return make_classification(seed, n, 28, 1, 10, noise=0.3)


def cifar_like(seed=0, n=6000):
    return make_classification(seed, n, 32, 3, 10, noise=0.5, difficulty=2.0)


def imagenet_like(seed=0, n=20000):
    return make_classification(seed, n, 64, 3, 1000, noise=0.5, difficulty=2.0)


# ---------------------------------------------------------------------------
# Fig.1 controlled experiments
# ---------------------------------------------------------------------------
def single_class_batches(seed: int, batch_size: int, num_classes: int = 10,
                         image_size: int = 32, channels: int = 3,
                         noise: float = 0.5, class_spread: float = 2.0):
    """One batch per class — maximal Sampling Bias (paper Fig. 1a)."""
    data = []
    for c in range(num_classes):
        rng = np.random.RandomState(seed + c)
        d = make_classification(seed + 1000 + c, batch_size * 4, image_size,
                                channels, num_classes, noise=noise,
                                class_spread=class_spread)
        idx = np.where(d["labels"] == c)[0]
        while len(idx) < batch_size:    # top up with fresh draws of class c
            extra = make_classification(rng.randint(1 << 30), batch_size * 4,
                                        image_size, channels, num_classes,
                                        noise=noise, class_spread=class_spread)
            d = {k: np.concatenate([d[k], extra[k]]) for k in d}
            idx = np.where(d["labels"] == c)[0]
        sel = idx[:batch_size]
        data.append({k: v[sel] for k, v in d.items()})
    return data


def iid_batches(seed: int, n_batches: int, per_class: int,
                num_classes: int = 10, image_size: int = 32, channels: int = 3,
                noise: float = 0.5):
    """n_batches batches, each with exactly ``per_class`` samples of every
    class in the SAME class order (paper Fig. 1b: i.i.d. batches differing
    only at pixels)."""
    out = []
    for b in range(n_batches):
        imgs, labels = [], []
        for c in range(num_classes):
            d = make_classification(seed + 7919 * b + c, per_class * num_classes * 5,
                                    image_size, channels, num_classes, noise=noise)
            idx = np.where(d["labels"] == c)[0][:per_class]
            assert len(idx) == per_class, "raise n in make_classification"
            imgs.append(d["images"][idx])
            labels.append(d["labels"][idx])
        out.append({"images": np.concatenate(imgs),
                    "labels": np.concatenate(labels)})
    return out


# ---------------------------------------------------------------------------
# LM token streams (for transformer smoke/e2e)
# ---------------------------------------------------------------------------
def make_lm_tokens(seed: int, n_seqs: int, seq_len: int, vocab: int,
                   order: int = 2):
    """Markov token stream — learnable structure for e2e LM training."""
    rng = np.random.RandomState(seed)
    # sparse transition table: each context maps to a few likely tokens
    n_ctx = 4096
    table = rng.randint(0, vocab, size=(n_ctx, 4))
    toks = rng.randint(0, vocab, size=(n_seqs, seq_len))
    ctx = rng.randint(0, n_ctx, size=n_seqs)
    for t in range(1, seq_len):
        choice = table[ctx, rng.randint(0, 4, size=n_seqs)]
        mask = rng.rand(n_seqs) < 0.8
        toks[:, t] = np.where(mask, choice, toks[:, t])
        ctx = (ctx * 31 + toks[:, t]) % n_ctx
    return {"tokens": toks.astype(np.int32)}
