"""Device-resident FCPR ring (ROADMAP: "prefetch depth tuning + device-
resident FCPR ring").

FCPR sampling (paper §3.4) makes batch identity a pure function of the step
index — ``t = j mod n_b`` — so the whole permuted epoch can be uploaded to
device ONCE and every batch served as a ``lax.dynamic_slice`` on the ring.
That removes the per-step host→device copy (and the numpy slice feeding it)
from the hot path entirely, which is what lets the chunked trainer
(``repro.train.chunked``) run K steps per host dispatch with zero host
involvement in batch selection.

Two layouts:

  * **unsharded** (``mesh=None``): the epoch lives replicated/on the default
    device; batch t is rows ``[t*bs, (t+1)*bs)``.
  * **sharded** (``mesh`` given): the epoch is re-laid-out so each device's
    contiguous block holds *its* shard of every batch in cycle order —
    ``v.reshape(n_b, n_dev, bs/n_dev, ...)`` transposed to put the device
    axis first — then placed with ``NamedSharding(mesh, P(axes))``.  Inside
    ``shard_map`` a device slices ``[t*bs_local, (t+1)*bs_local)`` of its
    local block and gets exactly the rows the per-step engine's
    ``P(axes)``-sharded global batch would have given it, so ring and
    host-sampler feeds are bit-identical.  The relayout is keyed to the
    data *sub-axes* of the mesh, not its total size: on the hybrid
    engine's 2-D ``(data, model)`` mesh the epoch splits over the data
    sub-axis only and ``P(axes)`` replicates each block across the model
    axis — every model peer of a data shard serves identical rows.  On the
    3-D ``(pod, data, model)`` mesh the leading dim shards over
    ``("pod", "data")`` jointly, in pod-major flat order.

    ``relayout=False`` keeps the **global row order** while still
    distributing the epoch ``P(axes)`` across the mesh — the layout the
    hybrid engine's GSPMD strategy wants: its in-scan ``dynamic_slice``
    picks the *global* batch ``[t*bs, (t+1)*bs)`` and the partitioner
    re-lays it out per the step's constraints (the per-device relayout
    only exists so a *manual* shard_map body can slice its own rows).

**Multi-process striping** (ROADMAP: multi-host scale-out): when ``mesh``
spans several processes, no process holds — or uploads — the whole epoch.
The sampler still permutes the *global* epoch (every process draws the same
permutation from the same seed), but each process materializes only its
stripe: the rows of the flattened data-shard order that land on its own
devices (``repro.launch.mesh.local_data_block``), uploaded via
``jax.make_array_from_process_local_data``.  Because
``make_training_mesh`` keeps each process's devices contiguous in pod-major
flat order, the stripe is one contiguous run of shard blocks, and the union
of all stripes is exactly the single-host permuted epoch — the "one ψ
window = one epoch" invariant survives scale-out, and in-shard_map slices
still equal the single-host ``P("data")`` shards bit-for-bit (pinned by
``repro.distributed.multihost_parity``).

``ring_or_prefetch`` is the configurable-byte-budget front door: epochs
whose **per-replica share** (1/n_dev of the epoch on a sharded ring) fits
``byte_budget`` are promoted to a :class:`DeviceRing`; epochs that don't
fall back to the double-buffered ``PrefetchSampler`` — a per-step
host→device stream instead of one-shot residency.  Under a sharded mesh the
fallback changes the transfer pattern, not the values: batches are still
``P(axes)``-sharded and bit-identical, but every step pays an H2D copy and
the chunked trainer loses its zero-host-involvement property (it needs
``ring.arrays``).  On a **multi-process** mesh the fallback additionally
changes collective behaviour — per-step uploads must be coordinated across
processes every step instead of once per epoch — so the promotion failure
is warned about (once); raise ``byte_budget`` (or pass ``None``) if the
warning appears on a parity-sensitive run.

The ring preserves the sampler protocol (``__call__(j)``, ``n_batches``,
``batch_size``, ``batch_index``), so per-step engines can consume it
unchanged; chunked engines take ``ring.arrays`` directly.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_BYTE_BUDGET = 256 * 1024 * 1024     # 256 MiB of epoch per replica

AxisSpec = Union[str, Tuple[str, ...], None]


def _norm_axes(mesh, axis: AxisSpec) -> tuple:
    """axis -> tuple of mesh axis names (None = the mesh's data sub-axes)."""
    if axis is None:
        from repro.launch.mesh import data_axes
        axes = data_axes(mesh)
        assert axes, f"mesh has no data axes: {tuple(mesh.shape)}"
        return axes
    return (axis,) if isinstance(axis, str) else tuple(axis)


def _is_multiprocess(mesh) -> bool:
    return len({d.process_index for d in mesh.devices.flat}) > 1


def _shard_layout(v: np.ndarray, n_batches: int, n_dev: int,
                  block: Optional[tuple] = None) -> np.ndarray:
    """(n_b*bs, ...) -> rows regrouped so device d's contiguous 1/n_dev
    block is [batch0 shard d, batch1 shard d, ...].  With ``block=(lo,hi)``
    only the blocks of flat shard positions [lo, hi) are materialized —
    this process's stripe of the relayout."""
    bs = v.shape[0] // n_batches
    bsl = bs // n_dev
    lo, hi = block if block is not None else (0, n_dev)
    r = v.reshape(n_batches, n_dev, bsl, *v.shape[1:])[:, lo:hi]
    return np.ascontiguousarray(
        r.swapaxes(0, 1).reshape(n_batches * bsl * (hi - lo), *v.shape[1:]))


class DeviceRing:
    def __init__(self, epoch_arrays: Dict[str, np.ndarray], batch_size: int,
                 *, mesh=None, axis: AxisSpec = "data",
                 relayout: bool = True):
        n = next(iter(epoch_arrays.values())).shape[0]
        for v in epoch_arrays.values():
            assert v.shape[0] == n, "epoch arrays must share the leading dim"
        assert n % batch_size == 0, (n, batch_size)
        self.batch_size = batch_size
        self.n_batches = n // batch_size
        self.mesh = mesh

        if mesh is None:
            self.axis = axis
            self.n_devices = 1
            self.local_batch_size = batch_size
            self.arrays = {k: jax.device_put(np.ascontiguousarray(v))
                           for k, v in epoch_arrays.items()}
            self._slice = jax.jit(self._slice_unsharded)
            return

        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P
        axes = _norm_axes(mesh, axis)
        for a in axes:
            assert a in mesh.shape, \
                f"ring axis {a!r} not in mesh axes {tuple(mesh.shape)}"
        self.axis = axes[0] if len(axes) == 1 else axes
        n_dev = int(np.prod([mesh.shape[a] for a in axes]))
        assert batch_size % n_dev == 0, \
            f"batch {batch_size} not divisible by {n_dev} {axes} devices"
        self.n_devices = n_dev
        self.local_batch_size = batch_size // n_dev
        spec = P(self.axis)
        sh = NamedSharding(mesh, spec)
        multiproc = _is_multiprocess(mesh)
        if multiproc:
            from repro.launch.mesh import local_data_block
            lo, hi, total = local_data_block(mesh, axes)
            assert total == n_dev
            self.local_block = (lo, hi)
        else:
            self.local_block = (0, n_dev)

        if not relayout:
            # global row order, distributed placement (GSPMD consumers)
            if multiproc:
                rows = n // n_dev
                lo, hi = self.local_block
                self.arrays = {
                    k: jax.make_array_from_process_local_data(
                        sh, np.ascontiguousarray(
                            np.asarray(v)[lo * rows:hi * rows]), v.shape)
                    for k, v in epoch_arrays.items()}
            else:
                self.arrays = {
                    k: jax.device_put(np.ascontiguousarray(v), sh)
                    for k, v in epoch_arrays.items()}
            self._slice = jax.jit(self._slice_unsharded)
            return

        if multiproc:
            self.arrays = {
                k: jax.make_array_from_process_local_data(
                    sh, _shard_layout(np.asarray(v), self.n_batches, n_dev,
                                      self.local_block), v.shape)
                for k, v in epoch_arrays.items()}
        else:
            self.arrays = {
                k: jax.device_put(_shard_layout(np.asarray(v),
                                                self.n_batches, n_dev), sh)
                for k, v in epoch_arrays.items()}
        from jax.experimental.shard_map import shard_map
        sliced = shard_map(self._slice_local, mesh=mesh,
                           in_specs=(spec, P()), out_specs=spec,
                           check_rep=False)
        self._slice = jax.jit(sliced)

    # -- slicing --------------------------------------------------------
    def _slice_unsharded(self, arrays, t):
        bs = self.batch_size
        return {k: jax.lax.dynamic_slice_in_dim(v, t * bs, bs)
                for k, v in arrays.items()}

    def _slice_local(self, arrays, t):
        bs = self.local_batch_size
        return {k: jax.lax.dynamic_slice_in_dim(v, t * bs, bs)
                for k, v in arrays.items()}

    # -- sampler protocol ----------------------------------------------
    def batch_index(self, j: int) -> int:
        return j % self.n_batches

    def __call__(self, j: int) -> Dict[str, jax.Array]:
        """Batch ``t = j mod n_b`` as device arrays — on a sharded ring the
        output is the *global* batch laid out like ``batch_sharding`` (leading
        dim over the data axes), directly consumable by the per-step
        engines.  Valid to call from every process of a multi-process mesh
        (the batch index is a python int, identical everywhere by FCPR)."""
        t = self.batch_index(j)
        if self.mesh is not None and _is_multiprocess(self.mesh):
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P
            t = jax.make_array_from_process_local_data(
                NamedSharding(self.mesh, P()),
                np.asarray(t, np.int32), ())
        else:
            t = jnp.asarray(t, jnp.int32)
        return self._slice(self.arrays, t)

    # -- sizing ---------------------------------------------------------
    @property
    def nbytes(self) -> int:
        """Global epoch footprint (all processes' stripes together)."""
        return sum(int(np.prod(v.shape)) * v.dtype.itemsize
                   for v in self.arrays.values())


def ring_or_prefetch(sampler, *, mesh=None, axis: AxisSpec = "data",
                     byte_budget: Optional[int] = DEFAULT_BYTE_BUDGET,
                     prefetch_depth: int = 2, relayout: bool = True):
    """Promote ``sampler``'s permuted epoch to a :class:`DeviceRing` when
    its *per-replica* share fits ``byte_budget`` bytes (``None`` = always
    fits; a sharded ring puts only 1/n_dev of the epoch on each device);
    otherwise fall back to the double-buffered ``PrefetchSampler`` over the
    same sampler, sharded for ``mesh`` if one is given.  Either return
    value satisfies the sampler protocol and yields bit-identical batches.

    Under a sharded mesh the fallback is a *transfer-pattern* change, not a
    values change: instead of one epoch upload and in-device slicing, every
    batch is a fresh host→device copy (double-buffered), and chunked-K
    consumers that need ``ring.arrays`` cannot use it.  On a
    **multi-process** mesh this additionally turns the data feed into a
    per-step cross-process coordination point, so the silent demotion is
    surfaced with a (once-per-process) ``UserWarning`` — raise
    ``byte_budget`` or pass ``byte_budget=None`` to force residency.

    The size check uses ``sampler.epoch_nbytes()`` so an over-budget epoch
    is never materialized just to be discarded."""
    if byte_budget is not None:
        if mesh is not None:
            axes = _norm_axes(mesh, axis)
            n_dev = int(np.prod([mesh.shape[a] for a in axes]))
        else:
            n_dev = 1
        if sampler.epoch_nbytes() > byte_budget * n_dev:
            if mesh is not None and _is_multiprocess(mesh):
                # keyed + coordinator-gated: fires once, on process 0 only
                from repro.obs.console import CONSOLE
                CONSOLE.warn_once(
                    "device_ring.prefetch_fallback",
                    f"epoch ({sampler.epoch_nbytes()} B) exceeds the "
                    f"device-ring byte budget ({byte_budget} B/replica x "
                    f"{n_dev}); falling back to per-step prefetch on a "
                    f"multi-process mesh — the data feed becomes a "
                    f"per-step cross-process upload instead of one "
                    f"resident epoch stripe. Raise byte_budget (or pass "
                    f"None) to keep the ring.")
            from repro.distributed.prefetch import prefetched
            return prefetched(sampler, mesh, axis=axis, depth=prefetch_depth)
    return DeviceRing(sampler.epoch_arrays(), sampler.batch_size,
                      mesh=mesh, axis=axis, relayout=relayout)
