"""Device-resident FCPR ring (ROADMAP: "prefetch depth tuning + device-
resident FCPR ring").

FCPR sampling (paper §3.4) makes batch identity a pure function of the step
index — ``t = j mod n_b`` — so the whole permuted epoch can be uploaded to
device ONCE and every batch served as a ``lax.dynamic_slice`` on the ring.
That removes the per-step host→device copy (and the numpy slice feeding it)
from the hot path entirely, which is what lets the chunked trainer
(``repro.train.chunked``) run K steps per host dispatch with zero host
involvement in batch selection.

Two layouts:

  * **unsharded** (``mesh=None``): the epoch lives replicated/on the default
    device; batch t is rows ``[t*bs, (t+1)*bs)``.
  * **sharded** (``mesh`` given): the epoch is re-laid-out so each device's
    contiguous block holds *its* shard of every batch in cycle order —
    ``v.reshape(n_b, n_dev, bs/n_dev, ...)`` transposed to put the device
    axis first — then placed with ``NamedSharding(mesh, P(axis))``.  Inside
    ``shard_map`` a device slices ``[t*bs_local, (t+1)*bs_local)`` of its
    local block and gets exactly the rows the per-step engine's
    ``P(axis)``-sharded global batch would have given it, so ring and
    host-sampler feeds are bit-identical.  The relayout is keyed to the
    ``axis`` *sub-axis* of the mesh, not its total size: on the hybrid
    engine's 2-D ``(data, model)`` mesh the epoch splits over the data
    sub-axis only and ``P(axis)`` replicates each block across the model
    axis — every model peer of a data shard serves identical rows.

    ``relayout=False`` keeps the **global row order** while still
    distributing the epoch ``P(axis)`` across the mesh — the layout the
    hybrid engine's GSPMD strategy wants: its in-scan ``dynamic_slice``
    picks the *global* batch ``[t*bs, (t+1)*bs)`` and the partitioner
    re-lays it out per the step's constraints (the per-device relayout
    only exists so a *manual* shard_map body can slice its own rows).

``ring_or_prefetch`` is the configurable-byte-budget front door: epochs that
fit are promoted to a ``DeviceRing``; epochs that don't fall back to the
double-buffered ``PrefetchSampler`` (H2D overlap instead of residency).

The ring preserves the sampler protocol (``__call__(j)``, ``n_batches``,
``batch_size``, ``batch_index``), so per-step engines can consume it
unchanged; chunked engines take ``ring.arrays`` directly.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_BYTE_BUDGET = 256 * 1024 * 1024     # 256 MiB of epoch per replica


def _shard_layout(v: np.ndarray, n_batches: int, n_dev: int) -> np.ndarray:
    """(n_b*bs, ...) -> same shape, rows regrouped so device d's contiguous
    1/n_dev block is [batch0 shard d, batch1 shard d, ...]."""
    bs = v.shape[0] // n_batches
    bsl = bs // n_dev
    r = v.reshape(n_batches, n_dev, bsl, *v.shape[1:])
    return np.ascontiguousarray(
        r.swapaxes(0, 1).reshape(n_batches * bs, *v.shape[1:]))


class DeviceRing:
    def __init__(self, epoch_arrays: Dict[str, np.ndarray], batch_size: int,
                 *, mesh=None, axis: str = "data", relayout: bool = True):
        n = next(iter(epoch_arrays.values())).shape[0]
        for v in epoch_arrays.values():
            assert v.shape[0] == n, "epoch arrays must share the leading dim"
        assert n % batch_size == 0, (n, batch_size)
        self.batch_size = batch_size
        self.n_batches = n // batch_size
        self.mesh = mesh
        self.axis = axis

        if mesh is None:
            self.n_devices = 1
            self.local_batch_size = batch_size
            self.arrays = {k: jax.device_put(np.ascontiguousarray(v))
                           for k, v in epoch_arrays.items()}
            self._slice = jax.jit(self._slice_unsharded)
            return

        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P
        assert axis in mesh.shape, \
            f"ring axis {axis!r} not in mesh axes {tuple(mesh.shape)}"
        n_dev = mesh.shape[axis]
        assert batch_size % n_dev == 0, \
            f"batch {batch_size} not divisible by {n_dev} '{axis}' devices"
        self.n_devices = n_dev
        self.local_batch_size = batch_size // n_dev
        sh = NamedSharding(mesh, P(axis))
        if not relayout:
            # global row order, distributed placement (GSPMD consumers)
            self.arrays = {
                k: jax.device_put(np.ascontiguousarray(v), sh)
                for k, v in epoch_arrays.items()}
            self._slice = jax.jit(self._slice_unsharded)
            return
        self.arrays = {
            k: jax.device_put(_shard_layout(np.asarray(v),
                                            self.n_batches, n_dev), sh)
            for k, v in epoch_arrays.items()}
        from jax.experimental.shard_map import shard_map
        sliced = shard_map(self._slice_local, mesh=mesh,
                           in_specs=(P(axis), P()), out_specs=P(axis),
                           check_rep=False)
        self._slice = jax.jit(sliced)

    # -- slicing --------------------------------------------------------
    def _slice_unsharded(self, arrays, t):
        bs = self.batch_size
        return {k: jax.lax.dynamic_slice_in_dim(v, t * bs, bs)
                for k, v in arrays.items()}

    def _slice_local(self, arrays, t):
        bs = self.local_batch_size
        return {k: jax.lax.dynamic_slice_in_dim(v, t * bs, bs)
                for k, v in arrays.items()}

    # -- sampler protocol ----------------------------------------------
    def batch_index(self, j: int) -> int:
        return j % self.n_batches

    def __call__(self, j: int) -> Dict[str, jax.Array]:
        """Batch ``t = j mod n_b`` as device arrays — on a sharded ring the
        output is the *global* batch laid out like ``batch_sharding`` (leading
        dim over ``axis``), directly consumable by the per-step engines."""
        t = jnp.asarray(self.batch_index(j), jnp.int32)
        return self._slice(self.arrays, t)

    # -- sizing ---------------------------------------------------------
    @property
    def nbytes(self) -> int:
        return sum(int(np.prod(v.shape)) * v.dtype.itemsize
                   for v in self.arrays.values())


def ring_or_prefetch(sampler, *, mesh=None, axis: str = "data",
                     byte_budget: Optional[int] = DEFAULT_BYTE_BUDGET,
                     prefetch_depth: int = 2, relayout: bool = True):
    """Promote ``sampler``'s permuted epoch to a :class:`DeviceRing` when
    its *per-replica* share fits ``byte_budget`` bytes (``None`` = always
    fits; a sharded ring puts only 1/n_dev of the epoch on each device);
    otherwise fall back to the double-buffered ``PrefetchSampler`` over the
    same sampler, sharded for ``mesh`` if one is given.  Either return
    value satisfies the sampler protocol and yields bit-identical batches.

    The size check uses ``sampler.epoch_nbytes()`` so an over-budget epoch
    is never materialized just to be discarded."""
    if byte_budget is not None:
        n_dev = mesh.shape[axis] if mesh is not None else 1
        if sampler.epoch_nbytes() > byte_budget * n_dev:
            from repro.distributed.prefetch import prefetched
            return prefetched(sampler, mesh, axis=axis, depth=prefetch_depth)
    return DeviceRing(sampler.epoch_arrays(), sampler.batch_size,
                      mesh=mesh, axis=axis, relayout=relayout)
