"""Crash-consistent checkpointing: pure-numpy .npz of flattened pytrees.

No external deps (orbax etc.) — paths/keys are derived from the tree
structure, so save/restore round-trips any params/opt-state pytree used in
this framework, including the ISGD loss queue (so inconsistent training can
resume with its control limit intact).

On-disk format (one ``.npz`` zip archive per checkpoint):

  * one array member per pytree leaf, keyed by its flattened tree path
    (``'a'/'b'`` for nested dicts, ``[0]`` for sequence entries, ``.field``
    for NamedTuple fields).  bf16 leaves are stored as f32 (npz cannot
    represent bf16); the f32 image is exact, so a bf16 round-trip is
    lossless.
  * a ``__meta__`` JSON member: ``{"format": 2, "keys": [...], "checksum":
    "<crc32 hex over every key/dtype/shape/payload>", "extra": {...}}``.
    ``extra`` is caller JSON (step cursors, server counters, …).  Format-1
    files (no checksum) from older runs still restore.

Crash-consistency guarantee: ``save`` writes to a temp file in the target
directory, fsyncs, then ``os.replace``s it over the final path — on POSIX
the rename is atomic, so a reader (or a restarted run) sees either the
complete previous checkpoint or the complete new one, never a torn write.
A kill *during* the write leaves at worst a stale ``*.tmp-*`` file next to
an intact checkpoint.  ``restore`` verifies the content checksum and the
shape/dtype of every leaf against the caller's template before returning,
raising :class:`CheckpointError` with the offending key rather than a
cryptic numpy error.

``pack_engine_state``/``unpack_engine_state`` define the full-engine
checkpoint every launch runner shares: params, the complete ``ISGDState``
(base-rule state, ψ control queue, iteration/acceleration counters), the
optional ``repro.sched`` policy state, the FCPR step cursor, and — for the
async-PS engine — the server version counter plus the per-worker SSP push
clocks.  Restoring it puts a killed run back onto the uninterrupted
trajectory bit-exactly (``repro.train.resume_parity`` proves it per
engine).
"""
from __future__ import annotations

import json
import os
import re
import time
import zlib
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

FORMAT_VERSION = 2


class CheckpointError(RuntimeError):
    """A checkpoint could not be restored (corrupt, truncated, or it does
    not match the requested template)."""


def _norm_path(path: str) -> str:
    """``np.savez`` silently appends ``.npz`` when the suffix is missing;
    normalizing BOTH directions keeps ``save("ckpt"); restore("ckpt", …)``
    working instead of failing with a confusing FileNotFoundError."""
    return path if path.endswith(".npz") else path + ".npz"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            arr = arr.astype(np.float32)       # npz can't store bf16
        out[key] = arr
    return out, treedef


def _stored_dtype(dtype) -> np.dtype:
    """The dtype a leaf of ``dtype`` is stored as on disk."""
    return np.dtype(np.float32) if dtype == jnp.bfloat16 else np.dtype(dtype)


def _checksum(arrays: dict) -> str:
    """Deterministic crc32 over every key, dtype, shape and payload, in
    sorted key order — cheap enough to run on every save/restore, strong
    enough to catch truncation and bit corruption."""
    crc = 0
    for key in sorted(arrays):
        arr = np.ascontiguousarray(arrays[key])
        head = f"{key}|{arr.dtype.str}|{arr.shape}".encode()
        crc = zlib.crc32(arr.tobytes(), zlib.crc32(head, crc))
    return f"{crc:08x}"


def tree_checksum(tree) -> str:
    """Content checksum of a pytree (used by the async-PS server to reject
    deltas corrupted in transit — see ``repro.distributed.async_ps``)."""
    arrays, _ = _flatten(tree)
    return _checksum(arrays)


def save(path: str, tree, extra: dict | None = None) -> str:
    """Atomically write ``tree`` (+ JSON-able ``extra``) to ``path``.

    Returns the normalized path actually written (``.npz`` appended when
    missing).  See the module docstring for the crash-consistency
    guarantee.
    """
    path = _norm_path(path)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays, _ = _flatten(tree)
    meta = {"format": FORMAT_VERSION, "keys": sorted(arrays.keys()),
            "checksum": _checksum(arrays), "extra": extra or {}}
    tmp = f"{path}.tmp-{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta), **arrays)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)                  # atomic publish
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return path


def _load(path: str):
    """-> (arrays dict fully read into memory, meta dict).  Every failure
    mode maps to a clear :class:`CheckpointError`."""
    path = _norm_path(path)
    if not os.path.exists(path):
        raise CheckpointError(f"no checkpoint at {path!r} (path is "
                              f"normalized to the .npz suffix)")
    try:
        with np.load(path, allow_pickle=False) as data:
            arrays = {k: data[k] for k in data.files if k != "__meta__"}
            meta = (json.loads(str(data["__meta__"]))
                    if "__meta__" in data.files else {})
    except CheckpointError:
        raise
    except Exception as e:   # BadZipFile / ValueError / EOFError / OSError
        raise CheckpointError(
            f"checkpoint {path!r} is truncated or corrupt and cannot be "
            f"read ({type(e).__name__}: {e}); was the writing process "
            f"killed mid-save without the atomic rename?") from e
    if meta.get("checksum"):
        got = _checksum(arrays)
        if got != meta["checksum"]:
            raise CheckpointError(
                f"checkpoint {path!r} failed its content checksum "
                f"(stored {meta['checksum']}, recomputed {got}): the file "
                f"was corrupted after it was written")
    return arrays, meta


def restore(path: str, like):
    """Restore into the structure of ``like`` (a template pytree).

    Every leaf is verified against the template before anything is
    returned: a missing key, shape mismatch or dtype mismatch raises
    :class:`CheckpointError` naming the offending key.  Keys present in the
    file but absent from the template are ignored (forward compatibility).
    """
    arrays, _ = _load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(k) for k in p)
        if key not in arrays:
            have = ", ".join(sorted(arrays)) or "<empty>"
            raise CheckpointError(
                f"checkpoint {_norm_path(path)!r} has no entry for "
                f"{key!r} required by the template (file has: {have})")
        arr = arrays[key]
        leaf_dtype = getattr(leaf, "dtype", None) or np.asarray(leaf).dtype
        want_shape = tuple(np.shape(leaf))
        if tuple(arr.shape) != want_shape:
            raise CheckpointError(
                f"checkpoint entry {key!r} has shape {tuple(arr.shape)} "
                f"but the template expects {want_shape}")
        want_dtype = _stored_dtype(leaf_dtype)
        if arr.dtype != want_dtype:
            raise CheckpointError(
                f"checkpoint entry {key!r} has dtype {arr.dtype} but the "
                f"template expects {want_dtype} (bf16 leaves are stored "
                f"as f32)")
        leaves.append(jnp.asarray(arr, dtype=leaf_dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_extra(path: str) -> dict:
    _, meta = _load(path)
    return meta.get("extra", {})


# -- full-engine checkpoints -------------------------------------------------
class EngineCheckpoint(NamedTuple):
    """One restored full-engine checkpoint (see ``unpack_engine_state``)."""
    params: Any               # weight pytree
    state: Any                # ISGDState: base rule state + ψ queue + counters
    sched_state: Any          # repro.sched policy state, or None
    step: int                 # global step cursor (FCPR: batch = step mod n_b)
    server: Optional[dict]    # async-PS: {"version": int, "pushed": {wid: n}}


def pack_engine_state(*, params, state, step: int, sched_state=None,
                      server: dict | None = None):
    """-> ``(tree, extra)`` covering everything a killed engine needs to
    resume bit-exactly: params, the full ``ISGDState`` (optimizer base, ψ
    control queue, FCPR/iteration counters), the optional sched-policy
    state, the global step cursor, and the async-PS server metadata
    (version counter + per-worker SSP push clocks)."""
    tree = {"params": params, "state": state}
    if sched_state is not None:
        tree["sched_state"] = sched_state
    extra = {"kind": "engine", "step": int(step)}
    if server is not None:
        extra["server"] = {
            "version": int(server["version"]),
            "pushed": {str(w): int(n)
                       for w, n in server.get("pushed", {}).items()},
        }
    return tree, extra


def unpack_engine_state(tree: dict, extra: dict) -> EngineCheckpoint:
    """Inverse of :func:`pack_engine_state` over already-restored pieces."""
    server = extra.get("server")
    if server is not None:
        server = {"version": int(server["version"]),
                  "pushed": {int(w): int(n)
                             for w, n in server.get("pushed", {}).items()}}
    return EngineCheckpoint(params=tree["params"], state=tree["state"],
                            sched_state=tree.get("sched_state"),
                            step=int(extra["step"]), server=server)


def save_engine(path: str, *, params, state, step: int, sched_state=None,
                server: dict | None = None) -> str:
    tree, extra = pack_engine_state(params=params, state=state, step=step,
                                    sched_state=sched_state, server=server)
    return save(path, tree, extra=extra)


def restore_engine(path: str, *, params_like, state_like,
                   sched_like=None, recorder=None) -> EngineCheckpoint:
    """Restore a full-engine checkpoint against templates (the freshly
    initialized params/state/sched pytrees of the resuming run)."""
    like = {"params": params_like, "state": state_like}
    if sched_like is not None:
        like["sched_state"] = sched_like
    extra = load_extra(path)
    if extra.get("kind") != "engine":
        raise CheckpointError(
            f"{_norm_path(path)!r} is not a full-engine checkpoint "
            f"(extra: {extra!r}); use restore() for plain pytrees")
    t0 = time.perf_counter()
    tree = restore(path, like)
    ckpt = unpack_engine_state(tree, extra)
    if recorder is not None:
        recorder.event("checkpoint.restore", step=ckpt.step,
                       path=_norm_path(path),
                       seconds=time.perf_counter() - t0,
                       bytes=os.path.getsize(_norm_path(path)))
    return ckpt


_CKPT_RE = re.compile(r"^ckpt_(\d+)\.npz$")


class Checkpointer:
    """Periodic engine checkpoints in a directory (``ckpt_<step>.npz``).

    ``maybe_save(step, …)`` writes whenever the run crosses an ``every``
    boundary since the last save — chunked engines call it at chunk
    boundaries, so with ``every`` not a multiple of the chunk size the save
    lands on the first boundary past the mark.  ``latest()`` finds the
    newest complete checkpoint for ``--resume`` (atomic saves guarantee any
    file it finds is complete).

    ``pointer=True`` additionally publishes a ``LATEST`` pointer file
    (atomic replace) naming the newest checkpoint after every save — the
    publish-directory protocol a serving ``SnapshotWatcher`` polls
    (``repro.serve.snapshot``): pruning keeps the ``keep`` newest files, so
    the pointed-to checkpoint always survives.

    **Multi-process runs: process-0-writes / all-validate.**  Params and
    ISGD state are replicated across processes (``repro.distributed``), so
    N processes writing N identical files — or worse, racing the atomic
    rename on a shared filesystem — would be pure waste.  ``role`` picks
    the behaviour: ``"write"`` (the default on process 0 and on any
    single-process run) does everything above; ``"validate"`` (the default
    elsewhere) never touches the directory but, at every save point,
    checksums *its own replica* of the engine state, barriers on the
    writer (``multihost_utils.sync_global_devices``), and verifies the
    written file's content checksum matches — a replica that silently
    diverged fails loudly at the next checkpoint instead of poisoning a
    later ``--resume``.  The save cadence predicate is a pure function of
    (step, every, last-save), so every process reaches the barrier at the
    same save points.  Validation assumes the writer's directory is
    visible (same machine or shared FS); ``--resume`` restores on every
    process from the same file, re-verifying the checksum per process.
    """

    def __init__(self, directory: str, every: int = 0, keep: int = 3,
                 pointer: bool = False, role: Optional[str] = None,
                 recorder=None):
        if role is None:
            try:
                role = "write" if jax.process_index() == 0 else "validate"
            except Exception:        # backend not initialized: single proc
                role = "write"
        assert role in ("write", "validate"), role
        self.directory = directory
        self.every = every
        self.keep = keep
        self.pointer = pointer
        self.role = role
        self.recorder = recorder   # obs: save/restore events, write role only
        self._last = 0
        if role == "write":
            os.makedirs(directory, exist_ok=True)

    @staticmethod
    def _nprocs() -> int:
        try:
            return jax.process_count()
        except Exception:
            return 1

    def _barrier(self, step: int) -> None:
        if self._nprocs() > 1:
            from jax.experimental import multihost_utils
            multihost_utils.sync_global_devices(f"ckpt_{step}")

    def path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:08d}.npz")

    def mark(self, step: int) -> None:
        """Tell the checkpointer a resumed run starts at ``step`` so
        ``maybe_save`` measures boundaries from there."""
        self._last = int(step)

    def save(self, step: int, **engine_kwargs) -> str:
        tree, extra = pack_engine_state(step=step, **engine_kwargs)
        out = self.path(step)
        self._last = int(step)
        if self.role == "write":
            t0 = time.perf_counter()
            out = save(out, tree, extra=extra)
            if self.recorder is not None:
                self.recorder.counter("checkpoint/saves")
                self.recorder.event("checkpoint.save", step=int(step),
                                    path=out, seconds=time.perf_counter() - t0,
                                    bytes=os.path.getsize(out))
            self._barrier(step)                # validators read after this
            if self.pointer:
                from repro.serve.snapshot import publish_pointer
                publish_pointer(self.directory, out)
            self._prune()
            return out
        # validate: checksum THIS replica, then verify the written file
        local = _checksum(_flatten(tree)[0])
        self._barrier(step)                    # writer's atomic publish done
        _, meta = _load(out)
        if meta.get("checksum") != local:
            raise CheckpointError(
                f"process replica diverged at step {step}: local engine "
                f"state checksums {local} but the written checkpoint "
                f"{out!r} has {meta.get('checksum')} — replicated "
                f"params/state are no longer identical across processes")
        return _norm_path(out)

    def maybe_save(self, step: int, **engine_kwargs) -> Optional[str]:
        if not self.every or int(step) // self.every <= self._last // self.every:
            return None
        return self.save(step, **engine_kwargs)

    def steps(self) -> list[int]:
        try:
            names = os.listdir(self.directory)
        except FileNotFoundError:
            return []
        return sorted(int(m.group(1)) for n in names
                      if (m := _CKPT_RE.match(n)))

    def latest(self) -> Optional[str]:
        steps = self.steps()
        return self.path(steps[-1]) if steps else None

    def _prune(self) -> None:
        if not self.keep:
            return                             # keep=0: never delete
        for s in self.steps()[:-self.keep]:
            try:
                os.remove(self.path(s))
            except OSError:
                pass
