"""Checkpointing: pure-numpy .npz of a flattened pytree + ISGD control state.

No external deps (orbax etc.) — paths/keys are derived from the tree
structure, so save/restore round-trips any params/opt-state pytree used in
this framework, including the ISGD loss queue (so inconsistent training can
resume with its control limit intact).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            arr = arr.astype(np.float32)       # npz can't store bf16
        out[key] = arr
    return out, treedef


def save(path: str, tree, extra: dict | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays, _ = _flatten(tree)
    meta = {"keys": sorted(arrays.keys()), "extra": extra or {}}
    np.savez(path, __meta__=json.dumps(meta), **arrays)


def restore(path: str, like):
    """Restore into the structure of ``like`` (a template pytree)."""
    data = np.load(path, allow_pickle=False)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(k) for k in p)
        arr = data[key]
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_extra(path: str) -> dict:
    data = np.load(path, allow_pickle=False)
    return json.loads(str(data["__meta__"]))["extra"]
