"""Training loop: wires model loss, base rule, ISGD controller, loss-driven
LR schedule and the FCPR data pipeline together.

``make_train_step`` builds the jitted step used both by the CPU reproduction
benchmarks and (under pjit, via launch/train.py) the production mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp  # noqa: F401  (jnp.copy used below)

from repro.core import ISGDConfig, consistent_step, isgd_init, isgd_step
from repro.core.reduce import LOCAL, ReduceCtx
from repro.core.schedule import constant_lr
from repro.optim.base import UpdateRule


def make_loss_and_grad(loss_fn: Callable, micro_batches: int = 1):
    """loss_fn(params, batch) -> (total_loss, data_loss) ⇒
    ((loss, aux), grads) with grads of total_loss.

    ``micro_batches`` > 1 splits the global batch and accumulates gradients
    in f32 over a lax.scan — the standard memory lever: activation temp
    scales with the micro-batch, not the global batch (§Perf memory term).

    The loss/aux scalars are upcast to f32 HERE, before anything reads
    them: ψ feeds the SPC queue (EMA/variance), the control limit and the
    loss-driven ``lr_fn``, all of which are f32 by contract.  A bf16 ψ
    entering the queue would survive ``control.push``'s dtype cast with its
    precision already gone — the rounded variance widens the control limit
    and silently suppresses accelerate (tests/test_precision.py pins this).
    """
    vag = jax.value_and_grad(loss_fn, has_aux=True)

    if micro_batches <= 1:
        def lg(params, batch):
            (loss, aux), grads = vag(params, batch)
            return (jnp.asarray(loss, jnp.float32),
                    jnp.asarray(aux, jnp.float32)), grads
        return lg

    def lg(params, batch):
        m = micro_batches

        def split(x):
            assert x.shape[0] % m == 0, (x.shape, m)
            return x.reshape(m, x.shape[0] // m, *x.shape[1:])

        micro = jax.tree.map(split, batch)
        g0 = jax.tree.map(lambda w: jnp.zeros(w.shape, jnp.float32), params)

        def body(carry, mb):
            loss_acc, aux_acc, g_acc = carry
            (l, a), g = vag(params, mb)
            g_acc = jax.tree.map(lambda acc, gi: acc + gi.astype(jnp.float32),
                                 g_acc, g)
            return (loss_acc + jnp.asarray(l, jnp.float32),
                    aux_acc + jnp.asarray(a, jnp.float32), g_acc), None

        from repro.analysis.mode import scan_unroll
        (loss, aux, grads), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32), g0),
            micro, unroll=scan_unroll())
        inv = 1.0 / m
        grads = jax.tree.map(lambda g: g * inv, grads)
        return (loss * inv, aux * inv), grads

    return lg


def make_step_core(loss_fn: Callable, rule: UpdateRule, isgd_cfg: ISGDConfig,
                   *, inconsistent: bool = True, lr_fn: Callable = None,
                   reduce_ctx: ReduceCtx = LOCAL, micro_batches: int = 1):
    """Un-jitted ``(init_fn, step_fn)`` — the traceable heart shared by the
    jitted per-step engine (``make_train_step``) and the fused multi-step
    scan engine (``repro.train.chunked``), so both run literally the same
    step computation.

    When ``lr`` is not passed explicitly, ``lr_fn`` reads ψ̄ from the queue
    *before* this step's loss is pushed — i.e. the LR is driven by the
    previous step's statistics (Alg.1 line 19).  The chunked engine relies
    on this one-step lag being inside the step, not the host loop, for its
    bit-exact parity.

    ``step_fn(state, params, batch, lr=None, slot=None)``: ``slot`` routes
    the SPC queue write — ``None`` keeps the FIFO push; a traced batch
    index writes the per-batch loss table instead (non-FCPR schedules,
    see ``repro.sched``).
    """
    lg = make_loss_and_grad(loss_fn, micro_batches)

    def init_fn(params):
        return isgd_init(rule, isgd_cfg, params)

    def step_fn(state, params, batch, lr=None, slot=None):
        if lr is None:
            from repro.core import control as C
            lr = lr_fn(C.mean(state.queue))
        if inconsistent:
            return isgd_step(rule, isgd_cfg, lg, state, params, batch, lr,
                             reduce_ctx=reduce_ctx, slot=slot)
        return consistent_step(rule, lg, state, params, batch, lr,
                               reduce_ctx=reduce_ctx, slot=slot)

    return init_fn, step_fn


def make_train_step(loss_fn: Callable, rule: UpdateRule, isgd_cfg: ISGDConfig,
                    *, inconsistent: bool = True, lr_fn: Callable = None,
                    donate: bool = True, reduce_ctx: ReduceCtx = LOCAL):
    """Returns (init_fn, step_fn).

    step_fn(state, params, batch, lr_override=None) ->
        (state, params, metrics).  If ``lr_fn`` is given, the LR is derived
    from the running average loss ψ̄ (the paper's loss-driven schedule);
    otherwise pass lr explicitly.

    ``reduce_ctx`` is the pluggable ψ/gradient reduction (core/reduce.py).
    A non-local context only makes sense when step_fn runs inside a scope
    binding its axis — the supported wrapper is
    ``repro.distributed.make_data_parallel_step``, which shares this
    (init_fn, step_fn) contract.
    """
    init_fn, step_fn = make_step_core(
        loss_fn, rule, isgd_cfg, inconsistent=inconsistent, lr_fn=lr_fn,
        reduce_ctx=reduce_ctx)
    jit_kwargs = dict(donate_argnums=(0, 1)) if donate else {}
    return init_fn, jax.jit(step_fn, **jit_kwargs)


def make_scheduled_train_step(loss_fn: Callable, rule: UpdateRule,
                              isgd_cfg: ISGDConfig, schedule, *,
                              inconsistent: bool = True,
                              lr_fn: Callable = None, donate: bool = True,
                              reduce_ctx: ReduceCtx = LOCAL,
                              micro_batches: int = 1, sched_seed: int = 0):
    """Per-step engine with on-device batch *selection* (``repro.sched``).

    Returns ``(init_fn, step_fn)`` where ``step_fn(state, params,
    sched_state, ring_arrays, j) -> (state, params, sched_state, metrics)``
    — the batch for step ``j`` is drawn by ``schedule`` inside the jit and
    fetched as a ``dynamic_slice`` of the ring arrays (a ``DeviceRing``'s
    ``.arrays``), so non-FCPR policies never round-trip the loss table
    through the host.  ``sched_state`` starts as
    ``schedule.init(isgd_cfg.n_batches)``.  ``lr_fn`` is required: the LR
    must be derived on device (selection already is).  With
    ``FCPRSchedule`` this engine is bit-exact with ``make_train_step`` fed
    by the host sampler (``repro.sched.parity`` pins it).
    """
    assert lr_fn is not None, "scheduled engine needs lr_fn (device-side LR)"
    from repro.sched.engine import make_scheduled_body
    init_fn, step_fn = make_step_core(
        loss_fn, rule, isgd_cfg, inconsistent=inconsistent, lr_fn=lr_fn,
        reduce_ctx=reduce_ctx, micro_batches=micro_batches)
    body = make_scheduled_body(step_fn, schedule, isgd_cfg.n_batches,
                               sched_seed)
    jit_kwargs = dict(donate_argnums=(0, 1, 2)) if donate else {}
    return init_fn, jax.jit(body, **jit_kwargs)


@dataclass
class TrainLog:
    """Per-step training record.

    ``wall[i]`` is a cumulative host timestamp (seconds since the run's
    t0); its consecutive deltas are true per-step durations only when
    ``wall_est[i]`` is False.  Entries marked True are *estimates* — the
    chunk-end time of a fused dispatch (``extend``), the dispatch time of
    an un-synced step (``train(step_sync=False)``), or overlapping
    async-worker pushes — and must not feed timing fits
    (``benchmarks/fig8_batch_size.py`` refuses them).
    """

    losses: list = field(default_factory=list)
    limits: list = field(default_factory=list)
    psi_bar: list = field(default_factory=list)
    psi_std: list = field(default_factory=list)
    accelerated: list = field(default_factory=list)
    sub_iters: list = field(default_factory=list)
    wall: list = field(default_factory=list)
    wall_est: list = field(default_factory=list)   # True = estimated wall

    def append(self, metrics: Dict[str, Any], wall: float, *,
               wall_estimated: bool = False):
        self.losses.append(float(metrics["loss"]))
        self.limits.append(float(metrics["limit"]))
        self.psi_bar.append(float(metrics["psi_bar"]))
        self.psi_std.append(float(metrics["psi_std"]))
        self.accelerated.append(bool(metrics["accelerated"]))
        self.sub_iters.append(int(metrics["sub_iters"]))
        self.wall.append(wall)
        self.wall_est.append(bool(wall_estimated))

    def extend(self, stacked: Dict[str, Any], wall: float):
        """Ingest one chunk of the fused engine: ``stacked`` holds (K,)
        leading-dim metric arrays from the on-device ``lax.scan``, fetched in
        ONE host transfer here (the only sync per chunk).  All K steps get
        the chunk-end ``wall`` — the host has no per-step timestamps inside
        a fused dispatch, and pretending otherwise would fabricate data — so
        every entry is marked ``wall_est=True``."""
        import numpy as np
        host = {k: np.asarray(v) for k, v in stacked.items()
                if k != "aux"}
        for i in range(len(host["loss"])):
            self.append({k: v[i] for k, v in host.items()}, wall,
                        wall_estimated=True)


def train(params, loss_fn, rule, sampler, *, steps: int, lr=0.01,
          inconsistent: bool = True, isgd_cfg: Optional[ISGDConfig] = None,
          lr_fn: Callable = None, log_every: int = 0,
          eval_fn: Callable = None, eval_every: int = 0,
          step_sync: bool = False, observer=None):
    """Simple host loop over FCPR batches (CPU reproduction path).

    Metrics are device scalars; converting them to python floats blocks, so
    the loop defers that to log/eval boundaries (and once at the end) rather
    than serializing host and device every step — steps in between are
    dispatched back-to-back and XLA's async runtime pipelines them.  The
    recorded ``wall`` for a deferred step is its *dispatch* time; the flush
    boundary is where the host actually observes completion.  Timing studies
    that need true per-step wall deltas (benchmarks/fig8_batch_size.py's
    Eq.21 fit) must pass ``step_sync=True`` to restore the per-step barrier.

    ``observer`` (a ``repro.obs.TrainObserver``) rides the same boundary
    discipline: deferred per step, ingested only at flushes.
    """
    if isgd_cfg is None:
        isgd_cfg = ISGDConfig(n_batches=sampler.n_batches)
    if lr_fn is None:
        lr_fn = constant_lr(lr)
    init_fn, step_fn = make_train_step(loss_fn, rule, isgd_cfg,
                                       inconsistent=inconsistent, lr_fn=lr_fn)
    params = jax.tree.map(jnp.copy, params)   # step donates its inputs
    state = init_fn(params)
    log = TrainLog()
    evals = []
    pending = []                              # un-synced (step, metrics, wall)
    t0 = time.perf_counter()

    def flush():
        for j, m, w in pending:
            # un-synced walls are dispatch times, not completion times —
            # record them as estimates so timing fits can refuse them
            log.append(m, w, wall_estimated=not step_sync)
            if observer is not None:
                observer.defer(j, m)
        pending.clear()
        if observer is not None:
            observer.flush()

    for j in range(steps):
        batch = sampler(j)
        state, params, metrics = step_fn(state, params, batch)
        if step_sync:
            jax.block_until_ready(metrics["loss"])
        pending.append((j, metrics, time.perf_counter() - t0))
        if log_every and (j + 1) % log_every == 0:
            flush()
            print(f"  step {j+1:5d} loss={log.losses[-1]:.4f} "
                  f"psi_bar={log.psi_bar[-1]:.4f} limit={log.limits[-1]:.4f} "
                  f"accel={log.accelerated[-1]}")
        if eval_fn and eval_every and (j + 1) % eval_every == 0:
            flush()
            evals.append((j + 1, time.perf_counter() - t0, eval_fn(params)))
    flush()
    return params, state, log, evals
