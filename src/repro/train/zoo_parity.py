"""Zoo parity matrix: the fused engines re-proven on non-CNN step bodies.

Every engine-parity guarantee the repo makes was first established on CNNs
and toy regressions (``distributed/parity.py``, ``hybrid_parity.py``).
ISSUE 6 puts transformer / MoE / SSM step bodies through the same engines;
this module re-proves the guarantees there:

  * **fcpr bit-exactness** — per-step ``make_train_step`` vs the fused
    chunked scan ``make_chunked_train_step`` at K ∈ {1, K} must produce
    bit-identical parameters, metrics and acceleration counts on the
    ``paper-transformer-tiny`` body (and K on the MoE / SSM bodies).
  * **ψ̄-lagged lr_fn** — every leg drives a ψ̄-dependent ``lr_fn``; a
    control leg re-runs the reference frozen at ``lr_fn(0.0)`` and asserts
    the trajectory *differs*, proving the matrix can catch a dropped
    schedule (the ISSUE 4 regression) on a transformer body too.
  * **sched composition** — the same chunked leg run through the
    ``repro.sched`` FCPR policy (on-device batch selection) stays
    bit-exact with the hard-wired ring walk.
  * **hybrid engine** — per-step vs chunked ``make_chunked_hybrid_step``
    on a (n, 1) data mesh, bit-exact (runs at any device count; the CI
    matrix exercises 1 and 8).
  * **kernel parity** — the ``--kernels interpret`` build (Pallas kernels
    in interpret mode) matches the reference build's loss and gradients
    within the per-kernel tolerances of ``repro.kernels.numerics``.

Data is a skewed FCPR epoch — batch 0 is uniform-random tokens (hard),
the rest are short repeated n-grams (easy) — so the ISGD subproblem
actually fires and the acceleration path is part of every comparison.

Usable two ways (same pattern as ``distributed/hybrid_parity.py``):

  * in-process: ``run_zoo_parity()`` on whatever devices exist;
  * subprocess with a forced device count (the CI acceptance check):

      PYTHONPATH=src python -m repro.train.zoo_parity --devices 8
"""
from __future__ import annotations

import argparse
import os
import sys


def _reexec_with_devices(n: int, argv: list) -> int:
    """Re-run this module in a child with the device count forced.

    ``repro.train`` imports jax at package-import time, so by the time
    ``main`` parses ``--devices`` the XLA backend is already initialised
    in this process — a subprocess with XLA_FLAGS set is the only way to
    honour the flag (``hybrid_parity`` gets away with an in-process env
    mutation only because ``repro.distributed`` imports lazily)."""
    import subprocess

    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip())
    cmd = [sys.executable, "-m", "repro.train.zoo_parity", *argv]
    return subprocess.call(cmd, env=env)


def run_zoo_parity(steps: int = 32, K: int = 32, verbose: bool = False,
                   models: tuple = ("transformer", "moe", "ssm")) -> dict:
    """Returns {"ok": bool, "devices": int, "legs": {name: report}, ...}."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import zoo_config
    from repro.core import ISGDConfig
    from repro.data import DeviceRing, FCPRSampler
    from repro.distributed.data_parallel import (make_chunked_hybrid_step,
                                                 make_hybrid_step)
    from repro.kernels.numerics import TOLERANCES
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.optim import momentum
    from repro.sched import FCPRSchedule
    from repro.train import make_chunked_train_step, make_train_step

    n_dev = len(jax.devices())
    n_batches, batch, seq = 4, 8, 64
    assert steps % K == 0 and steps >= 2 * n_batches, (steps, K, n_batches)
    assert batch % n_dev == 0, f"batch {batch} not divisible over {n_dev}"

    rule = momentum(0.9)
    icfg = ISGDConfig(n_batches=n_batches, k_sigma=1.0, stop=3, zeta=0.01)

    def lr_fn(psi_bar):
        # ψ̄-dependent on purpose: freezing ψ̄=0 shifts the whole trajectory
        return jnp.asarray(0.05) + 0.005 * jnp.minimum(psi_bar, 1.0)

    def skewed_epoch(vocab, rng):
        """Batch 0 uniform-random (hard), rest repeated 4-grams (easy)."""
        hard = rng.randint(0, vocab, size=(batch, seq))
        base = rng.randint(0, vocab, size=(3, 4))
        easy = np.stack([np.tile(base[i % 3], (batch, seq // 4))
                         for i in range(n_batches - 1)])
        return np.concatenate([hard[None], easy], 0) \
                 .reshape(-1, seq).astype(np.int32)

    def compare(ref, got, exact, tol=0.0):
        """(ok, max_param_dev) for (state, params, metrics) triples."""
        r_s, r_p, r_m = ref
        g_s, g_p, g_m = got
        dev = max(float(np.max(np.abs(np.asarray(a, np.float32)
                                      - np.asarray(b, np.float32))))
                  for a, b in zip(jax.tree.leaves(r_p), jax.tree.leaves(g_p)))
        ok = True
        for key in ("loss", "limit", "psi_bar", "accelerated", "sub_iters"):
            a, b = r_m[key], g_m[key]
            if exact:
                ok &= bool(np.array_equal(a, b))
            else:
                finite = np.isfinite(a) & np.isfinite(b)
                ok &= bool(np.allclose(a[finite], b[finite],
                                       atol=tol, rtol=tol))
        ok &= (dev == 0.0) if exact else (dev <= tol)
        ok &= int(r_s.accel_count) == int(g_s.accel_count)
        return ok, dev

    legs = {}
    accels = {}
    rng = np.random.RandomState(0)

    for name in models:
        cfg = zoo_config(name, "tiny")
        model = build_model(cfg)                     # reference kernels, bf16
        params0 = model.init(jax.random.PRNGKey(0), max_seq=seq)
        toks = skewed_epoch(cfg.vocab_size, rng)
        sampler = FCPRSampler({"tokens": toks}, batch_size=batch, seed=1)
        host = [{k: jnp.asarray(v) for k, v in sampler(j).items()}
                for j in range(steps)]

        def drive(step_fn, init_fn, feed=lambda j: host[j]):
            p = jax.tree.map(jnp.copy, params0)
            s = init_fn(p)
            ms = []
            for j in range(steps):
                s, p, m = step_fn(s, p, feed(j))
                ms.append(jax.tree.map(np.asarray, m))
            return s, p, {k: np.stack([m[k] for m in ms]) for k in ms[0]}

        def drive_chunked(chunk_fn, init_fn, ring, k):
            p = jax.tree.map(jnp.copy, params0)
            s = init_fn(p)
            outs = []
            for c in range(steps // k):
                s, p, ms = chunk_fn(s, p, ring.arrays, c * k)
                outs.append(jax.tree.map(np.asarray, ms))
            return s, p, {key: np.concatenate([o[key] for o in outs])
                          for key in outs[0]}

        # reference: the per-step engine
        init_fn, step = make_train_step(model.loss_fn, rule, icfg,
                                        lr_fn=lr_fn, donate=False)
        ref = drive(step, init_fn)
        accels[name] = int(ref[2]["accelerated"].sum())

        ring = DeviceRing(sampler.epoch_arrays(), batch)
        Ks = (1, K) if name == "transformer" else (K,)
        for k in Ks:
            cinit, chunk = make_chunked_train_step(
                model.loss_fn, rule, icfg, chunk_steps=k, lr_fn=lr_fn,
                donate=False)
            got = drive_chunked(chunk, cinit, ring, k)
            ok, dev = compare(ref, got, exact=True)
            legs[f"{name}:chunked-K{k}"] = {"ok": ok, "max_param": dev}

        if name != "transformer":
            continue

        # control: LR frozen at lr_fn(0.0) must DIFFER, or this matrix
        # could not catch a dropped ψ̄ schedule on a transformer body
        finit, fstep = make_train_step(model.loss_fn, rule, icfg,
                                       lr_fn=lambda _: lr_fn(0.0),
                                       donate=False)
        frozen = drive(fstep, finit)
        differs = any(
            not np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(ref[1]),
                            jax.tree.leaves(frozen[1])))
        legs["transformer:frozen-lr-differs"] = {"ok": differs,
                                                 "max_param": None}

        # sched composition: FCPR policy inside the scan, bit-exact
        fcpr = FCPRSchedule()
        cinit, chunk = make_chunked_train_step(
            model.loss_fn, rule, icfg, chunk_steps=K, lr_fn=lr_fn,
            donate=False, schedule=fcpr)
        p = jax.tree.map(jnp.copy, params0)
        s = cinit(p)
        ss = fcpr.init(n_batches)
        outs = []
        for c in range(steps // K):
            s, p, ss, ms = chunk(s, p, ss, ring.arrays, c * K)
            outs.append(jax.tree.map(np.asarray, ms))
        got = (s, p, {key: np.concatenate([o[key] for o in outs])
                      for key in outs[0]})
        ok, dev = compare(ref, got, exact=True)
        legs[f"transformer:sched-fcpr-K{K}"] = {"ok": ok, "max_param": dev}

        # hybrid engine on a (n, 1) data mesh: per-step vs fused chunked
        mesh = make_host_mesh(model=1)
        hinit, hstep = make_hybrid_step(model.loss_fn, rule, icfg, mesh,
                                        lr_fn=lr_fn, donate=False)
        hy = drive(hstep, hinit)
        ring_m = DeviceRing(sampler.epoch_arrays(), batch, mesh=mesh)
        cinit, chunk = make_chunked_hybrid_step(
            model.loss_fn, rule, icfg, mesh, chunk_steps=K, lr_fn=lr_fn,
            donate=False)
        got = drive_chunked(chunk, cinit, ring_m, K)
        ok, dev = compare(hy, got, exact=True)
        legs[f"transformer:hybrid(n,1)-chunked-K{K}"] = {"ok": ok,
                                                        "max_param": dev}

    # kernel parity: the interpret build (real Pallas kernels, interpreter
    # backend) vs the reference build — loss and grads within the numerics
    # gate's f32 tolerances (grads get 10x headroom: they accumulate over
    # the depth of the body).  f32 params on purpose: bf16 grads quantize
    # at ~3e-3 ulp and would swamp the kernel deviation being measured
    # (the numerics gate sweeps bf16 per-kernel separately).
    kernels_by_model = {"transformer": ("flash_attention", "fused_xent"),
                        "moe": ("flash_attention", "fused_xent"),
                        "ssm": ("ssd_scan", "fused_xent")}
    for name in models:
        cfg = zoo_config(name, "tiny")
        ref_m = build_model(cfg, param_dtype=jnp.float32)
        int_m = build_model(cfg, kernels="interpret",
                            param_dtype=jnp.float32)
        params = ref_m.init(jax.random.PRNGKey(0), max_seq=seq)
        toks = skewed_epoch(cfg.vocab_size, np.random.RandomState(7))
        b = {"tokens": jnp.asarray(toks[:2])}
        (l_r, _), g_r = jax.value_and_grad(ref_m.loss_fn,
                                           has_aux=True)(params, b)
        (l_i, _), g_i = jax.value_and_grad(int_m.loss_fn,
                                           has_aux=True)(params, b)
        tol = max(TOLERANCES[k]["float32"][0]
                  for k in kernels_by_model[name])
        l_dev = float(np.abs(np.asarray(l_r) - np.asarray(l_i)))
        g_dev = max(float(np.max(np.abs(np.asarray(a, np.float32)
                                        - np.asarray(b_, np.float32))))
                    for a, b_ in zip(jax.tree.leaves(g_r),
                                     jax.tree.leaves(g_i)))
        legs[f"{name}:kernels-interpret-vs-ref"] = {
            "ok": l_dev <= tol and g_dev <= 10 * tol,
            "max_param": g_dev, "loss_dev": l_dev, "tol": tol}

    ok = all(leg["ok"] for leg in legs.values())
    if verbose:
        for name, leg in legs.items():
            print(f"  {name:38s} ok={leg['ok']} "
                  f"max_param={leg['max_param']}")
    return {"ok": ok, "devices": n_dev, "steps": steps, "K": K,
            "accelerations": accels, "legs": legs}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=0,
                    help="force this many XLA host-platform devices "
                         "(0 = use whatever XLA_FLAGS already provides)")
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--chunk-steps", type=int, default=32)
    ap.add_argument("--models", default="transformer,moe,ssm",
                    help="comma-separated subset of the zoo")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    if args.devices:
        return _reexec_with_devices(args.devices, [
            "--steps", str(args.steps),
            "--chunk-steps", str(args.chunk_steps),
            "--models", args.models,
            *(["--verbose"] if args.verbose else [])])
    r = run_zoo_parity(steps=args.steps, K=args.chunk_steps,
                       verbose=args.verbose,
                       models=tuple(args.models.split(",")))
    bad = [n for n, leg in r["legs"].items() if not leg["ok"]]
    print(f"zoo-parity devices={r['devices']} steps={r['steps']} "
          f"K={r['K']} accelerations={r['accelerations']} "
          f"legs={len(r['legs'])} failed={bad or 'none'} -> "
          f"{'OK' if r['ok'] else 'FAIL'}")
    if r["accelerations"].get("transformer", 1) == 0:
        print("zoo-parity WARNING: subproblem never fired on transformer")
        return 2
    return 0 if r["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
