from repro.train import checkpoints
from repro.train.trainer import TrainLog, make_loss_and_grad, make_train_step, train

__all__ = ["make_train_step", "make_loss_and_grad", "train", "TrainLog",
           "checkpoints"]
