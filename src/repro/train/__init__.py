from repro.train import checkpoints
from repro.train.checkpoints import Checkpointer
from repro.train.chunked import chunk_over_ring, make_chunked_train_step
from repro.train.resume_parity import run_resume_parity
from repro.train.trainer import (TrainLog, make_loss_and_grad,
                                 make_scheduled_train_step, make_step_core,
                                 make_train_step, train)

__all__ = ["make_train_step", "make_step_core", "make_chunked_train_step",
           "make_scheduled_train_step", "chunk_over_ring",
           "make_loss_and_grad", "train", "TrainLog", "checkpoints",
           "Checkpointer", "run_resume_parity"]
