"""Fused multi-step training engine: K ISGD steps per host dispatch.

The per-step engine pays one jit dispatch, one host→device batch transfer
and (worst case) one host sync per iteration — at paper-reproduction scales
that fixed cost dominates the actual compute, the exact pipeline-throughput
trap Eq. 21's batch-size/cost model amortizes on the hardware side.  This
module amortizes it on the dispatch side: batches come from a device-
resident :class:`~repro.data.device_ring.DeviceRing` (FCPR makes batch
identity a pure function of the step index, so selection is a
``dynamic_slice``, no host involvement), and a ``lax.scan`` runs
``chunk_steps`` full ISGD iterations — queue push, control limit,
accelerate ``cond``, subproblem ``while_loop``, loss-driven LR — inside ONE
compiled dispatch, stacking the per-step metrics on device.  The host
fetches metrics once per chunk (``TrainLog.extend``) and ``(state, params)``
buffers are donated across chunks.

Semantics are bit-exact with the per-step engine because the scan body *is*
the per-step body (``trainer.make_step_core``): in particular the
loss-driven LR reads ψ̄ from the carry's queue *before* the step pushes its
own loss — the same one-step lag the host loop has, just carried on device.
Putting the ``lr_fn`` read anywhere else (e.g. after the push, or hoisted to
the chunk boundary) silently changes the schedule; see the parity test.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import ISGDConfig
from repro.core.reduce import LOCAL, ReduceCtx
from repro.optim.base import UpdateRule
from repro.train.trainer import make_step_core


def chunk_over_ring(step_fn: Callable, n_batches: int, chunk_steps: int):
    """Wrap an un-jitted ``step_fn(state, params, batch) -> (state, params,
    metrics)`` in a ``lax.scan`` over the FCPR ring.

    Returns ``chunk_fn(state, params, ring_arrays, j0) -> (state, params,
    stacked_metrics)`` where ``ring_arrays`` is a dict of epoch arrays with
    ``n_batches * batch_size`` leading rows (batch t at ``[t*bs, (t+1)*bs)``
    — a :class:`DeviceRing`'s ``.arrays``, or its local shard inside
    ``shard_map``) and ``j0`` is the global index of the chunk's first step.
    Stacked metrics have a (chunk_steps,) leading dim.
    """
    assert chunk_steps >= 1

    def chunk_fn(state, params, ring_arrays, j0):
        j0 = jnp.asarray(j0, jnp.int32)
        bs = next(iter(ring_arrays.values())).shape[0] // n_batches

        def body(carry, off):
            state, params = carry
            t = (j0 + off) % n_batches      # FCPR: batch identity from index
            batch = {k: jax.lax.dynamic_slice_in_dim(v, t * bs, bs)
                     for k, v in ring_arrays.items()}
            state, params, metrics = step_fn(state, params, batch)
            return (state, params), metrics

        with jax.named_scope("obs/chunk_scan"):
            (state, params), stacked = jax.lax.scan(
                body, (state, params),
                jnp.arange(chunk_steps, dtype=jnp.int32))
        return state, params, stacked

    return chunk_fn


def make_chunked_train_step(loss_fn: Callable, rule: UpdateRule,
                            isgd_cfg: ISGDConfig, *, chunk_steps: int,
                            inconsistent: bool = True,
                            lr_fn: Callable = None, donate: bool = True,
                            reduce_ctx: ReduceCtx = LOCAL,
                            micro_batches: int = 1, schedule=None,
                            sched_seed: int = 0):
    """Single-device fused engine; distributed twin:
    ``repro.distributed.make_chunked_data_parallel_step``.

    Returns ``(init_fn, chunk_fn)`` with ``chunk_fn(state, params,
    ring_arrays, j0)`` jitted and donating ``(state, params)``.  ``lr_fn``
    is required — inside a fused chunk the LR *must* be derived on device
    from the previous step's queue; there is no host between steps to pass
    an override.

    ``schedule`` (a ``repro.sched`` policy) swaps the hard-wired FCPR ring
    walk for on-device policy selection: the chunk signature becomes
    ``chunk_fn(state, params, sched_state, ring_arrays, j0) -> (state,
    params, sched_state, stacked_metrics)`` with ``sched_state`` =
    ``schedule.init(isgd_cfg.n_batches)`` threaded through the scan carry
    (still one host dispatch per K steps; ``FCPRSchedule`` is bit-exact
    with ``schedule=None``).
    """
    assert lr_fn is not None, "chunked engine needs lr_fn (no per-step host)"
    init_fn, step_fn = make_step_core(
        loss_fn, rule, isgd_cfg, inconsistent=inconsistent, lr_fn=lr_fn,
        reduce_ctx=reduce_ctx, micro_batches=micro_batches)
    if schedule is not None:
        from repro.sched.engine import chunk_over_schedule
        chunk_fn = chunk_over_schedule(step_fn, schedule, isgd_cfg.n_batches,
                                       chunk_steps, sched_seed)
        jit_kwargs = dict(donate_argnums=(0, 1, 2)) if donate else {}
        return init_fn, jax.jit(chunk_fn, **jit_kwargs)
    chunk_fn = chunk_over_ring(step_fn, isgd_cfg.n_batches, chunk_steps)
    jit_kwargs = dict(donate_argnums=(0, 1)) if donate else {}
    return init_fn, jax.jit(chunk_fn, **jit_kwargs)
