"""Kill-and-resume parity: a checkpointed run resumes **bit-exactly**.

For each engine this module runs the same FCPR problem twice:

  * **uninterrupted** — the reference trajectory to S steps;
  * **killed** — run to step k, write a full-engine checkpoint
    (``repro.train.checkpoints.save_engine`` — a real on-disk ``.npz``
    round-trip, not an in-memory copy), throw EVERYTHING away, restore
    against freshly initialized templates, and run the remaining steps.

and demands the final ``(params, ISGDState)`` agree to the **bit** (max
abs deviation exactly 0.0).  That is the strongest possible statement that
the checkpoint captures the complete engine state: base-rule state, the ψ
control queue (so the resumed ψ̄-lagged loss-driven LR reproduces the
uninterrupted schedule — the lr_fn here depends on ψ̄ on purpose), the
iteration/acceleration counters, the sched-policy state and the FCPR step
cursor.  The problem is rigged with an outlier batch so the accelerate
``cond``/``while_loop`` fires across the kill boundary, not just the base
update.

Legs:

  * ``per-step``  — ``make_train_step``; killed at k=10 of S=30;
  * ``chunked``   — killed at a K=3 chunk boundary (step 6), resumed with
    K=4 — step 6 is **mid-chunk** on the resumed grid (6 % 4 = 2), pinning
    that ``chunk_fn``'s ``j0`` really is a free cursor; reference is the
    *per-step* engine (resume parity composes with engine parity);
  * ``sched``     — the fused scheduled engine under ``loss-prop``
    (stateful policy: EMA loss table rides the checkpoint);
  * ``hybrid``    — the DP×TP engine on the host mesh (data axis = all
    devices), checkpointing the sharded arrays through the same npz path;
  * ``async-ps``  — 1 worker, ``max_staleness=0``: the crash-consistent
    server snapshot (written by the in-lock ``checkpoint_fn`` hook at
    version 10) is saved to disk, restored, and handed back as ``resume=``;
    the worker replays from its SSP push clock.

Usable in-process (tests call ``run_resume_parity``) or as a module:

    PYTHONPATH=src python -m repro.train.resume_parity [--devices 8]

Exit status 0 iff every leg is bit-exact.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile


def _force_host_devices(n: int) -> None:
    assert "jax" not in sys.modules, "--devices must be set before jax init"
    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip())


def _problem(batch_size: int = 32, n_batches: int = 4):
    """Least-squares + one outlier batch (same rig as the other parity
    modules): the outlier breaches ψ̄ + kσ every cycle after warm-up, so the
    subproblem fires on both sides of the kill."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import ISGDConfig
    from repro.data import FCPRSampler
    from repro.optim import momentum

    dim = 6
    rng = np.random.RandomState(0)
    xs = rng.randn(batch_size * n_batches, dim).astype(np.float32)
    ys = ((xs @ rng.randn(dim, 1).astype(np.float32)).ravel()
          / np.sqrt(dim)).astype(np.float32)
    ys[:batch_size] += 3.0                        # the under-trained batch
    sampler = FCPRSampler({"x": xs, "y": ys}, batch_size=batch_size, seed=1)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, loss

    params0 = {"w": jnp.zeros((dim,), jnp.float32),
               "b": jnp.zeros((), jnp.float32)}
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=1.0, stop=3,
                      zeta=0.01)

    # ψ̄-dependent LR on purpose: a resume that loses the queue would pick a
    # wrong LR on its first step and diverge from the reference immediately
    def lr_fn(psi_bar):
        return 0.01 + 0.001 * jnp.minimum(psi_bar, 1.0)

    return loss_fn, params0, sampler, icfg, momentum(0.9), lr_fn


def _max_dev(a, b) -> float:
    import jax
    import jax.numpy as jnp
    diffs = jax.tree.map(
        lambda x, y: float(jnp.max(jnp.abs(jnp.asarray(x, jnp.float32)
                                           - jnp.asarray(y, jnp.float32))))
        if getattr(x, "size", 1) else 0.0, a, b)
    return max(jax.tree.leaves(diffs), default=0.0)


def _leg(name: str, ref, resumed, accelerations: int) -> dict:
    dev = max(_max_dev(ref[0], resumed[0]), _max_dev(ref[1], resumed[1]))
    return {"leg": name, "ok": dev == 0.0, "max_dev": dev,
            "accelerations": accelerations}


def _leg_per_step(tmp: str, S: int, k: int) -> dict:
    from repro.train import checkpoints
    from repro.train.trainer import make_train_step

    loss_fn, params0, sampler, icfg, rule, lr_fn = _problem()
    init_fn, step = make_train_step(loss_fn, rule, icfg, lr_fn=lr_fn,
                                    donate=False)

    def run(state, params, j0, j1):
        accel = 0
        for j in range(j0, j1):
            state, params, m = step(state, params, sampler(j))
            accel += int(m["accelerated"])
        return state, params, accel

    state, params, a_ref = run(init_fn(params0), params0, 0, S)

    st, pr, a1 = run(init_fn(params0), params0, 0, k)
    checkpoints.save_engine(os.path.join(tmp, "per_step"), params=pr,
                            state=st, step=k)
    ck = checkpoints.restore_engine(                   # fresh templates
        os.path.join(tmp, "per_step"),
        params_like=params0, state_like=init_fn(params0))
    st2, pr2, a2 = run(ck.state, ck.params, ck.step, S)
    return _leg("per-step", (params, state), (pr2, st2), a_ref)


def _leg_chunked(tmp: str, S: int, k: int) -> dict:
    import jax.numpy as jnp

    from repro.data import DeviceRing
    from repro.train import checkpoints
    from repro.train.chunked import make_chunked_train_step
    from repro.train.trainer import make_train_step

    loss_fn, params0, sampler, icfg, rule, lr_fn = _problem()
    assert k % 3 == 0 and (S - k) % 4 == 0 and k % 4 != 0, (S, k)
    ring = DeviceRing(dict(sampler.epoch_arrays()), sampler.batch_size)

    # reference: the PER-STEP engine — the kill/resume legs must land on the
    # same trajectory the engines already agree on, not a chunk-private one
    init_fn, step = make_train_step(loss_fn, rule, icfg, lr_fn=lr_fn,
                                    donate=False)
    state, params = init_fn(params0), params0
    a_ref = 0
    for j in range(S):
        state, params, m = step(state, params, sampler(j))
        a_ref += int(m["accelerated"])

    _, chunk3 = make_chunked_train_step(loss_fn, rule, icfg, chunk_steps=3,
                                        lr_fn=lr_fn, donate=False)
    st, pr = init_fn(params0), params0
    for c in range(k // 3):
        st, pr, _ = chunk3(st, pr, ring.arrays, c * 3)
    checkpoints.save_engine(os.path.join(tmp, "chunked"), params=pr,
                            state=st, step=k)
    ck = checkpoints.restore_engine(
        os.path.join(tmp, "chunked"),
        params_like=params0, state_like=init_fn(params0))
    # resume with K=4: ck.step=6 sits MID-chunk on this grid (6 % 4 = 2)
    _, chunk4 = make_chunked_train_step(loss_fn, rule, icfg, chunk_steps=4,
                                        lr_fn=lr_fn, donate=False)
    st2, pr2, j0 = ck.state, ck.params, jnp.asarray(ck.step, jnp.int32)
    for c in range((S - ck.step) // 4):
        st2, pr2, _ = chunk4(st2, pr2, ring.arrays, j0 + c * 4)
    return _leg("chunked", (params, state), (pr2, st2), a_ref)


def _leg_sched(tmp: str, S: int, k: int) -> dict:
    from repro.data import DeviceRing
    from repro.sched import schedule_from_spec
    from repro.train import checkpoints
    from repro.train.chunked import make_chunked_train_step

    loss_fn, params0, sampler, icfg, rule, lr_fn = _problem()
    K = 3
    assert k % K == 0 and S % K == 0, (S, k, K)
    schedule = schedule_from_spec("loss-prop")
    ring = DeviceRing(dict(sampler.epoch_arrays()), sampler.batch_size)
    init_fn, chunk = make_chunked_train_step(
        loss_fn, rule, icfg, chunk_steps=K, lr_fn=lr_fn, donate=False,
        schedule=schedule)

    def run(state, params, sched_state, c0, c1):
        accel = 0
        for c in range(c0, c1):
            state, params, sched_state, ms = chunk(state, params, sched_state,
                                                   ring.arrays, c * K)
            accel += int(ms["accelerated"].sum())
        return state, params, sched_state, accel

    sch0 = schedule.init(icfg.n_batches)
    state, params, sch, a_ref = run(init_fn(params0), params0, sch0, 0, S // K)

    st, pr, s1, _ = run(init_fn(params0), params0, sch0, 0, k // K)
    checkpoints.save_engine(os.path.join(tmp, "sched"), params=pr, state=st,
                            sched_state=s1, step=k)
    ck = checkpoints.restore_engine(
        os.path.join(tmp, "sched"), params_like=params0,
        state_like=init_fn(params0), sched_like=schedule.init(icfg.n_batches))
    st2, pr2, s2, _ = run(ck.state, ck.params, ck.sched_state,
                          ck.step // K, S // K)
    r = _leg("sched", (params, state), (pr2, st2), a_ref)
    r["max_dev"] = max(r["max_dev"], _max_dev(sch, s2))
    r["ok"] = r["max_dev"] == 0.0
    return r


def _leg_hybrid(tmp: str, S: int, k: int) -> dict:
    import jax

    from repro.distributed import batch_sharding, make_hybrid_step
    from repro.launch.mesh import make_host_mesh
    from repro.train import checkpoints

    loss_fn, params0, sampler, icfg, rule, lr_fn = _problem()
    mesh = make_host_mesh(model=1)
    assert sampler.batch_size % mesh.shape["data"] == 0
    init_fn, step = make_hybrid_step(loss_fn, rule, icfg, mesh, lr_fn=lr_fn,
                                     donate=False)
    b_sh = batch_sharding(mesh)

    def run(state, params, j0, j1):
        accel = 0
        with mesh:
            for j in range(j0, j1):
                batch = jax.device_put(sampler(j), b_sh)
                state, params, m = step(state, params, batch)
                accel += int(m["accelerated"])
        return state, params, accel

    state, params, a_ref = run(init_fn(params0), params0, 0, S)

    st, pr, _ = run(init_fn(params0), params0, 0, k)
    checkpoints.save_engine(os.path.join(tmp, "hybrid"), params=pr, state=st,
                            step=k)
    ck = checkpoints.restore_engine(
        os.path.join(tmp, "hybrid"),
        params_like=params0, state_like=init_fn(params0))
    st2, pr2, _ = run(ck.state, ck.params, ck.step, S)
    return _leg("hybrid", (params, state), (pr2, st2), a_ref)


def _leg_async_ps(tmp: str, S: int, k: int) -> dict:
    from repro.core import isgd_init
    from repro.distributed.async_ps.coordinator import (
        AsyncPSCoordinator, snapshot_engine_kwargs, snapshot_from_checkpoint)
    from repro.train import checkpoints

    loss_fn, params0, sampler, icfg, rule, lr_fn = _problem()

    def coord():
        return AsyncPSCoordinator(loss_fn, rule, icfg, workers=1,
                                  max_staleness=0, lr_fn=lr_fn)

    # the uninterrupted run doubles as the checkpoint writer: the server's
    # in-lock checkpoint_fn hook fires at version k (crash consistency —
    # the snapshot pairs push k with its SSP clock)
    snaps = []
    c1 = coord()
    c1.warmup(params0, sampler)
    params, state, records = c1.run(
        params0, sampler, S,
        checkpoint_fn=lambda s: snaps.append(s), checkpoint_every=k)
    snap = next(s for s in snaps if s["version"] == k)
    checkpoints.save_engine(os.path.join(tmp, "async_ps"),
                            **snapshot_engine_kwargs(snap))

    ck = checkpoints.restore_engine(
        os.path.join(tmp, "async_ps"), params_like=params0,
        state_like=isgd_init(rule, icfg, params0))
    assert ck.server == {"version": k, "pushed": {0: k}}, ck.server
    params2, state2, rec2 = coord().run(params0, sampler, S,
                                        resume=snapshot_from_checkpoint(ck))
    a_ref = sum(int(r["accelerated"]) for r in records)
    r = _leg("async-ps", (params, state), (params2, state2), a_ref)
    r["resumed_pushes"] = len(rec2)            # only the replayed tail
    return r


def run_resume_parity(S: int = 30, k: int = 10, *,
                      legs=("per-step", "chunked", "sched", "hybrid",
                            "async-ps")) -> list:
    """Returns one result dict per leg: {"leg", "ok", "max_dev",
    "accelerations"} — ``ok`` means bit-exact (max_dev == 0.0)."""
    runners = {"per-step": lambda t: _leg_per_step(t, S, k),
               "chunked": lambda t: _leg_chunked(t, S, 6),
               "sched": lambda t: _leg_sched(t, S, max(3, k - k % 3)),
               "hybrid": lambda t: _leg_hybrid(t, S, k),
               "async-ps": lambda t: _leg_async_ps(t, S, k)}
    out = []
    with tempfile.TemporaryDirectory(prefix="resume_parity_") as tmp:
        for leg in legs:
            out.append(runners[leg](tmp))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=0,
                    help="force this many XLA host-platform devices "
                         "(0 = use whatever XLA_FLAGS already provides)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--kill-at", type=int, default=10)
    args = ap.parse_args(argv)
    if args.devices:
        _force_host_devices(args.devices)
    results = run_resume_parity(args.steps, args.kill_at)
    fired = 0
    for r in results:
        fired += r["accelerations"]
        print(f"resume-parity {r['leg']:>8s}: "
              f"max_dev={r['max_dev']:.3e} "
              f"accelerations={r['accelerations']} -> "
              f"{'BIT-EXACT' if r['ok'] else 'FAIL'}")
    if fired == 0:
        print("resume-parity WARNING: subproblem never fired; the "
              "cond/while path never crossed a kill boundary")
        return 2
    return 0 if all(r["ok"] for r in results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
