"""Pluggable gradient/ψ reduction context.

The ISGD controller's correctness under data parallelism hinges on one
invariant (paper §6, DESIGN.md §2): the monitored loss ψ and the subproblem
gradients must be *globally reduced* scalars/trees, so the ``lax.cond``
accelerate predicate and every trip of the inner ``lax.while_loop`` take the
identical branch on every device.  ``isgd_step`` therefore takes a
``ReduceCtx`` and routes every ``loss_and_grad`` evaluation through it:

  * ``LocalReduce`` — identity; single-device semantics (the default, and
    what the host-loop reproduction path uses);
  * ``AxisReduce(axis)`` — ``lax.pmean`` over a named mesh axis; only valid
    inside a ``shard_map``/``pmap`` scope that binds that axis (the
    ``repro.distributed.data_parallel`` engine);
  * ``StalenessReduce`` — the async parameter-server regime
    (``repro.distributed.async_ps``, paper §6.2): loss/gradients stay
    *local* during the step, so the accelerate ``cond`` and the subproblem
    ``while_loop`` are per-worker-deterministic with no collectives inside
    them; global consistency is instead recovered at the server, which owns
    the canonical ψ queue and folds each worker's pushed delta in with the
    staleness weight ``w(τ)`` this context defines (``weight``).

All are hashable frozen dataclasses so a jitted step specializes on the
context without retracing per call.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax


@dataclass(frozen=True)
class ReduceCtx:
    """Base: identity (local) reduction."""

    #: mesh axis the context reduces over; ``None`` = purely local.
    axis: Optional[str] = None

    def scalar(self, x):
        """Reduce a per-shard scalar (mean over participating devices)."""
        return x

    def tree(self, t):
        """Reduce a pytree of per-shard values (mean over devices)."""
        return t

    def sum_scalar(self, x):
        """Reduce a per-shard scalar by summation (psum)."""
        return x

    def wrap_loss_and_grad(self, loss_and_grad: Callable) -> Callable:
        """``((loss, aux), grads)``-returning fn -> globally reduced variant.

        This is the single choke point that enforces the ψ invariant: every
        consumer of the wrapped fn (base update, control queue, accelerate
        predicate, subproblem solver) sees identical values on all devices.
        """
        if self.axis is None:
            return loss_and_grad

        def lg(params, batch):
            (loss, aux), grads = loss_and_grad(params, batch)
            return (self.scalar(loss), self.tree(aux)), self.tree(grads)

        return lg


@dataclass(frozen=True)
class LocalReduce(ReduceCtx):
    """Single-device / per-shard semantics (identity)."""


@dataclass(frozen=True)
class AxisReduce(ReduceCtx):
    """Mean-reduce over a named mesh axis (``lax.pmean``).

    Shard losses are per-shard *means*, so a pmean of equal-sized shards
    equals the global-batch mean — the single-device reference — up to f32
    reassociation (the parity test bounds this at 1e-5 over 20 steps).

    ``axis`` may also be a *tuple* of axis names — the data sub-axes of a
    larger mesh (e.g. ``("pod", "data")`` on the production 3-D mesh): the
    reduction then spans exactly those axes and leaves the remaining
    (model-parallel) axes untouched, which is what the hybrid DP × TP engine
    needs — ψ/grads averaged over the data sub-mesh while GSPMD handles the
    tensor-parallel axis.  Tuples keep the dataclass hashable, so the jitted
    step still specializes without retracing.

    ``deterministic=True`` replaces the backend all-reduce with an
    ``all_gather`` + *local* reduction in flat shard order.  A plain
    ``pmean``'s f32 association is a backend/topology detail — intra-host
    XLA:CPU reduces in a different order than a cross-process gloo ring, so
    the same 4 data shards give 1-ulp-different ψ on a ``(4,)`` mesh vs a
    ``(pod=2, data=2)`` one, and the accelerate ``cond`` can eventually
    branch apart.  Gathering first pins the association to the flattened
    shard order (pod-major, matching the global batch's row order), making
    the reduction a pure function of the shard *values* — bit-identical on
    any process topology that preserves the data order.  The distributed
    engines always construct this mode (see
    ``repro.distributed.data_parallel``); the cost is an all-gather of the
    grad tree instead of a psum, irrelevant at control-tree sizes but worth
    revisiting if grads ever dominate the wire.
    """

    axis: str | tuple = "data"
    deterministic: bool = False

    def _gathered(self, x):
        """x gathered over the data axes: (n_shards, *x.shape), pod-major
        flat order — the same order the global batch's rows have."""
        import jax.numpy as jnp

        g = jax.lax.all_gather(x, self.axis, tiled=False)
        extra = g.ndim - jnp.ndim(x)        # one gathered dim per axis name
        return g.reshape((-1,) + g.shape[extra:])

    def scalar(self, x):
        if self.deterministic:
            import jax.numpy as jnp
            return jnp.mean(self._gathered(x), axis=0)
        return jax.lax.pmean(x, self.axis)

    def tree(self, t):
        if self.deterministic:
            return jax.tree.map(self.scalar, t)
        return jax.lax.pmean(t, self.axis)

    def sum_scalar(self, x):
        if self.deterministic:
            import jax.numpy as jnp
            return jnp.sum(self._gathered(x), axis=0)
        return jax.lax.psum(x, self.axis)


@dataclass(frozen=True)
class StalenessReduce(ReduceCtx):
    """Async parameter-server reduction (paper §6.2).

    ``axis`` stays ``None``: during the step every ``loss_and_grad``
    evaluation is the worker's own (``wrap_loss_and_grad`` is the identity),
    so the subproblem ``while_loop`` trips on per-worker values and never
    needs a collective — each worker is deterministic given its snapshot.
    The ψ invariant is instead enforced *server-side*: the
    :class:`~repro.distributed.async_ps.ParamServer` owns the canonical loss
    queue (so limit/accelerate decisions use globally consistent statistics
    even when workers race) and folds each pushed delta in with the
    staleness weight ``w(τ)`` defined here, where τ is the number of server
    versions applied between the worker's pull and its push.

    Decay families (``w(0) == 1`` for all, which is what makes the
    ``max_staleness=0`` single-worker engine reduce exactly to the
    synchronous schedule):

      * ``"inverse"`` — ``w(τ) = 1 / (1 + alpha·τ)`` (the default, the
        classic staleness-aware async-SGD weighting);
      * ``"exp"``     — ``w(τ) = exp(-alpha·τ)``;
      * ``"none"``    — ``w(τ) = 1`` (pure Hogwild-style application).
    """

    decay: str = "inverse"
    alpha: float = 1.0

    def weight(self, tau):
        """Staleness weight ``w(τ)`` — accepts python ints or jnp scalars."""
        import jax.numpy as jnp

        tau = jnp.asarray(tau, jnp.float32)
        if self.decay == "inverse":
            return 1.0 / (1.0 + self.alpha * tau)
        if self.decay == "exp":
            return jnp.exp(-self.alpha * tau)
        if self.decay == "none":
            return jnp.ones_like(tau)
        raise ValueError(f"unknown staleness decay {self.decay!r}")


def staleness_reduce_from_spec(spec: str) -> StalenessReduce:
    """Parse a ``--staleness-decay`` CLI spec: ``"inverse"``, ``"exp:0.5"``,
    ``"none"`` — ``family[:alpha]``."""
    family, _, alpha = spec.partition(":")
    ctx = StalenessReduce(decay=family, alpha=float(alpha) if alpha else 1.0)
    ctx.weight(0)                      # validate the family eagerly
    return ctx


LOCAL = LocalReduce()
