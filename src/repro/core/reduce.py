"""Pluggable gradient/ψ reduction context.

The ISGD controller's correctness under data parallelism hinges on one
invariant (paper §6, DESIGN.md §2): the monitored loss ψ and the subproblem
gradients must be *globally reduced* scalars/trees, so the ``lax.cond``
accelerate predicate and every trip of the inner ``lax.while_loop`` take the
identical branch on every device.  ``isgd_step`` therefore takes a
``ReduceCtx`` and routes every ``loss_and_grad`` evaluation through it:

  * ``LocalReduce`` — identity; single-device semantics (the default, and
    what the host-loop reproduction path uses);
  * ``AxisReduce(axis)`` — ``lax.pmean`` over a named mesh axis; only valid
    inside a ``shard_map``/``pmap`` scope that binds that axis (the
    ``repro.distributed.data_parallel`` engine).

Both are hashable frozen dataclasses so a jitted step specializes on the
context without retracing per call.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax


@dataclass(frozen=True)
class ReduceCtx:
    """Base: identity (local) reduction."""

    #: mesh axis the context reduces over; ``None`` = purely local.
    axis: Optional[str] = None

    def scalar(self, x):
        """Reduce a per-shard scalar (mean over participating devices)."""
        return x

    def tree(self, t):
        """Reduce a pytree of per-shard values (mean over devices)."""
        return t

    def sum_scalar(self, x):
        """Reduce a per-shard scalar by summation (psum)."""
        return x

    def wrap_loss_and_grad(self, loss_and_grad: Callable) -> Callable:
        """``((loss, aux), grads)``-returning fn -> globally reduced variant.

        This is the single choke point that enforces the ψ invariant: every
        consumer of the wrapped fn (base update, control queue, accelerate
        predicate, subproblem solver) sees identical values on all devices.
        """
        if self.axis is None:
            return loss_and_grad

        def lg(params, batch):
            (loss, aux), grads = loss_and_grad(params, batch)
            return (self.scalar(loss), self.tree(aux)), self.tree(grads)

        return lg


@dataclass(frozen=True)
class LocalReduce(ReduceCtx):
    """Single-device / per-shard semantics (identity)."""


@dataclass(frozen=True)
class AxisReduce(ReduceCtx):
    """Mean-reduce over a named mesh axis (``lax.pmean``).

    Shard losses are per-shard *means*, so a pmean of equal-sized shards
    equals the global-batch mean — the single-device reference — up to f32
    reassociation (the parity test bounds this at 1e-5 over 20 steps).
    """

    axis: str = "data"

    def scalar(self, x):
        return jax.lax.pmean(x, self.axis)

    def tree(self, t):
        return jax.lax.pmean(t, self.axis)

    def sum_scalar(self, x):
        return jax.lax.psum(x, self.axis)


LOCAL = LocalReduce()
