"""Inconsistent Stochastic Gradient Descent (the paper's contribution).

``isgd(base_rule, ...)`` wraps any base update rule (SGD / Momentum /
Nesterov, §4.3) with inconsistent training:

  1. every iteration runs the normal base update (Alg.1 line 21);
  2. the batch loss is pushed into the O(1) epoch-window queue and the upper
     control limit ψ̄ + kσ is recomputed (lines 13–20);
  3. if the loss exceeded the limit (and warm-up is over), the conservative
     subproblem (Eq. 17) is solved on the same batch with early stopping
     (Alg.2) — extra gradient updates that stay proximal to the entry
     weights w_{t-1} via the ε/(2 n_w)·‖w − w_{t−1}‖² term.

Everything is jit-able: the accelerate branch is a ``lax.cond`` whose
predicate is a *globally reduced* scalar (identical on every device under
pjit — DESIGN.md §2), and the inner solver is a ``lax.while_loop``.

The global reduction is enforced (not just assumed) via the ``reduce_ctx``
argument: every ``loss_and_grad`` evaluation — the main step's and each
subproblem trip's — goes through ``ReduceCtx.wrap_loss_and_grad``, so under
``AxisReduce("data")`` inside a ``shard_map`` the gradients are pmean'd and
ψ is the global-batch mean, making the cond/while control flow identical on
every device (see ``repro.distributed.data_parallel``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.analysis.mode import in_analysis_mode
from repro.core import control
from repro.core.reduce import LOCAL, ReduceCtx
from repro.optim.base import UpdateRule


class ISGDState(NamedTuple):
    base: tuple
    queue: control.LossQueue
    iter: jnp.ndarray            # global iteration counter
    accel_count: jnp.ndarray     # how many batches were accelerated
    sub_iters: jnp.ndarray       # total subproblem iterations spent


@dataclass(frozen=True)
class ISGDConfig:
    n_batches: int               # n_b: batches per epoch = queue length
    k_sigma: float = 3.0         # control-limit multiplier (2–3 recommended)
    stop: int = 5                # early-stopping bound for Alg.2
    epsilon: float = 0.1         # conservative-constraint weight (paper: 1e-1)
    zeta: float | None = None    # Alg.2 constant step; default = current lr


def _tree_param_count(params) -> float:
    return float(sum(x.size for x in jax.tree.leaves(params)))


def solve_subproblem(loss_and_grad, params, limit, entry_loss, lr,
                     cfg: ISGDConfig):
    """Alg.2: minimize ½‖ψ(w)−limit‖² + ε/(2n_w)‖w−w_{t-1}‖² by early-stopped
    constant-step descent.  Returns (params, iterations_used)."""
    n_w = _tree_param_count(params)
    zeta = cfg.zeta if cfg.zeta is not None else lr
    w0 = params

    def cond(carry):
        _, psi, it = carry
        return (it < cfg.stop) & (psi > limit)

    def body(carry):
        w, _, it = carry
        psi, grads = loss_and_grad(w)
        scale = (psi - limit)

        def upd(wi, gi, w0i):
            d = (scale * gi.astype(jnp.float32)
                 + cfg.epsilon * (wi.astype(jnp.float32) - w0i.astype(jnp.float32)) / n_w)
            return (wi.astype(jnp.float32) - zeta * d).astype(wi.dtype)

        w = jax.tree.map(upd, w, grads, w0)
        return (w, psi, it + 1)

    if in_analysis_mode():
        # unrolled, convergence-masked loop of exactly ``stop`` iterations —
        # the early-stopping upper bound, so compiled cost counts every trip
        carry = (params, entry_loss, jnp.zeros((), jnp.int32))
        for _ in range(cfg.stop):
            live = cond(carry)
            new = body(carry)
            carry = jax.tree.map(
                lambda a, b: jnp.where(live, b, a), carry, new)
        w, _, used = carry
        return w, used

    w, _, used = jax.lax.while_loop(cond, body, (params, entry_loss, jnp.zeros((), jnp.int32)))
    return w, used


def isgd_init(rule: UpdateRule, cfg: ISGDConfig, params) -> ISGDState:
    return ISGDState(
        base=rule.init(params),
        queue=control.init_queue(cfg.n_batches),
        iter=jnp.zeros((), jnp.int32),
        accel_count=jnp.zeros((), jnp.int32),
        sub_iters=jnp.zeros((), jnp.int32),
    )


def isgd_step(rule: UpdateRule, cfg: ISGDConfig, loss_and_grad: Callable,
              state: ISGDState, params, batch, lr,
              reduce_ctx: ReduceCtx = LOCAL, slot=None):
    """One inconsistent-training iteration (Alg.1 body).

    ``loss_and_grad(params, batch) -> ((loss, aux), grads)`` computes the
    per-shard loss/gradients; ``reduce_ctx`` turns them into the globally
    reduced ψ/grads the controller monitors (identity for single device).

    ``slot`` (static ``None`` or a traced batch index) picks the SPC queue
    write: ``None`` = FIFO push (FCPR: window = one epoch); an index =
    per-batch table write (``control.push_at``), used by non-FCPR batch
    schedules so the limit statistics stay one-entry-per-batch
    (``repro.sched``).
    """
    loss_and_grad = reduce_ctx.wrap_loss_and_grad(loss_and_grad)
    (loss, aux), grads = loss_and_grad(params, batch)

    # line 21: vanilla base update
    base_state, params = rule.apply(state.base, params, grads, lr)

    # lines 13-20: queue + control limit
    with jax.named_scope("obs/psi_push"):
        queue = (control.push(state.queue, loss) if slot is None
                 else control.push_at(state.queue, slot, loss))
        limit = control.control_limit(queue, cfg.k_sigma)
    accelerate = (loss > limit)          # warm-up handled by limit=+inf

    # line 22-23: conservative subproblem on the under-trained batch
    def on_accel(p):
        def lg(w):
            (l, _), g = loss_and_grad(w, batch)
            return l, g
        with jax.named_scope("obs/accelerate"):
            return solve_subproblem(lg, p, limit, loss, lr, cfg)

    def no_accel(p):
        return p, jnp.zeros((), jnp.int32)

    params, used = jax.lax.cond(accelerate, on_accel, no_accel, params)

    new_state = ISGDState(
        base=base_state,
        queue=queue,
        iter=state.iter + 1,
        accel_count=state.accel_count + accelerate.astype(jnp.int32),
        sub_iters=state.sub_iters + used,
    )
    metrics = {
        "loss": loss,
        "aux": aux,
        "psi_bar": control.mean(queue),
        "psi_std": control.std(queue),
        "limit": limit,
        "accelerated": accelerate,
        "sub_iters": used,
    }
    return new_state, params, metrics


def consistent_step(rule: UpdateRule, loss_and_grad: Callable, state, params,
                    batch, lr, reduce_ctx: ReduceCtx = LOCAL, slot=None):
    """Baseline SGD/Momentum/Nesterov step (no inconsistent training) with the
    same metrics surface, so benchmarks are single-factor (paper §5.2).
    ``slot`` as in :func:`isgd_step`."""
    loss_and_grad = reduce_ctx.wrap_loss_and_grad(loss_and_grad)
    (loss, aux), grads = loss_and_grad(params, batch)
    base_state, params = rule.apply(state.base, params, grads, lr)
    queue = (control.push(state.queue, loss) if slot is None
             else control.push_at(state.queue, slot, loss))
    metrics = {
        "loss": loss,
        "aux": aux,
        "psi_bar": control.mean(queue),
        "psi_std": control.std(queue),
        "limit": control.control_limit(queue),
        "accelerated": jnp.zeros((), bool),
        "sub_iters": jnp.zeros((), jnp.int32),
    }
    new_state = ISGDState(base=base_state, queue=queue, iter=state.iter + 1,
                          accel_count=state.accel_count,
                          sub_iters=state.sub_iters)
    return new_state, params, metrics
