"""Statistical-process-control loss tracking (paper §4.1, Alg.1 lines 13–20).

A fixed-length FIFO of the last ``n_b`` batch losses (one epoch under FCPR
sampling) with O(1) running mean/std maintained via Σ and Σ² — the paper's
"memory efficient" alternative to variance-reduction state.  The upper
control limit is ψ̄ + kσ (k=3 by default, Eq. 15).

During warm-up (fewer than ``n_b`` observed losses) the limit is +inf so the
subproblem never triggers before one full epoch (Alg.1 line 22: iter > n).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
from jax import lax


def _sq(x):
    """x² via an exact 12/12-bit split, immune to fma contraction.

    LLVM's CPU backend may or may not contract ``total_sq + x*x`` into an
    fma depending on fusion context (it differs between the chunked scan
    and the hybrid engine, and an HLO ``optimization_barrier`` does not
    survive to codegen) — so a plain ``x*x`` makes Σ² codegen-dependent
    and unreplayable by the host-side SPC mirror (``repro.obs.spc``).
    Masking the low 12 mantissa bits splits x = hi + lo with ≤12
    significant bits each, so hi², 2·hi·lo and lo² are all ≤24-bit
    products — exactly representable in f32.  When every multiply is
    exact, fma(a, b, c) ≡ round(a·b) + c, so contraction cannot change
    the result and the remaining rounding (the adds, in this fixed
    association) is deterministic on both device and host."""
    xi = lax.bitcast_convert_type(x, jnp.int32)
    hi = lax.bitcast_convert_type(jnp.bitwise_and(xi, jnp.int32(-4096)), jnp.float32)
    lo = x - hi
    return (hi * hi + 2.0 * (hi * lo)) + lo * lo


class LossQueue(NamedTuple):
    buf: jnp.ndarray        # (n_b,) f32 ring buffer
    total: jnp.ndarray      # Σ losses in window
    total_sq: jnp.ndarray   # Σ losses² in window
    count: jnp.ndarray      # observed so far (saturates at n_b)
    idx: jnp.ndarray        # ring position


def init_queue(n_b: int) -> LossQueue:
    return LossQueue(
        buf=jnp.zeros((n_b,), jnp.float32),
        total=jnp.zeros((), jnp.float32),
        total_sq=jnp.zeros((), jnp.float32),
        count=jnp.zeros((), jnp.int32),
        idx=jnp.zeros((), jnp.int32),
    )


def push(q: LossQueue, loss) -> LossQueue:
    """O(1) ring-buffer update: dequeue the stale loss, enqueue the new one."""
    loss = jnp.asarray(loss, jnp.float32)
    n_b = q.buf.shape[0]
    old = q.buf[q.idx]
    full = q.count >= n_b
    total = q.total + loss - jnp.where(full, old, 0.0)
    total_sq = q.total_sq + _sq(loss) - jnp.where(full, _sq(old), 0.0)
    buf = q.buf.at[q.idx].set(loss)
    return LossQueue(
        buf=buf,
        total=total,
        total_sq=total_sq,
        count=jnp.minimum(q.count + 1, n_b),
        idx=(q.idx + 1) % n_b,
    )


def push_at(q: LossQueue, slot, loss) -> LossQueue:
    """O(1) per-batch *table* write: replace the loss at position ``slot``
    (= the batch index) instead of dequeuing FIFO.

    Used by non-FCPR batch schedules (``repro.sched``): when the visit
    order is no longer the fixed cycle, the FIFO window stops meaning "one
    epoch" (hot batches would occupy several entries), so the queue is
    re-purposed as a per-batch loss table — one slot per batch — and
    ψ̄/σ/limit become statistics over the latest loss of each batch.

    Validity bookkeeping reuses ``count``: ``mean``/``std`` mask to slots
    ``< count``, so callers must fill slots ``0..n_b-1`` in order before
    free-order writes — which the schedules' warm-up FCPR sweep does (and
    the +inf warm-up limit holds until all ``n_b`` slots are seen, exactly
    as under FIFO pushes).
    """
    loss = jnp.asarray(loss, jnp.float32)
    slot = jnp.asarray(slot, jnp.int32)
    n_b = q.buf.shape[0]
    old = q.buf[slot]
    filled = slot < q.count
    total = q.total + loss - jnp.where(filled, old, 0.0)
    total_sq = q.total_sq + _sq(loss) - jnp.where(filled, _sq(old), 0.0)
    return LossQueue(
        buf=q.buf.at[slot].set(loss),
        total=total,
        total_sq=total_sq,
        count=jnp.minimum(jnp.maximum(q.count, slot + 1), n_b),
        idx=(slot + 1) % n_b,
    )


def mean(q: LossQueue):
    return q.total / jnp.maximum(q.count, 1).astype(jnp.float32)


def std(q: LossQueue):
    """Computed from the buffer (masked to observed entries) rather than the
    Σ²−mean² identity — f32 cancellation makes the latter unusable once the
    losses are small relative to their magnitude.  Still O(n_b) time with
    O(n_b) memory, n_b = batches/epoch (a few hundred floats)."""
    n_b = q.buf.shape[0]
    m = mean(q)
    valid = (jnp.arange(n_b) < q.count).astype(jnp.float32)
    var = jnp.sum(valid * (q.buf - m) ** 2) / jnp.maximum(q.count, 1)
    return jnp.sqrt(jnp.maximum(var, 0.0))


def control_limit(q: LossQueue, k: float = 3.0):
    """Upper control limit ψ̄ + kσ (Eq. 15); +inf until one full epoch."""
    warm = q.count >= q.buf.shape[0]
    return jnp.where(warm, mean(q) + k * std(q), jnp.inf)
