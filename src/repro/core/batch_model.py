"""Time-domain batch-size/convergence model (paper §4.5, Eq. 21–24).

t_iter = n_b/C1 + C2 (compute + synchronization); after T = t/t_iter updates
the loss bound is ψ ≤ 1/sqrt(n_b·T) + 1/T (Dekel et al.).  Solving for the
time t that reaches a target ψ gives the predicted training time as a
function of batch size, with an interior optimum (Fig. 5).
"""
from __future__ import annotations

import numpy as np


def iter_time(n_b, c1: float, c2: float):
    """Eq. 21: seconds per gradient update."""
    return np.asarray(n_b, float) / c1 + c2


def loss_bound(n_b, T):
    """Eq. 23 with equality."""
    n_b = np.asarray(n_b, float)
    T = np.asarray(T, float)
    return 1.0 / np.sqrt(n_b * T) + 1.0 / T


def predicted_time_to_loss(n_b, psi: float, c1: float, c2: float,
                           t_max: float = 1e9):
    """Smallest t with loss_bound(n_b, t/t_iter) <= psi (numeric, per Eq. 24)."""
    n_b = np.asarray(n_b, float)
    ti = iter_time(n_b, c1, c2)

    def solve_one(nb, t1):
        lo, hi = t1, t_max
        if loss_bound(nb, hi / t1) > psi:
            return np.inf
        for _ in range(200):
            mid = 0.5 * (lo + hi)
            if loss_bound(nb, mid / t1) <= psi:
                hi = mid
            else:
                lo = mid
        return hi

    return np.array([solve_one(nb, t1) for nb, t1 in
                     zip(np.atleast_1d(n_b), np.atleast_1d(ti))])


def optimal_batch_size(psi: float, c1: float, c2: float,
                       candidates=None) -> int:
    """argmin over candidate batch sizes of the predicted training time."""
    if candidates is None:
        candidates = np.arange(50, 3050, 50)
    times = predicted_time_to_loss(candidates, psi, c1, c2)
    return int(candidates[int(np.argmin(times))])
