from repro.core import batch_model, control, schedule
from repro.core.isgd import (
    ISGDConfig,
    ISGDState,
    consistent_step,
    isgd_init,
    isgd_step,
    solve_subproblem,
)
from repro.core.reduce import (LOCAL, AxisReduce, LocalReduce, ReduceCtx,
                               StalenessReduce, staleness_reduce_from_spec)

__all__ = [
    "ISGDConfig", "ISGDState", "isgd_init", "isgd_step", "consistent_step",
    "solve_subproblem", "control", "schedule", "batch_model",
    "ReduceCtx", "LocalReduce", "AxisReduce", "StalenessReduce",
    "staleness_reduce_from_spec", "LOCAL",
]
