from repro.core import batch_model, control, schedule
from repro.core.isgd import (
    ISGDConfig,
    ISGDState,
    consistent_step,
    isgd_init,
    isgd_step,
    solve_subproblem,
)

__all__ = [
    "ISGDConfig", "ISGDState", "isgd_init", "isgd_step", "consistent_step",
    "solve_subproblem", "control", "schedule", "batch_model",
]
