"""Loss-driven learning-rate schedule (paper §4.2 end / §5.2).

Because ISGD iterations are inconsistent, the LR is keyed on the running
average loss ψ̄ (Alg.1 line 19) instead of the iteration count.  The paper's
AlexNet schedule: lr=0.015 for ψ̄∈[2.0,∞), 0.0015 for [1.2,2.0), 0.00015 for
[0,1.2).
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp


def loss_driven_lr(thresholds: Sequence[float], lrs: Sequence[float]):
    """thresholds descending: lr = lrs[i] for psi_bar >= thresholds[i],
    else lrs[-1].  len(lrs) == len(thresholds) + 1."""
    assert len(lrs) == len(thresholds) + 1
    th = tuple(float(t) for t in thresholds)
    vals = tuple(float(v) for v in lrs)

    def lr_fn(psi_bar):
        # arrays are built inside the closure, not at factory time: module-
        # level schedules (ALEXNET_SCHEDULE) must not touch the backend
        # before a multi-process run calls jax.distributed.initialize
        psi_bar = jnp.asarray(psi_bar, jnp.float32)
        idx = jnp.sum(psi_bar < jnp.asarray(th, jnp.float32))
        return jnp.asarray(vals, jnp.float32)[idx]

    return lr_fn


def constant_lr(lr: float):
    def lr_fn(psi_bar):
        return jnp.asarray(lr, jnp.float32)
    return lr_fn


ALEXNET_SCHEDULE = loss_driven_lr([2.0, 1.2], [0.015, 0.0015, 0.00015])
