"""Whisper-medium — encoder-decoder audio backbone [arXiv:2212.04356].

24 decoder layers (as assigned) + 24 encoder layers; the mel-spectrogram +
conv frontend is a STUB: ``input_specs`` provides precomputed frame
embeddings of shape (batch, encoder_seq, d_model).  Whisper uses learned
absolute positions and MHA (kv heads = heads = 16).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51865,
    encoder_layers=24, encoder_seq=1500, frontend="audio",
    source="arXiv:2212.04356",
)
