"""The ``paper_transformer`` zoo: configs the ISGD engines train end-to-end.

The source paper (arXiv:1603.05544) benchmarks CNNs; these are the
matmul-dominated counterparts that put the ISGD engines on the Pallas fast
path (ISSUE 6) — one family per mixer class, two tiers each:

  * ``tiny`` — CPU-CI tier: trains through the fused chunked engines in
    seconds (tests, parity modules, bench smokes).  Dims chosen so the
    kernel tile selection hits the same block sizes the numerics gate
    sweeps (seq 64, head_dim 16, vocab 256).
  * ``base`` — single-host GPU/TPU tier: big enough that flash-attention,
    fused-xent and ssd_scan are the step-body hot spots and remat at the
    chunk-scan boundary is the memory bound.

``zoo_config(model, tier)`` is the launcher surface (``--model`` /
``--tier``); ``get_config("paper_transformer")`` resolves to the base
transformer like any other arch module.
"""
from repro.configs.base import ModelConfig

PAPER_TRANSFORMER_TINY = ModelConfig(
    name="paper-transformer-tiny", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, tie_embeddings=True,
    source="arXiv:1603.05544 §5 workloads, transformer counterpart (CI tier)",
)

PAPER_TRANSFORMER = ModelConfig(
    name="paper-transformer", family="dense",
    num_layers=16, d_model=1024, num_heads=16, num_kv_heads=8, head_dim=64,
    d_ff=4096, vocab_size=32768, rope_theta=1e5,
    source="arXiv:1603.05544 §5 workloads, transformer counterpart "
           "(single-host tier, ~0.4B params)",
)

PAPER_MOE_TINY = ModelConfig(
    name="paper-moe-tiny", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, tie_embeddings=True,
    num_experts=4, top_k=2, moe_d_ff=128, moe_every=1,
    # no-drop capacity: keeps tiny-tier parity runs deterministic in the
    # face of capacity drops that depend on group composition
    moe_capacity_factor=1e9,
    source="GShard-style top-2 MoE, CI tier",
)

PAPER_MOE = ModelConfig(
    name="paper-moe", family="moe",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=4, head_dim=64,
    d_ff=3072, vocab_size=32768, rope_theta=1e5,
    num_experts=8, top_k=2, moe_d_ff=1536, moe_every=2,
    source="GShard-style top-2 MoE, single-host tier",
)

PAPER_SSM_TINY = ModelConfig(
    name="paper-ssm-tiny", family="ssm",
    num_layers=2, d_model=64, vocab_size=256, tie_embeddings=True,
    ssm_state=32, ssm_headdim=16, ssm_expand=2, ssm_chunk=16,
    source="Mamba2/SSD mixer stack, CI tier",
)

PAPER_SSM = ModelConfig(
    name="paper-ssm", family="ssm",
    num_layers=24, d_model=1024, vocab_size=32768,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    source="Mamba2/SSD mixer stack, single-host tier",
)

ZOO = {
    ("transformer", "tiny"): PAPER_TRANSFORMER_TINY,
    ("transformer", "base"): PAPER_TRANSFORMER,
    ("moe", "tiny"): PAPER_MOE_TINY,
    ("moe", "base"): PAPER_MOE,
    ("ssm", "tiny"): PAPER_SSM_TINY,
    ("ssm", "base"): PAPER_SSM,
}

ZOO_MODELS = ("transformer", "moe", "ssm")
ZOO_TIERS = ("tiny", "base")


def zoo_config(model: str, tier: str = "tiny") -> ModelConfig:
    try:
        return ZOO[(model, tier)]
    except KeyError:
        raise ValueError(f"unknown zoo config ({model!r}, {tier!r}); "
                         f"models={ZOO_MODELS} tiers={ZOO_TIERS}") from None


CONFIG = PAPER_TRANSFORMER
