"""Jamba-v0.1 (52B total) — hybrid Mamba/attention + MoE [arXiv:2403.19887].

1 attention layer per block of 8 (1:7 attn:mamba); MoE every 2nd layer,
16 experts top-2.  Mamba mixer: d_state=16, expand=2, headdim=64 (we use the
Mamba2/SSD mixer for TPU-friendliness — DESIGN.md §2).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    num_experts=16, top_k=2, moe_d_ff=14336, moe_every=2,
    ssm_state=16, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    attn_every=8,
    source="arXiv:2403.19887",
)
