"""The paper's own benchmark networks (§5): LeNet on MNIST, Caffe
CIFAR-10-Quick on CIFAR-10, AlexNet on ImageNet — reimplemented in pure JAX
for the faithful ISGD reproduction.  Dims follow the Caffe model zoo
definitions the paper used.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class ConvSpec:
    features: int
    kernel: int
    stride: int = 1
    pool: int = 0          # max-pool window (0 = none)
    pool_stride: int = 2


@dataclass(frozen=True)
class CNNConfig:
    name: str
    image_size: int
    channels: int
    num_classes: int
    convs: tuple = ()
    hidden: tuple = ()
    source: str = ""

    @property
    def family(self) -> str:
        return "cnn"


LENET = CNNConfig(
    name="lenet", image_size=28, channels=1, num_classes=10,
    convs=(ConvSpec(20, 5, pool=2), ConvSpec(50, 5, pool=2)),
    hidden=(500,),
    source="LeCun et al. 1998 (Caffe LeNet)",
)

CIFAR_QUICK = CNNConfig(
    name="cifar-quick", image_size=32, channels=3, num_classes=10,
    convs=(ConvSpec(32, 5, pool=3), ConvSpec(32, 5, pool=3), ConvSpec(64, 5, pool=3)),
    hidden=(64,),
    source="Caffe CIFAR-10 Quick",
)

# Downscaled AlexNet-class network (the paper's large-scale case).  Full
# 224x224 AlexNet is instantiable too, but benchmarks default to 64x64 to fit
# the CPU budget; relative ISGD-vs-SGD behaviour is preserved.
ALEXNET_SMALL = CNNConfig(
    name="alexnet-small", image_size=64, channels=3, num_classes=1000,
    convs=(ConvSpec(64, 11, stride=4, pool=3), ConvSpec(192, 5, pool=3),
           ConvSpec(384, 3), ConvSpec(256, 3), ConvSpec(256, 3, pool=3)),
    hidden=(1024, 1024),
    source="Krizhevsky et al. 2012 (Caffe AlexNet, downscaled)",
)

PAPER_CNNS = {c.name: c for c in (LENET, CIFAR_QUICK, ALEXNET_SMALL)}
