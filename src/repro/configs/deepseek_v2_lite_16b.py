"""DeepSeek-V2-Lite (16B total) — MLA + fine-grained MoE [arXiv:2405.04434].

Assignment line: 27L d_model=2048 16H d_ff=1408 vocab=102400, MoE 64e top-6,
MLA kv_lora=512, 2 shared experts.  (The bracket's "160 routed" belongs to the
full V2; the Lite model and the assignment's main line use 64 routed experts.)
First layer is dense (d_ff=10944) per the model card; remaining layers MoE with
per-expert hidden 1408.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=10944, vocab_size=102400, rope_theta=1e4,
    mla=True, kv_lora_rank=512, qk_rope_head_dim=64, qk_nope_head_dim=128,
    v_head_dim=128,
    num_experts=64, num_shared_experts=2, top_k=6, moe_d_ff=1408,
    moe_every=1, first_dense=1,
    source="arXiv:2405.04434",
)
