"""Mamba2-2.7B — attention-free SSM, SSD (state-space duality) [arXiv:2405.21060].

d_inner = 2*2560 = 5120, headdim=64 -> 80 SSM heads, d_state=128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256, ssm_ngroups=1,
    source="arXiv:2405.21060",
)
