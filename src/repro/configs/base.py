"""Config system: architecture configs + input-shape registry.

Every assigned architecture gets one file in this package defining a
``ModelConfig`` with the exact dimensions from the assignment sheet (source
paper cited in the file docstring).  ``reduced()`` derives the CPU-smoke
variant (2 layers, d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm | audio | cnn
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 128
    d_ff: int = 0
    vocab_size: int = 0
    norm_eps: float = 1e-5
    rope_theta: float = 1e4
    tie_embeddings: bool = False

    # --- attention variants -------------------------------------------------
    sliding_window: Optional[int] = None   # SWA width (mixtral, gemma3 local)
    global_every: int = 0                  # gemma3: one global layer per block of this size
    mla: bool = False                      # DeepSeek-V2 multi-head latent attention
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                      # per-expert hidden size
    moe_every: int = 1                     # MoE layer every k-th layer
    first_dense: int = 0                   # leading dense layers (deepseek-v2)
    moe_capacity_factor: float = 1.25      # GShard-style capacity (1e9 = no drop)

    # --- SSM (mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_ngroups: int = 1
    conv_width: int = 4

    # --- hybrid (jamba) --------------------------------------------------------
    attn_every: int = 0                    # one attention layer per block of this size

    # --- encoder-decoder (whisper) ---------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0                   # audio frame positions (stub frontend)

    # --- modality frontend stub --------------------------------------------------
    frontend: Optional[str] = None         # 'audio' | 'vision' — embeddings precomputed
    num_image_tokens: int = 0

    # --- source citation -----------------------------------------------------------
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so the embedding shards cleanly over the mesh."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def block_size(self) -> int:
        """Layers per scanned block (repeating pattern period)."""
        if self.family == "hybrid" and self.attn_every:
            return self.attn_every
        if self.global_every:
            return self.global_every
        if self.num_experts and self.moe_every > 1:
            return self.moe_every
        return 1

    # ------------------------------------------------------------------
    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count (embedding included once)."""
        d = self.d_model
        n = 0
        n += self.padded_vocab * d                      # embed
        if not self.tie_embeddings:
            n += self.padded_vocab * d                  # lm head
        for i in range(self.num_layers):
            n += self._layer_params(i, active_only)
        if self.family == "encdec":
            for _ in range(self.encoder_layers):
                # self-attn + mlp (dense)
                n += self._attn_params() + 2 * d * self.d_ff + 4 * d
        return n

    def _attn_params(self) -> int:
        d = self.d_model
        if self.mla:
            q = d * self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
            kv_a = d * (self.kv_lora_rank + self.qk_rope_head_dim)
            kv_b = self.kv_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.v_head_dim)
            o = self.num_heads * self.v_head_dim * d
            return q + kv_a + kv_b + o
        q = d * self.num_heads * self.head_dim
        kv = 2 * d * self.num_kv_heads * self.head_dim
        o = self.num_heads * self.head_dim * d
        return q + kv + o

    def _mlp_params(self, i: int) -> int:
        d = self.d_model
        if self.num_experts and self._is_moe_layer(i):
            e = 3 * d * self.moe_d_ff
            routed = self.num_experts * e
            shared = self.num_shared_experts * e
            router = d * self.num_experts
            return routed + shared + router
        return 3 * d * self.d_ff                         # swiglu

    def _mlp_active_params(self, i: int) -> int:
        d = self.d_model
        if self.num_experts and self._is_moe_layer(i):
            e = 3 * d * self.moe_d_ff
            return (self.top_k + self.num_shared_experts) * e + d * self.num_experts
        return 3 * d * self.d_ff

    def _is_moe_layer(self, i: int) -> bool:
        if not self.num_experts:
            return False
        if i < self.first_dense:
            return False
        return (i % self.moe_every) == (self.moe_every - 1) if self.moe_every > 1 else True

    def _is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid" and self.attn_every:
            # one attention layer per attn_every block (jamba: index 4 of 8; we use mid-block)
            return (i % self.attn_every) == (self.attn_every // 2)
        return True

    def _ssm_params(self) -> int:
        di, ds, nh = self.d_inner, self.ssm_state, self.ssm_nheads
        d = self.d_model
        in_proj = d * (2 * di + 2 * self.ssm_ngroups * ds + nh)
        conv = self.conv_width * (di + 2 * self.ssm_ngroups * ds)
        out = di * d
        return in_proj + conv + out + 2 * nh + di        # A, D, norm

    def _layer_params(self, i: int, active_only: bool) -> int:
        mixer = self._attn_params() if self._is_attn_layer(i) else self._ssm_params()
        mlp = self._mlp_active_params(i) if active_only else self._mlp_params(i)
        if self.family == "encdec":
            mixer += self._attn_params()                 # cross attention
        return mixer + mlp + 4 * self.d_model            # norms

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """CPU-smoke variant: same family/topology, tiny dims."""
        d = min(self.d_model, 256)
        heads = max(2, min(self.num_heads, 4))
        kv = max(1, min(self.num_kv_heads, 2))
        blk = self.block_size()
        layers = max(2, blk) if blk > 1 else 2
        kw = dict(
            num_layers=layers,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=32,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 32) if self.encoder_seq else 0,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else None,
            num_image_tokens=min(self.num_image_tokens, 8),
        )
        if self.mla:
            kw.update(kv_lora_rank=64, qk_rope_head_dim=16, qk_nope_head_dim=32, v_head_dim=32)
        if self.num_experts:
            # no-drop capacity: keeps the smoke variants' prefill/decode
            # exactly consistent (capacity drops depend on group composition)
            kw.update(num_experts=min(self.num_experts, 4),
                      top_k=min(self.top_k, 2),
                      moe_d_ff=min(self.moe_d_ff, 256),
                      first_dense=min(self.first_dense, 1),
                      moe_capacity_factor=1e9)
        if self.ssm_state:
            kw.update(ssm_state=32, ssm_headdim=16, ssm_chunk=16)
        return dataclasses.replace(self, name=self.name + "-smoke", **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",  524_288,    1, "decode"),
}

ARCH_IDS = [
    "internlm2_1_8b", "deepseek_v2_lite_16b", "whisper_medium", "jamba_v0_1_52b",
    "starcoder2_3b", "deepseek_coder_33b", "internvl2_2b", "mamba2_2_7b",
    "gemma3_12b", "mixtral_8x22b",
]

# archs allowed to lower long_500k (sub-quadratic / windowed decode)
LONG_CONTEXT_ARCHS = {"jamba_v0_1_52b", "mamba2_2_7b", "gemma3_12b", "mixtral_8x22b"}


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether (arch, shape) is part of the dry-run matrix; reason if not."""
    arch = cfg.name.replace("-", "_").replace(".", "_")
    if shape.name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, "full-attention arch: long_500k skipped per DESIGN.md §4"
    return True, ""
