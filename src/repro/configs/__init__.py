from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    LONG_CONTEXT_ARCHS,
    InputShape,
    ModelConfig,
    get_config,
    shape_applicable,
)
from repro.configs.paper_cnns import CIFAR_QUICK, LENET, ALEXNET_SMALL, PAPER_CNNS

__all__ = [
    "ARCH_IDS", "INPUT_SHAPES", "LONG_CONTEXT_ARCHS", "InputShape",
    "ModelConfig", "get_config", "shape_applicable",
    "CIFAR_QUICK", "LENET", "ALEXNET_SMALL", "PAPER_CNNS",
]
