from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    LONG_CONTEXT_ARCHS,
    InputShape,
    ModelConfig,
    get_config,
    shape_applicable,
)
from repro.configs.paper_cnns import CIFAR_QUICK, LENET, ALEXNET_SMALL, PAPER_CNNS
from repro.configs.paper_transformer import ZOO, ZOO_MODELS, ZOO_TIERS, zoo_config

__all__ = [
    "ARCH_IDS", "INPUT_SHAPES", "LONG_CONTEXT_ARCHS", "InputShape",
    "ModelConfig", "get_config", "shape_applicable",
    "CIFAR_QUICK", "LENET", "ALEXNET_SMALL", "PAPER_CNNS",
    "ZOO", "ZOO_MODELS", "ZOO_TIERS", "zoo_config",
]
