"""Gemma3-12B — dense GQA, 5 local (window 1024) : 1 global, 128k context
[hf:google/gemma-3-1b-pt family card].  head_dim=256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=15360, vocab_size=262144, rope_theta=1e6, tie_embeddings=True,
    sliding_window=1024, global_every=6,
    source="hf:google/gemma-3-1b-pt",
)
