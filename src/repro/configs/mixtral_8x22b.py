"""Mixtral-8x22B — sparse MoE (8 experts top-2), GQA, SWA [arXiv:2401.04088].

Assignment specifies SWA; we use window 4096 (Mistral lineage).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768, rope_theta=1e6,
    num_experts=8, top_k=2, moe_d_ff=16384, moe_every=1,
    sliding_window=4096,
    source="arXiv:2401.04088",
)
