"""InternVL2-2B — InternViT + InternLM2 backbone [arXiv:2404.16821].

The vision encoder (InternViT) + MLP projector is a STUB per the carve-out:
``input_specs`` provides precomputed patch embeddings (num_image_tokens,
d_model) that are prepended to the text sequence.  Backbone = InternLM2-1.8B
dims with the VLM's extended vocab (92553).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=92553, rope_theta=1e6,
    frontend="vision", num_image_tokens=256,
    source="arXiv:2404.16821",
)
