"""Batch-selection policies implementing the ``BatchSchedule`` protocol.

Each policy is a frozen (hashable) dataclass holding only static
hyper-parameters — like ``ReduceCtx``, a jitted step specializes on the
policy without retracing — and all of its methods are pure functions over a
device pytree ``state``:

  * ``init(n_batches) -> state``                 (device pytree)
  * ``select(state, step, key) -> (batch_idx, state)``
  * ``update(state, batch_idx, loss) -> state``

Policies:

  * :class:`FCPRSchedule` — the paper's §3.4 fixed cycle ``t = j mod n_b``.
    Stateless (the state carries only ``n_b``), ignores the key, and its
    ``update`` is the identity, so an engine threading it is bit-exact with
    the hard-wired FCPR engines (the dead key/fold-in is pruned by XLA).
  * :class:`LossPropSchedule` — loss-proportional importance sampling in
    the spirit of Katharopoulos & Fleuret (2017), at batch granularity:
    sample batch i with probability ``(1-ε)·s_i/Σs + ε/n_b`` where ``s`` is
    the (min-shifted) EMA-smoothed per-batch loss table.  The ε-uniform
    mixture floors every batch at ``ε/n_b`` per draw, so no batch starves.
  * :class:`RankSchedule` — Loshchilov & Hutter (2015) online batch
    selection: batches ranked by table loss (descending), selection
    probability decaying exponentially with rank so that
    ``p_top/p_bottom = pressure``; an optional ε-uniform floor composes the
    same way.

Both table policies open with one deterministic FCPR sweep (steps
``0..n_b-1`` visit batches ``0..n_b-1``) so every table slot holds a real
loss before sampling starts — the same warm-up epoch the SPC control chart
already spends building its window (``limit=+inf`` until ``n_b`` pushes),
and the fill order ``control.push_at`` requires.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class FCPRSchedule:
    """Fixed cycle ``t = j mod n_b`` (paper §3.4) as a schedule policy."""

    #: FCPR keeps the FIFO loss queue ("one window = one epoch" holds).
    uses_table = False

    def init(self, n_batches: int):
        return {"n_b": jnp.asarray(n_batches, jnp.int32)}

    def select(self, state, step, key):
        del key                           # deterministic: identity from index
        return jnp.asarray(step, jnp.int32) % state["n_b"], state

    def update(self, state, batch_idx, loss):
        return state


@dataclass(frozen=True)
class _TableSchedule:
    """Shared state/update for table-driven policies: an EMA-smoothed
    per-batch loss table + visit counters, FCPR-swept for one warm-up epoch.

    ``uses_table=True`` tells the scheduled engine to write the SPC loss
    queue per *batch* (``control.push_at``) instead of FIFO — under a
    non-FCPR visit order the FIFO window no longer means "one epoch", so
    the control chart takes its ψ̄/σ statistics from the per-batch table
    this policy maintains anyway (see ``repro.sched`` module doc).
    """

    #: EMA smoothing for the table: ``new = (1-beta)*old + beta*loss``.
    beta: float = 0.5
    #: uniform mixing weight — P(select i) ≥ eps/n_b every post-warm-up draw.
    eps: float = 0.1

    uses_table = True

    def init(self, n_batches: int):
        return {"table": jnp.zeros((n_batches,), jnp.float32),
                "visits": jnp.zeros((n_batches,), jnp.int32)}

    def _scores(self, table):
        raise NotImplementedError

    def select(self, state, step, key):
        table = state["table"]
        n_b = table.shape[0]
        p = self._scores(table)
        p = (1.0 - self.eps) * p + self.eps / n_b
        drawn = jax.random.categorical(key, jnp.log(p))
        step = jnp.asarray(step, jnp.int32)
        # warm-up epoch: deterministic FCPR sweep fills the table in order
        t = jnp.where(step < n_b, step % n_b, drawn.astype(jnp.int32))
        return t, state

    def update(self, state, batch_idx, loss):
        table, visits = state["table"], state["visits"]
        loss = jnp.asarray(loss, jnp.float32)
        old = table[batch_idx]
        seen = visits[batch_idx] > 0
        new = jnp.where(seen, (1.0 - self.beta) * old + self.beta * loss,
                        loss)
        return {"table": table.at[batch_idx].set(new),
                "visits": visits.at[batch_idx].add(1)}


@dataclass(frozen=True)
class LossPropSchedule(_TableSchedule):
    """Sample ∝ smoothed per-batch loss (min-shifted so the distribution is
    scale- and offset-robust), ε-uniform mixed."""

    def _scores(self, table):
        n_b = table.shape[0]
        s = table - jnp.min(table)
        total = jnp.sum(s)
        # all-equal table (e.g. warm-up zeros) -> uniform
        return jnp.where(total > 0.0, s / jnp.maximum(total, 1e-30),
                         1.0 / n_b)


@dataclass(frozen=True)
class RankSchedule(_TableSchedule):
    """Exponential-decay ranking (Loshchilov & Hutter 2015): sort batches by
    table loss descending; p(rank r) ∝ exp(-r·ln(pressure)/n_b), i.e. the
    top-ranked batch is ``pressure``× as likely as the bottom one."""

    #: selection pressure s_e — p_top / p_bottom.
    pressure: float = 100.0
    eps: float = 0.0                      # exp decay is already > 0 everywhere

    def _scores(self, table):
        n_b = table.shape[0]
        order = jnp.argsort(-table)           # rank 0 = highest loss
        ranks = jnp.zeros((n_b,), jnp.int32).at[order].set(
            jnp.arange(n_b, dtype=jnp.int32))
        # ranks span 0..n_b-1, so the decay rate divides by n_b-1 to make
        # the realized p_top/p_bottom exactly ``pressure``
        rate = jnp.log(self.pressure) / max(n_b - 1, 1)
        return jax.nn.softmax(-rate * ranks.astype(jnp.float32))


_FAMILIES = {"fcpr": FCPRSchedule, "loss-prop": LossPropSchedule,
             "rank": RankSchedule}


def schedule_from_spec(spec: str):
    """Parse a ``--schedule`` CLI spec: ``family[:k=v,...]`` — e.g.
    ``"fcpr"``, ``"loss-prop"``, ``"loss-prop:eps=0.2,beta=0.3"``,
    ``"rank:pressure=50"``."""
    family, _, rest = spec.partition(":")
    cls = _FAMILIES.get(family)
    if cls is None:
        raise ValueError(f"unknown schedule {family!r} "
                         f"(choose from {sorted(_FAMILIES)})")
    kwargs = {}
    for kv in filter(None, rest.split(",")):
        k, sep, v = kv.partition("=")
        if not sep:
            raise ValueError(f"malformed schedule option {kv!r} (want k=v)")
        kwargs[k] = float(v)
    return cls(**kwargs)
