"""Scheduler parity matrix: the scheduled engines vs the hard-wired ones.

Two contracts from the ``repro.sched`` package doc are pinned here, both
driven by a **ψ̄-dependent** ``lr_fn`` (so any schedule-induced drift in the
control statistics breaks the comparison loudly):

  * **FCPR bit-exactness** — threading :class:`FCPRSchedule` through the
    scheduled engines reproduces the pre-scheduler engines EXACTLY:
    per-step vs ``make_train_step`` (host batches), chunked K ∈ {1, 32} vs
    the per-step reference, and the data-parallel per-step + chunked K=4
    legs vs the hard-wired shard_map engine (the hybrid strategies get the
    same treatment in ``repro.distributed.hybrid_parity``);
  * **replicated-deterministic selection** — under ``loss-prop`` every
    data shard draws the same batch index at every step: checked directly
    (a shard_map stacking each shard's draw over the data axis must be
    constant) and end-to-end (the n-device chunked run reproduces the
    1-device run's visit sequence);

plus the device-residency invariant: the chunked ``loss-prop`` engine makes
exactly ``steps / K`` host dispatches — selection, table update and batch
fetch all live inside the fused scan (metrics, including the realized
``batch_idx`` sequence, come back (K,)-stacked in one transfer per chunk).

Usable two ways (same pattern as ``repro.distributed.parity``):

  * in-process: ``run_sched_parity()`` on whatever devices exist;
  * subprocess with a forced device count (the CI acceptance check):

      PYTHONPATH=src python -m repro.sched.parity --devices 8
"""
from __future__ import annotations

import argparse
import os
import sys


def _force_host_devices(n: int) -> None:
    assert "jax" not in sys.modules, "--devices must be set before jax init"
    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip())


def run_sched_parity(steps: int = 32, verbose: bool = False) -> dict:
    """Returns {"ok": bool, "devices": int, "legs": {name: report}, ...}."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core import ISGDConfig
    from repro.data import DeviceRing, FCPRSampler
    from repro.distributed import (make_chunked_data_parallel_step,
                                   make_data_parallel_step)
    from repro.launch.mesh import make_data_mesh
    from repro.optim import momentum
    from repro.sched import FCPRSchedule, LossPropSchedule
    from repro.train import (make_chunked_train_step,
                             make_scheduled_train_step, make_train_step)

    n_dev = len(jax.devices())
    n_batches = 4
    batch_size = 8 * n_dev
    assert steps % 32 == 0 and steps >= 2 * n_batches

    # dim=6: the repo's canonical bit-exact problem size (XLA:CPU compiles
    # straight-line and in-scan step bodies to identical float programs
    # there; wider dims pick up 1-ulp fusion differences)
    dim = 6
    rng = np.random.RandomState(0)
    xs = rng.randn(batch_size * n_batches, dim).astype(np.float32)
    ys = ((xs @ rng.randn(dim, 1).astype(np.float32)).ravel()
          / np.sqrt(dim)).astype(np.float32)
    ys[:batch_size] += 3.0                      # the under-trained batch
    sampler = FCPRSampler({"x": xs, "y": ys}, batch_size=batch_size, seed=1)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, loss

    params0 = {"w": jnp.zeros((dim,), jnp.float32),
               "b": jnp.zeros((), jnp.float32)}
    rule = momentum(0.9)
    icfg = ISGDConfig(n_batches=n_batches, k_sigma=1.0, stop=3, zeta=0.01)

    def lr_fn(psi_bar):
        # ψ̄-dependent on purpose: schedule drift moves the LR trajectory
        return jnp.asarray(0.01) + 0.001 * jnp.minimum(psi_bar, 1.0)

    def drive(step_fn, init_fn, feed):
        p = jax.tree.map(jnp.copy, params0)
        s = init_fn(p)
        ms = []
        for j in range(steps):
            s, p, m = step_fn(s, p, feed(j))
            ms.append(jax.tree.map(np.asarray, m))
        return s, p, {k: np.stack([m[k] for m in ms]) for k in ms[0]}

    def drive_sched(fn, init_fn, schedule, ring, K=None):
        p = jax.tree.map(jnp.copy, params0)
        s = init_fn(p)
        ss = schedule.init(n_batches)
        out = []
        if K is None:
            for j in range(steps):
                s, p, ss, m = fn(s, p, ss, ring.arrays, j)
                out.append(jax.tree.map(np.asarray, m))
            return s, p, {k: np.stack([m[k] for m in out]) for k in out[0]}
        for c in range(steps // K):
            s, p, ss, ms = fn(s, p, ss, ring.arrays, c * K)
            out.append(jax.tree.map(np.asarray, ms))
        return s, p, {k: np.concatenate([o[k] for o in out])
                      for k in out[0]}

    def bit_exact(ref, got):
        r_s, _, r_m = ref
        g_s, _, g_m = got
        ok = all(bool(np.array_equal(r_m[k], g_m[k]))
                 for k in ("loss", "limit", "psi_bar", "accelerated",
                           "sub_iters"))
        dev = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
                  for a, b in zip(jax.tree.leaves(ref[1]),
                                  jax.tree.leaves(got[1])))
        ok &= dev == 0.0
        ok &= int(r_s.accel_count) == int(g_s.accel_count)
        return ok, dev

    legs = {}
    fcpr = FCPRSchedule()
    host = [{k: jnp.asarray(v) for k, v in sampler(j).items()}
            for j in range(steps)]

    # reference: per-step engine on host batches
    init_fn, step = make_train_step(loss_fn, rule, icfg, lr_fn=lr_fn,
                                    donate=False)
    ref = drive(step, init_fn, lambda j: host[j])
    assert ref[2]["accelerated"].sum() > 0, "subproblem never fired"

    ring = DeviceRing(sampler.epoch_arrays(), batch_size)
    sinit, sstep = make_scheduled_train_step(loss_fn, rule, icfg, fcpr,
                                             lr_fn=lr_fn, donate=False)
    ok, dev = bit_exact(ref, drive_sched(sstep, sinit, fcpr, ring))
    legs["sched-fcpr per-step"] = {"ok": ok, "max_param": dev}

    for K in (1, 32):
        cinit, chunk = make_chunked_train_step(
            loss_fn, rule, icfg, chunk_steps=K, lr_fn=lr_fn, donate=False,
            schedule=fcpr)
        ok, dev = bit_exact(ref, drive_sched(chunk, cinit, fcpr, ring, K=K))
        legs[f"sched-fcpr chunked K{K}"] = {"ok": ok, "max_param": dev}

    # data-parallel engine legs (manual shard_map strategy)
    mesh = make_data_mesh()
    dinit, dstep = make_data_parallel_step(loss_fn, rule, icfg, mesh,
                                           lr_fn=lr_fn, donate=False)
    dp = drive(dstep, dinit, lambda j: host[j])
    ring_m = DeviceRing(sampler.epoch_arrays(), batch_size, mesh=mesh)
    sinit, sstep = make_data_parallel_step(loss_fn, rule, icfg, mesh,
                                           lr_fn=lr_fn, donate=False,
                                           schedule=fcpr)
    ok, dev = bit_exact(dp, drive_sched(sstep, sinit, fcpr, ring_m))
    legs["sched-fcpr dp per-step"] = {"ok": ok, "max_param": dev}

    cinit, chunk = make_chunked_data_parallel_step(
        loss_fn, rule, icfg, mesh, chunk_steps=4, lr_fn=lr_fn, donate=False,
        schedule=fcpr)
    ok, dev = bit_exact(dp, drive_sched(chunk, cinit, fcpr, ring_m, K=4))
    legs["sched-fcpr dp chunked K4"] = {"ok": ok, "max_param": dev}

    # loss-prop: per-shard draws must agree at every step (direct check)
    lp = LossPropSchedule(eps=0.2)

    def draws(table, visits, step_arr):
        # each shard draws from the same (replicated) state and step index
        key = jax.random.fold_in(jax.random.PRNGKey(0), step_arr)
        t, _ = lp.select({"table": table, "visits": visits}, step_arr, key)
        return t[None]

    per_shard = shard_map(draws, mesh=mesh, in_specs=(P(), P(), P()),
                          out_specs=P("data"), check_rep=False)
    table = jnp.asarray(rng.rand(n_batches).astype(np.float32)) * 3.0
    visits = jnp.ones((n_batches,), jnp.int32)
    agree = True
    for j in range(n_batches, n_batches + 16):      # post-warm-up draws
        t = np.asarray(per_shard(table, visits, jnp.asarray(j, jnp.int32)))
        agree &= bool((t == t[0]).all())
    legs["loss-prop shard-draw agreement"] = {"ok": agree, "max_param": None}

    # loss-prop: n-device chunked run == 1-device run (selection + ψ)
    K = 8

    def lp_run(mesh_, ring_):
        maker = (make_chunked_data_parallel_step if mesh_ is not None
                 else None)
        if mesh_ is None:
            cinit, chunk = make_chunked_train_step(
                loss_fn, rule, icfg, chunk_steps=K, lr_fn=lr_fn,
                donate=False, schedule=lp)
        else:
            cinit, chunk = maker(loss_fn, rule, icfg, mesh_, chunk_steps=K,
                                 lr_fn=lr_fn, donate=False, schedule=lp)
        calls = [0]
        def counting(*a):
            calls[0] += 1
            return chunk(*a)
        out = drive_sched(counting, cinit, lp, ring_, K=K)
        return out, calls[0]

    one, calls1 = lp_run(None, ring)
    many, calls_n = lp_run(mesh, ring_m)
    same_idx = bool(np.array_equal(one[2]["batch_idx"],
                                   many[2]["batch_idx"]))
    # ψ agrees to reduction-reassociation tolerance (f32 pmean vs global)
    finite = np.isfinite(one[2]["loss"])
    close = bool(np.allclose(one[2]["loss"][finite],
                             many[2]["loss"][finite], atol=1e-5, rtol=1e-5))
    legs["loss-prop 1-vs-n-device selection"] = {
        "ok": same_idx and close, "max_param": None}

    # device residency: one host dispatch per K-step chunk, no per-step sync
    legs["loss-prop dispatches = steps/K"] = {
        "ok": calls1 == steps // K and calls_n == steps // K,
        "max_param": None}
    legs["loss-prop visits all batches"] = {
        "ok": bool((np.bincount(one[2]["batch_idx"],
                                minlength=n_batches) > 0).all()),
        "max_param": None}

    ok = all(leg["ok"] for leg in legs.values())
    if verbose:
        for name, leg in legs.items():
            print(f"  {name:34s} ok={leg['ok']} "
                  f"max_param={leg['max_param']}")
    return {"ok": ok, "devices": n_dev, "steps": steps,
            "accelerations": int(ref[2]["accelerated"].sum()), "legs": legs}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=0,
                    help="force this many XLA host-platform devices "
                         "(0 = use whatever XLA_FLAGS already provides)")
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    if args.devices:
        _force_host_devices(args.devices)
    r = run_sched_parity(steps=args.steps, verbose=args.verbose)
    bad = [n for n, leg in r["legs"].items() if not leg["ok"]]
    print(f"sched-parity devices={r['devices']} steps={r['steps']} "
          f"accelerations={r['accelerations']} legs={len(r['legs'])} "
          f"failed={bad or 'none'} -> {'OK' if r['ok'] else 'FAIL'}")
    if r["accelerations"] == 0:
        print("sched-parity WARNING: subproblem never fired")
        return 2
    return 0 if r["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
