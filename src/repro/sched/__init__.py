"""Pluggable device-resident batch scheduling.

ISGD's premise is that batches deserve *inconsistent* treatment.  The paper
varies per-batch **effort** (Alg. 2 spends extra sub-iterations on
under-trained batches); the related work varies per-batch **selection** —
loss-proportional importance sampling (Katharopoulos & Fleuret, 2017) and
rank-based online batch selection (Loshchilov & Hutter, 2015).  This package
makes batch *identity* a policy instead of the hard-wired FCPR
``t = j mod n_b``, while keeping the device-resident, fused-scan fast path:
selection runs **inside** the jitted step, so a batch fetch is a
``dynamic_slice`` of the :class:`~repro.data.device_ring.DeviceRing` epoch
at a traced index — no host round-trip, one dispatch per K-step chunk.

The ``BatchSchedule`` protocol (three pure functions over a device pytree;
policies themselves are frozen, hashable dataclasses of static
hyper-parameters, so jitted engines specialize without retracing):

  * ``init(n_batches) -> state`` — a device pytree (loss table, visit
    counters, ...);
  * ``select(state, step, key) -> (batch_idx, state)`` — draw the batch for
    ``step``; ``key`` is ``fold_in(base, step)``, a pure function of the
    replicated step index, so every data shard draws the same index;
  * ``update(state, batch_idx, loss) -> state`` — feed back the realized
    batch loss; engines pass the *globally reduced* ψ (the same scalar the
    SPC controller monitors), so the table stays replicated across shards.

FCPR bit-exactness contract: :class:`FCPRSchedule` threaded through a
scheduled engine reproduces the hard-wired engines **bit-exactly** — same
losses, limits, accelerate decisions, sub-iteration counts, and final
params.  Its ``select`` is the same integer ``mod``, its ``update`` is the
identity, it ignores the RNG key (dead code to XLA), and it keeps the FIFO
queue push — so the traced step computation is the pre-scheduler one.  The
parity matrices (``repro.sched.parity``, ``repro.distributed.
hybrid_parity``) pin this with a ψ̄-dependent ``lr_fn``.

ψ-window caveat (SPC semantics under non-FCPR schedules): the control
chart's "one window = one epoch" reading of the loss queue (core/control.py)
holds *because* FCPR visits each batch exactly once per n_b steps.  Under
loss-prop/rank selection the last n_b losses oversample hot batches, which
would bias ψ̄ upward and inflate the limit with duplicate entries.  Table
policies therefore set ``uses_table=True``: the step writes the loss queue
**per batch** (``control.push_at`` at slot ``batch_idx``) instead of FIFO,
so the queue *is* the per-batch latest-loss table and ψ̄ + kσ are computed
over one entry per batch — the window means "one (virtual) epoch" again.
Warm-up is unchanged: the policies' first-epoch FCPR sweep fills the table
in slot order, and the limit stays +inf until all ``n_b`` slots are seen.
"""
from __future__ import annotations

import importlib

# lazy, like repro.distributed: ``python -m repro.sched.parity --devices N``
# must set the XLA device-count flag before anything imports jax, and this
# package is imported before the parity submodule runs.
_EXPORTS = {
    "FCPRSchedule": "repro.sched.policies",
    "LossPropSchedule": "repro.sched.policies",
    "RankSchedule": "repro.sched.policies",
    "schedule_from_spec": "repro.sched.policies",
    "make_scheduled_body": "repro.sched.engine",
    "chunk_over_schedule": "repro.sched.engine",
    "run_sched_parity": "repro.sched.parity",
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)


def __dir__():
    return sorted(_EXPORTS)
