"""Device-resident scheduled step/chunk bodies.

``make_scheduled_body`` turns the un-jitted per-step ISGD body
(``train.trainer.make_step_core``) into a body that *selects* its batch on
device: draw ``t`` from the policy, fetch batch ``t`` as a
``dynamic_slice`` of the epoch ring arrays, run the step, feed the (already
globally-reduced) batch loss back to the policy.  Selection therefore
composes with :class:`~repro.data.device_ring.DeviceRing` and the fused
``lax.scan`` chunk engine with zero per-step host involvement — the host
dispatches once per chunk exactly as in ``repro.train.chunked``.

Determinism across data shards: the selection key is
``fold_in(PRNGKey(seed), step)`` — a pure function of the (replicated) step
index — and the loss driving ``update`` is the reduce-ctx-reduced ψ, so
under the manual shard_map strategy every shard derives the same key, sees
the same table, and draws the same index; under GSPMD there is only one
logical program.  The same argument that makes the accelerate ``cond``
branch identically on every device (core/reduce.py) covers the scheduler.

SPC coupling: for ``uses_table`` policies the step writes the loss queue at
slot ``t`` (``control.push_at``) instead of FIFO, so the control chart's
ψ̄/σ/limit read the per-batch loss table — see the ``repro.sched`` package
doc for why.  FCPR keeps the FIFO push, bit-exactly the pre-scheduler step.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def selection_counts(batch_idx, n_batches: int) -> np.ndarray:
    """Visit histogram over batches from a realized ``batch_idx`` sequence
    (a chunk's stacked metrics, or a whole run's) — the obs layer and the
    launch drivers share this one definition."""
    return np.bincount(np.asarray(batch_idx).ravel().astype(np.int64),
                       minlength=n_batches)


def make_scheduled_body(step_fn: Callable, schedule, n_batches: int,
                        seed: int = 0):
    """Wrap an un-jitted ``step_fn(state, params, batch, lr=None, slot=None)``
    into ``body(state, params, sched_state, ring_arrays, j) -> (state,
    params, sched_state, metrics)`` with on-device selection.

    ``ring_arrays`` is a dict of epoch arrays with ``n_batches *
    batch_size`` leading rows (a ``DeviceRing``'s ``.arrays``, or its local
    shard inside ``shard_map``); ``j`` is the global step index.  Metrics
    gain ``batch_idx`` — the selected batch, stacked per step by the chunk
    engine so drivers can log the realized visit sequence without extra
    fetches.
    """
    base_key = jax.random.PRNGKey(seed)

    def body(state, params, sched_state, ring_arrays, j):
        j = jnp.asarray(j, jnp.int32)
        key = jax.random.fold_in(base_key, j)
        t, sched_state = schedule.select(sched_state, j, key)
        bs = next(iter(ring_arrays.values())).shape[0] // n_batches
        batch = {k: jax.lax.dynamic_slice_in_dim(v, t * bs, bs)
                 for k, v in ring_arrays.items()}
        slot = t if schedule.uses_table else None
        state, params, metrics = step_fn(state, params, batch, slot=slot)
        sched_state = schedule.update(sched_state, t, metrics["loss"])
        metrics = dict(metrics, batch_idx=t)
        return state, params, sched_state, metrics

    return body


def chunk_over_schedule(step_fn: Callable, schedule, n_batches: int,
                        chunk_steps: int, seed: int = 0):
    """Scheduled twin of ``train.chunked.chunk_over_ring``: K policy-selected
    ISGD steps per dispatch.

    Returns ``chunk_fn(state, params, sched_state, ring_arrays, j0) ->
    (state, params, sched_state, stacked_metrics)`` — the schedule state
    rides the scan carry next to ``(state, params)``, so table updates from
    step ``j`` steer the selection at step ``j+1`` inside the same chunk.
    """
    assert chunk_steps >= 1
    body = make_scheduled_body(step_fn, schedule, n_batches, seed)

    def chunk_fn(state, params, sched_state, ring_arrays, j0):
        j0 = jnp.asarray(j0, jnp.int32)

        def scan_body(carry, off):
            state, params, sched_state = carry
            state, params, sched_state, metrics = body(
                state, params, sched_state, ring_arrays, j0 + off)
            return (state, params, sched_state), metrics

        with jax.named_scope("obs/chunk_scan"):
            (state, params, sched_state), stacked = jax.lax.scan(
                scan_body, (state, params, sched_state),
                jnp.arange(chunk_steps, dtype=jnp.int32))
        return state, params, sched_state, stacked

    return chunk_fn
