from repro.optim.base import RULES, UpdateRule, momentum, nesterov, sgd

__all__ = ["RULES", "UpdateRule", "sgd", "momentum", "nesterov"]
