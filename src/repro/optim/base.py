"""Base first-order update rules (the paper's baselines, §2/§4.3).

Each rule is a pair of pure functions so ISGD can wrap any of them by
swapping only the base update (paper Alg.1 line 21):

  init(params)                         -> state
  apply(state, params, grads, lr)     -> (state, params)

Update rules follow the paper's equations exactly:
  SGD       w' = w - lr * g                              (Eq. 4)
  Momentum  v' = mu*v - lr*g ; w' = w + v'               (Eq. 19)
  Nesterov  v' = mu*v - lr*g(w + mu*v) ; w' = w + v'     (Eq. 20)

Nesterov is implemented in the standard "lookahead-free" transformed form so
the gradient is always evaluated at the current iterate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


def _tree_update(params, grads, fn):
    return jax.tree.map(fn, params, grads)


@dataclass(frozen=True)
class UpdateRule:
    name: str
    init: Callable
    apply: Callable          # (state, params, grads, lr) -> (state, params)


def sgd(weight_decay: float = 0.0) -> UpdateRule:
    def init(params):
        return ()

    def apply(state, params, grads, lr):
        def upd(w, g):
            g = g.astype(jnp.float32) + weight_decay * w.astype(jnp.float32)
            return (w.astype(jnp.float32) - lr * g).astype(w.dtype)
        return state, _tree_update(params, grads, upd)

    return UpdateRule("sgd", init, apply)


def momentum(mu: float = 0.9, weight_decay: float = 0.0) -> UpdateRule:
    def init(params):
        return jax.tree.map(lambda w: jnp.zeros(w.shape, jnp.float32), params)

    def apply(vel, params, grads, lr):
        def upd_v(v, g, w):
            g = g.astype(jnp.float32) + weight_decay * w.astype(jnp.float32)
            return mu * v - lr * g
        new_vel = jax.tree.map(upd_v, vel, grads, params)
        new_params = jax.tree.map(
            lambda w, v: (w.astype(jnp.float32) + v).astype(w.dtype),
            params, new_vel)
        return new_vel, new_params

    return UpdateRule("momentum", init, apply)


def nesterov(mu: float = 0.9, weight_decay: float = 0.0) -> UpdateRule:
    """Nesterov accelerated gradient in the Sutskever transformed form:
    v' = mu*v - lr*g(w);  w' = w + mu*v' - lr*g(w)."""
    def init(params):
        return jax.tree.map(lambda w: jnp.zeros(w.shape, jnp.float32), params)

    def apply(vel, params, grads, lr):
        def upd(w, v, g):
            g = g.astype(jnp.float32) + weight_decay * w.astype(jnp.float32)
            v_new = mu * v - lr * g
            w_new = w.astype(jnp.float32) + mu * v_new - lr * g
            return w_new.astype(w.dtype), v_new
        out = jax.tree.map(upd, params, vel, grads)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_vel = jax.tree.map(lambda t: t[1], out,
                               is_leaf=lambda t: isinstance(t, tuple))
        return new_vel, new_params

    return UpdateRule("nesterov", init, apply)


def adagrad(eps: float = 1e-8, weight_decay: float = 0.0) -> UpdateRule:
    """Duchi et al. — the adaptive baseline the paper contrasts with (§2).
    ISGD composes with it like any base rule: the controller adjusts the
    FREQUENCY of a batch's updates, Adagrad the per-parameter magnitude."""
    def init(params):
        return jax.tree.map(lambda w: jnp.zeros(w.shape, jnp.float32), params)

    def apply(acc, params, grads, lr):
        def upd(a, w, g):
            g = g.astype(jnp.float32) + weight_decay * w.astype(jnp.float32)
            a_new = a + g * g
            w_new = w.astype(jnp.float32) - lr * g / (jnp.sqrt(a_new) + eps)
            return a_new, w_new.astype(w.dtype)
        out = jax.tree.map(upd, acc, params, grads)
        new_acc = jax.tree.map(lambda t: t[0], out,
                               is_leaf=lambda t: isinstance(t, tuple))
        new_params = jax.tree.map(lambda t: t[1], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        return new_acc, new_params

    return UpdateRule("adagrad", init, apply)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> UpdateRule:
    """AdamW-style decoupled weight decay; state = (m, v, t)."""
    def init(params):
        zeros = lambda w: jnp.zeros(w.shape, jnp.float32)   # noqa: E731
        return (jax.tree.map(zeros, params), jax.tree.map(zeros, params),
                jnp.zeros((), jnp.int32))

    def apply(state, params, grads, lr):
        m, v, t = state
        t = t + 1
        bc1 = 1.0 - b1 ** t.astype(jnp.float32)
        bc2 = 1.0 - b2 ** t.astype(jnp.float32)

        def upd(mi, vi, w, g):
            g = g.astype(jnp.float32)
            mi = b1 * mi + (1 - b1) * g
            vi = b2 * vi + (1 - b2) * g * g
            step = lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
            w_new = w.astype(jnp.float32) - step \
                - lr * weight_decay * w.astype(jnp.float32)
            return mi, vi, w_new.astype(w.dtype)

        out = jax.tree.map(upd, m, v, params, grads)
        pick = lambda i: jax.tree.map(lambda tpl: tpl[i], out,   # noqa: E731
                                      is_leaf=lambda x: isinstance(x, tuple))
        return (pick(0), pick(1), t), pick(2)

    return UpdateRule("adam", init, apply)


RULES = {"sgd": sgd, "momentum": momentum, "nesterov": nesterov,
         "adagrad": adagrad, "adam": adam}
