"""Block-size selection shared by the Pallas kernels.

The benches only ever drove the kernels at 128-aligned shapes; training
bodies produce whatever ``B·S`` / seq / vocab the config family dictates.
``divisor_tile`` keeps the kernels' "tiles divide the axis" invariant by
shrinking the requested tile to the largest divisor of the axis length,
preferring MXU-aligned (multiple-of-``align``) candidates — on TPU the
config families are sized so an aligned divisor exists; the unaligned
fallback keeps ragged CPU/CI shapes correct (interpret mode has no MXU to
starve).
"""
from __future__ import annotations


def divisor_tile(n: int, want: int, align: int = 128) -> int:
    """Largest tile <= min(want, n) dividing n, preferring multiples of
    ``align``."""
    assert n >= 1 and want >= 1
    want = min(want, n)
    for b in range(want - want % align, 0, -align):
        if n % b == 0:
            return b
    b = want
    while n % b:
        b -= 1
    return b
