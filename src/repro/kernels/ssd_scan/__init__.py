from repro.kernels.ssd_scan.kernel import ssd_intra_chunk
from repro.kernels.ssd_scan.ops import ssd_chunked_pallas
from repro.kernels.ssd_scan.ref import ssd_ref

__all__ = ["ssd_intra_chunk", "ssd_chunked_pallas", "ssd_ref"]
