"""Mamba2 SSD intra-chunk Pallas kernel.

Per grid cell (batch·chunk, head) the kernel computes, entirely in VMEM:
  * the decay matrix L[i,j] = exp(cumsum(dA)_i − cumsum(dA)_j) (i ≥ j),
  * the diagonal-block output Y_diag = ((C·Bᵀ) ⊙ L) · (x·dt),
  * the chunk's boundary state  S = Σ_j exp(cum_last − cum_j)·(x·dt)_j ⊗ B_j,
  * the chunk decay exp(cum_last).
The O(S/chunk)-step inter-chunk recurrence runs in ops.py as a lax.scan over
these per-chunk outputs (it is tiny: (nh, hd, ds) per step).

Block shapes: x (cl, hd), B/C (cl, ds) — with cl=chunk≤256, hd=64, ds=128
everything is 128-lane friendly and the three matmuls hit the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                y_ref, state_ref, decay_ref, *, cl: int):
    x = x_ref[0, :, 0, :].astype(jnp.float32)   # (cl, hd)
    dt = dt_ref[0, :, 0].astype(jnp.float32)    # (cl,)
    A = a_ref[0]                                # scalar for this head
    B = b_ref[0, :, 0, :].astype(jnp.float32)   # (cl, ds)
    C = c_ref[0, :, 0, :].astype(jnp.float32)   # (cl, ds)

    dA = dt * A                                 # (cl,)
    cum = jnp.cumsum(dA)
    diff = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 1)
    L = jnp.where(tri, jnp.exp(diff), 0.0)

    xdt = x * dt[:, None]                       # (cl, hd)
    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (cl, cl)
    y = jax.lax.dot_general(CB * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (cl, hd)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    w = jnp.exp(cum[-1] - cum)                  # (cl,)
    state = jax.lax.dot_general(xdt * w[:, None], B, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (hd, ds)
    state_ref[0, 0] = state
    decay_ref[...] = jnp.exp(cum[-1]).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(x, dt, A, B, C, *, interpret: bool = True):
    """x: (N, cl, nh, hd); dt: (N, cl, nh); A: (nh,); B/C: (N, cl, nh, ds)
    (groups pre-broadcast to heads).  N = batch·n_chunks.

    Returns (y_diag (N, cl, nh, hd) f32, states (N, nh, hd, ds) f32,
    decays (N, nh) f32)."""
    N, cl, nh, hd = x.shape
    ds = B.shape[-1]
    grid = (N, nh)
    y, states, decays = pl.pallas_call(
        functools.partial(_ssd_kernel, cl=cl),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cl, 1, hd), lambda n, h: (n, 0, h, 0)),
            pl.BlockSpec((1, cl, 1), lambda n, h: (n, 0, h)),
            pl.BlockSpec((1,), lambda n, h: (h,)),
            pl.BlockSpec((1, cl, 1, ds), lambda n, h: (n, 0, h, 0)),
            pl.BlockSpec((1, cl, 1, ds), lambda n, h: (n, 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cl, 1, hd), lambda n, h: (n, 0, h, 0)),
            pl.BlockSpec((1, 1, hd, ds), lambda n, h: (n, h, 0, 0)),
            pl.BlockSpec((1, 1), lambda n, h: (n, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, cl, nh, hd), jnp.float32),
            jax.ShapeDtypeStruct((N, nh, hd, ds), jnp.float32),
            jax.ShapeDtypeStruct((N, nh), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A, B, C)
    return y, states, decays
