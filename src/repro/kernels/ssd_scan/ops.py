"""Jit'd wrapper: full SSD forward = Pallas intra-chunk kernel + lax.scan
inter-chunk recurrence + off-diagonal contribution.

``ssd_chunked_pallas`` is trainable: the forward runs the Pallas kernel,
the backward differentiates the block-matmul reference (``models.ssm.
ssd_chunked`` — the same chunk decomposition, so the recompute cost matches
a flash-style backward; a fused bwd kernel is the TPU follow-up)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_intra_chunk


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _ssd_pallas_fwd(x, dt, A, B, C, chunk: int):
    """Same contract as models.ssm.ssd_chunked.

    x: (b, S, nh, hd); dt: (b, S, nh); A: (nh,); B/C: (b, S, G, ds).
    -> (y (b, S, nh, hd) f32, final_state (b, nh, hd, ds) f32)
    """
    b, S, nh, hd = x.shape
    G, ds = B.shape[-2], B.shape[-1]
    cl = min(chunk, S)
    while S % cl:                 # largest dividing chunk <= requested
        cl -= 1
    nc = S // cl
    rep = nh // G

    Bh = jnp.repeat(B, rep, axis=-2)
    Ch = jnp.repeat(C, rep, axis=-2)
    xr = x.reshape(b * nc, cl, nh, hd)
    dtr = dt.reshape(b * nc, cl, nh)
    Br = Bh.reshape(b * nc, cl, nh, ds)
    Cr = Ch.reshape(b * nc, cl, nh, ds)

    y_diag, states, decays = ssd_intra_chunk(
        xr, dtr, A, Br, Cr, interpret=_use_interpret())
    y_diag = y_diag.reshape(b, nc, cl, nh, hd)
    states = states.reshape(b, nc, nh, hd, ds)
    decays = decays.reshape(b, nc, nh)

    def step(state, inp):
        s_n, d_n = inp
        new = state * d_n[..., None, None] + s_n
        return new, state

    final_state, prevs = jax.lax.scan(
        step, jnp.zeros((b, nh, hd, ds), jnp.float32),
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(decays, 1, 0)))
    prevs = jnp.moveaxis(prevs, 0, 1)                      # (b, nc, nh, hd, ds)

    # off-diagonal: Y_off[i] = C_i · prev_state · exp(cum_i)
    dA = (dtr * A).reshape(b, nc, cl, nh)
    cum = jnp.cumsum(jnp.moveaxis(dA, -1, -2), axis=-1)     # (b, nc, nh, cl)
    Y_off = jnp.einsum("bnihd,bnhpd,bnhi->bnihp",
                       Cr.reshape(b, nc, cl, nh, ds).astype(jnp.float32),
                       prevs, jnp.exp(cum))
    y = (y_diag + Y_off).reshape(b, S, nh, hd)
    return y, final_state


@partial(jax.custom_vjp, nondiff_argnums=(5,))
def _ssd(x, dt, A, B, C, chunk):
    return _ssd_pallas_fwd(x, dt, A, B, C, chunk)


def _ssd_fwd(x, dt, A, B, C, chunk):
    return _ssd(x, dt, A, B, C, chunk), (x, dt, A, B, C)


def _ssd_bwd(chunk, res, g):
    x, dt, A, B, C = res
    from repro.models.ssm import ssd_chunked   # lazy: models lazily import us
    _, vjp = jax.vjp(
        lambda x_, dt_, A_, B_, C_: ssd_chunked(x_, dt_, A_, B_, C_,
                                                chunk=chunk),
        x, dt, A, B, C)
    return vjp(g)


_ssd.defvjp(_ssd_fwd, _ssd_bwd)


def ssd_chunked_pallas(x, dt, A, B, C, *, chunk: int):
    """Trainable surface — see module docstring; contract of ``_ssd_pallas_fwd``."""
    return _ssd(x, dt, A, B, C, chunk)
