"""Pure-jnp oracle for the SSD kernel: repro.models.ssm.ssd_chunked is the
reference implementation; re-exported here so kernel tests read naturally."""
from repro.models.ssm import ssd_chunked as ssd_ref

__all__ = ["ssd_ref"]
