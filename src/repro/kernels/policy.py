"""The ``--kernels`` switch: which implementation backs the model hot spots.

Three requestable modes, two effective paths:

  * ``reference`` — the pure-XLA ``ref.py`` paths (chunked-scan attention,
    scanned cross-entropy, SSD block matmuls).  Always available.
  * ``pallas``    — the Pallas kernels (flash_attention, fused_xent,
    ssd_scan), lowered to Mosaic.  Only real on a TPU backend: everywhere
    else this resolves to ``reference`` — interpret mode is a correctness
    harness (measured ~1000x slower than the reference paths on CPU, see
    kernels/README.md), not a training path.
  * ``interpret`` — force the Pallas kernels in interpret mode regardless
    of backend.  The numerics gate (``repro.kernels.numerics``) and the
    kernel-leg of ``repro.train.zoo_parity`` use this to prove the kernel
    step body agrees with the reference step body on CPU CI.

``resolve_kernels`` is called once at ``build_model`` time (backend choice
is process-static), so the fallback never branches inside a traced step.
"""
from __future__ import annotations

import jax

KERNEL_CHOICES = ("pallas", "reference", "interpret")


def resolve_kernels(kernels: str) -> str:
    """-> effective mode: 'pallas' | 'reference' | 'interpret'."""
    if kernels not in KERNEL_CHOICES:
        raise ValueError(f"kernels must be one of {KERNEL_CHOICES}, "
                         f"got {kernels!r}")
    if kernels == "pallas" and jax.default_backend() != "tpu":
        return "reference"
    return kernels


def kernels_note(requested: str, resolved: str) -> str:
    """One-line provenance for launcher logs."""
    if requested == resolved:
        return f"kernels: {resolved}"
    return (f"kernels: {requested} -> {resolved} (Pallas lowering needs a "
            f"TPU backend; ref.py fallback — see kernels/README.md)")
