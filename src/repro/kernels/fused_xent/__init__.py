from repro.kernels.fused_xent.kernel import fused_xent
from repro.kernels.fused_xent.ops import fused_xent_sum, xent_ref_sum
from repro.kernels.fused_xent.ref import xent_ref

__all__ = ["fused_xent", "fused_xent_sum", "xent_ref", "xent_ref_sum"]
