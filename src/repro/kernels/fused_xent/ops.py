"""Jit'd wrapper: model-facing fused cross-entropy.

On CPU (this container) the kernel runs in interpret mode; on TPU it lowers
to Mosaic.  ``fused_xent_sum`` is the surface ``lm_loss_fn`` consumes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fused_xent.kernel import fused_xent
from repro.kernels.fused_xent.ref import xent_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.custom_vjp, nondiff_argnums=(4,))
def fused_xent_sum(h, w, labels, mask, vocab_size: int):
    """h: (B,S,d); w: (d,Vp); labels/mask: (B,S) -> (sum_nll, sum_mask).

    Forward runs the Pallas streaming kernel; backward uses the analytic
    softmax gradient (p − onehot) computed in sequence chunks (a bwd kernel
    is the TPU follow-up; the fwd kernel is the ISGD hot path since the
    controller and the Alg.2 early-stop check only need ψ)."""
    return _fwd_value(h, w, labels, mask, vocab_size)


def _fwd_value(h, w, labels, mask, vocab_size):
    B, S, d = h.shape
    N = B * S
    nll = fused_xent(h.reshape(N, d), w, labels.reshape(N),
                     vocab_size=vocab_size, interpret=_use_interpret())
    m = mask.reshape(N).astype(jnp.float32)
    return jnp.sum(nll * m), jnp.sum(m)


def _fwd(h, w, labels, mask, vocab_size):
    out = _fwd_value(h, w, labels, mask, vocab_size)
    return out, (h, w, labels, mask)


def _bwd(vocab_size, res, g):
    h, w, labels, mask = res
    g_tot, _ = g
    B, S, d = h.shape
    Vp = w.shape[1]
    c = S
    while c > 512 and c % 2 == 0:
        c //= 2
    n = S // c

    def chunk(i):
        hs = jax.lax.dynamic_slice_in_dim(h, i * c, c, axis=1)
        ys = jax.lax.dynamic_slice_in_dim(labels, i * c, c, axis=1)
        ms = jax.lax.dynamic_slice_in_dim(mask, i * c, c, axis=1)
        logits = (hs.astype(jnp.float32) @ w.astype(jnp.float32))
        if vocab_size != Vp:
            vmask = jnp.arange(Vp) < vocab_size
            logits = jnp.where(vmask, logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        delta = p - jax.nn.one_hot(ys, Vp, dtype=jnp.float32)
        delta *= (ms.astype(jnp.float32) * g_tot)[..., None]
        dh = (delta @ w.astype(jnp.float32).T).astype(h.dtype)
        dw = jnp.einsum("bsd,bsv->dv", hs.astype(jnp.float32), delta)
        return dh, dw

    def body(carry, i):
        dw_acc = carry
        dh_c, dw_c = chunk(i)
        return dw_acc + dw_c, dh_c

    dw, dhs = jax.lax.scan(body, jnp.zeros((d, Vp), jnp.float32),
                           jnp.arange(n))
    dh = jnp.moveaxis(dhs, 0, 1).reshape(B, S, d)      # (n,B,c,d) -> (B,S,d)
    return dh, dw.astype(w.dtype), None, None


fused_xent_sum.defvjp(_fwd, _bwd)


def xent_ref_sum(h, w, labels, mask, vocab_size: int):
    B, S, d = h.shape
    N = B * S
    nll = xent_ref(h.reshape(N, d), w, labels.reshape(N), vocab_size=vocab_size)
    m = mask.reshape(N).astype(jnp.float32)
    return jnp.sum(nll * m), jnp.sum(m)
