"""Pure-jnp oracle for the fused cross-entropy kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def xent_ref(h, w, labels, *, vocab_size: int):
    """h: (N, d); w: (d, Vp); labels: (N,) -> nll (N,) f32."""
    logits = (h.astype(jnp.float32) @ w.astype(jnp.float32))
    Vp = logits.shape[-1]
    if vocab_size != Vp:
        mask = jnp.arange(Vp) < vocab_size
        logits = jnp.where(mask, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lse - gold
