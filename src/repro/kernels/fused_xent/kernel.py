"""Fused softmax cross-entropy Pallas kernel.

Computes nll[i] = logsumexp_j(h[i]·W[:,j]) − h[i]·W[:,label[i]] WITHOUT
materializing the (tokens, vocab) logits: the grid streams vocab tiles
(minor axis) through VMEM, maintaining an online (max, sumexp, gold)
accumulator per token tile.  This is the ISGD hot spot — a loss is needed
every iteration (and up to ``stop`` more inside the subproblem), and at
gemma3's 262k vocab the naive path writes B·S·V logits to HBM twice.

Tiling: token tile ``bn`` × vocab tile ``bv`` (both 128-aligned for the MXU);
the h tile (bn, d) stays resident in VMEM across the vocab sweep
(index_map ignores the vocab grid coordinate).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import divisor_tile


def _xent_kernel(h_ref, w_ref, label_ref, out_ref, m_ref, s_ref, g_ref,
                 *, bv: int, vocab_size: int):
    vi = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(vi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        s_ref[...] = jnp.zeros_like(s_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    h = h_ref[...].astype(jnp.float32)            # (bn, d)
    w = w_ref[...].astype(jnp.float32)            # (d, bv)
    logits = jax.lax.dot_general(h, w, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    v0 = vi * bv
    col = v0 + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(col < vocab_size, logits, -1e30)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
    corr = jnp.exp(m_prev - m_new)
    s_ref[...] = s_ref[...] * corr + jnp.sum(jnp.exp(logits - m_new[:, None]), axis=1)
    m_ref[...] = m_new

    labels = label_ref[...]                        # (bn,)
    hit = col == labels[:, None]
    g_ref[...] = g_ref[...] + jnp.sum(jnp.where(hit, logits, 0.0), axis=1)

    @pl.when(vi == nv - 1)
    def _finish():
        out_ref[...] = jnp.log(s_ref[...]) + m_ref[...] - g_ref[...]


@functools.partial(jax.jit, static_argnames=("vocab_size", "bn", "bv", "interpret"))
def fused_xent(h, w, labels, *, vocab_size: int, bn: int = 256, bv: int = 512,
               interpret: bool = True):
    """h: (N, d); w: (d, Vp); labels: (N,) -> nll (N,) f32."""
    N, d = h.shape
    Vp = w.shape[1]
    # requested tiles are upper bounds: training bodies hand us whatever
    # B·S / padded-vocab the config dictates, so shrink to dividing tiles
    bn = divisor_tile(N, bn)
    bv = divisor_tile(Vp, bv)
    grid = (N // bn, Vp // bv)
    return pl.pallas_call(
        functools.partial(_xent_kernel, bv=bv, vocab_size=vocab_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bv), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bn,), jnp.float32),
            pltpu.VMEM((bn,), jnp.float32),
            pltpu.VMEM((bn,), jnp.float32),
        ],
        interpret=interpret,
    )(h, w, labels)
