"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None):
    """q: (BH, Sq, hd); k/v: (BH, Sk, hd)."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (hd ** 0.5)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
