from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import gqa_flash, gqa_ref
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["flash_attention", "attention_ref", "gqa_flash", "gqa_ref"]
