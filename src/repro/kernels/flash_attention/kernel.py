"""Blocked (flash-style) causal attention Pallas kernel with optional
sliding window.

Grid: (batch·heads, q_tiles, k_tiles) with k minor.  Per (bh, q) tile the
online-softmax state (m, l, acc) lives in VMEM scratch; K/V stream through
in (bk, hd) tiles.  Tiles are 128-aligned for the MXU; GQA is handled in
ops.py by an index_map that maps query heads onto their shared KV head, so
KV tiles are NOT replicated in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import divisor_tile

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, bq: int, bk: int, scale: float, causal: bool,
                  window: int | None):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                        # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                        # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    m_ref[...] = m_new
    v = v_ref[0].astype(jnp.float32)                        # (bk, hd)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    """q: (BH, Sq, hd); k/v: (BH, Sk, hd) — heads pre-flattened (GQA mapping
    done by the caller in ops.py).  Returns (BH, Sq, hd) in q.dtype."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    # requested tiles are upper bounds (see kernels/tiling.py): model seq
    # lengths need not be 128-aligned
    bq = divisor_tile(Sq, bq)
    bk = divisor_tile(Sk, bk)
    grid = (BH, Sq // bq, Sk // bk)
    scale = 1.0 / (hd ** 0.5)
    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale,
                          causal=causal, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
