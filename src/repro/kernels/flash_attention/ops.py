"""Model-facing wrapper: GQA layout -> flash kernel.

Maps (B, S, H, hd) q and (B, S, K, hd) k/v onto the kernel's flattened
(B·H, S, hd) layout; the shared KV head of each query-head group is
expanded with a gather (broadcast, no HBM copy under XLA).

``gqa_flash`` is trainable: the forward runs the Pallas kernel, the
backward is the standard softmax-attention gradient obtained by
differentiating the oracle (recompute-from-inputs — exactly what a flash
backward does; the fused TPU bwd kernel is a follow-up, mirroring
fused_xent's split).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, window, bq, bk):
    return flash_attention(q, k, v, causal=causal, window=window,
                           bq=bq, bk=bk, interpret=_use_interpret())


def _flash_fwd(q, k, v, causal, window, bq, bk):
    return _flash(q, k, v, causal, window, bq, bk), (q, k, v)


def _flash_bwd(causal, window, bq, bk, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         window=window), q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


def gqa_flash(q, k, v, *, causal=True, window=None, bq=128, bk=128):
    """q: (B, Sq, H, hd); k/v: (B, Sk, K, hd) -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    rep = H // K
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, -1, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, -1, hd)
    of = _flash(qf, kf, vf, causal, window, bq, bk)
    return of.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)


def gqa_ref(q, k, v, *, causal=True, window=None):
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    rep = H // K
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, -1, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, -1, hd)
    of = attention_ref(qf, kf, vf, causal=causal, window=window)
    return of.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
