"""Pallas-vs-reference numerics gate: ONE tolerance table, one sweep.

``TOLERANCES`` is the single source of truth for how far each Pallas kernel
may drift from its ``ref.py`` oracle, per compute dtype.  Three consumers
read it so the numbers cannot fork:

  * ``tests/test_kernel_numerics.py`` parametrizes the pytest matrix from
    ``iter_cases()`` (the tier-1 suite);
  * ``python -m repro.kernels.numerics`` runs the full dtype × shape grid
    and exits nonzero on any violation — the CI ``kernels`` job, so kernel
    drift fails the PR that causes it rather than the next bench run;
  * ``repro.train.zoo_parity``'s kernel leg reuses the per-kernel f32
    tolerances for its whole-model loss/grad comparison.

The shape grids deliberately include the training shapes the benches never
used: the ``paper-transformer-tiny`` / ``paper-ssm-tiny`` step-body shapes
and ragged (non-128-aligned) axes that exercise ``tiling.divisor_tile``.
All kernels run in interpret mode here (CPU container); on TPU the same
sweep times and checks the Mosaic lowering.
"""
from __future__ import annotations

import argparse

# kernel -> dtype name -> (rtol, atol).  bf16 tolerances cover input
# rounding (eps 2^-8) plus accumulation-order differences; f32 tolerances
# are a few ulps of the reduction reassociation.
TOLERANCES = {
    "fused_xent": {"float32": (1e-4, 1e-4), "bfloat16": (2e-2, 2e-2)},
    "flash_attention": {"float32": (2e-5, 2e-5), "bfloat16": (3e-2, 3e-2)},
    "ssd_scan": {"float32": (1e-3, 1e-3), "bfloat16": (3e-2, 3e-2)},
}

# fused_xent: (N, d, Vp, V)
XENT_SHAPES = [
    (128, 64, 512, 500),      # padded vocab, aligned tokens
    (256, 32, 1024, 1024),    # exact vocab
    (384, 32, 256, 256),      # N=B·S not a multiple of the 256 token tile
    (96, 48, 1024, 1000),     # ragged token axis
    (128, 64, 256, 256),      # paper-transformer-tiny head (d=64, V=256)
]

# flash_attention: (BH, S, hd, causal, window)
ATTN_SHAPES = [
    (4, 256, 64, True, None),
    (2, 256, 64, True, 64),     # sliding window
    (8, 64, 16, True, None),    # paper-transformer-tiny (B·H=8, S=64, hd=16)
    (2, 192, 32, True, 64),     # seq not 128-aligned
    (1, 128, 32, False, None),  # non-causal (encoder/cross)
]

# ssd_scan: (b, S, nh, hd, G, ds, chunk)
SSD_SHAPES = [
    (2, 128, 4, 32, 1, 16, 32),
    (2, 64, 8, 16, 1, 32, 16),   # paper-ssm-tiny (d_inner=128, hd=16)
    (1, 96, 2, 16, 2, 8, 32),    # S not a multiple of the chunk
]

DTYPES = ("float32", "bfloat16")


def iter_cases():
    """Yields (kernel, dtype_name, shape_tuple) over the whole grid."""
    for dt in DTYPES:
        for shp in XENT_SHAPES:
            yield ("fused_xent", dt, shp)
        for shp in ATTN_SHAPES:
            yield ("flash_attention", dt, shp)
        for shp in SSD_SHAPES:
            yield ("ssd_scan", dt, shp)


def check_case(kernel: str, dtype_name: str, shape) -> dict:
    """Run one (kernel, dtype, shape) cell -> report dict (no raising)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    key = jax.random.PRNGKey(0)
    dtype = jnp.dtype(dtype_name)
    rtol, atol = TOLERANCES[kernel][dtype_name]

    def sub(i):
        return jax.random.fold_in(key, i)

    if kernel == "fused_xent":
        from repro.kernels.fused_xent import fused_xent, xent_ref
        N, d, Vp, V = shape
        h = jax.random.normal(key, (N, d), jnp.float32).astype(dtype)
        w = (jax.random.normal(sub(1), (d, Vp), jnp.float32) * 0.05).astype(dtype)
        y = jax.random.randint(sub(2), (N,), 0, V)
        out = fused_xent(h, w, y, vocab_size=V)
        ref = xent_ref(h, w, y, vocab_size=V)
        outs, refs = [out], [ref]
    elif kernel == "flash_attention":
        from repro.kernels.flash_attention import attention_ref, flash_attention
        BH, S, hd, causal, window = shape
        q = jax.random.normal(key, (BH, S, hd), jnp.float32).astype(dtype)
        k = jax.random.normal(sub(1), (BH, S, hd), jnp.float32).astype(dtype)
        v = jax.random.normal(sub(2), (BH, S, hd), jnp.float32).astype(dtype)
        out = flash_attention(q, k, v, causal=causal, window=window)
        ref = attention_ref(q, k, v, causal=causal, window=window)
        outs, refs = [out], [ref]
    else:
        from repro.kernels.ssd_scan import ssd_chunked_pallas, ssd_ref
        b, S, nh, hd, G, ds, chunk = shape
        x = jax.random.normal(key, (b, S, nh, hd), jnp.float32).astype(dtype)
        dt = jax.nn.softplus(jax.random.normal(sub(1), (b, S, nh)))
        A = -jnp.exp(jax.random.normal(sub(2), (nh,)) * 0.3)
        B = jax.random.normal(sub(3), (b, S, G, ds), jnp.float32).astype(dtype)
        C = jax.random.normal(sub(4), (b, S, G, ds), jnp.float32).astype(dtype)
        y1, s1 = ssd_chunked_pallas(x, dt, A, B, C, chunk=chunk)
        y2, s2 = ssd_ref(x, dt, A, B, C, chunk=chunk)
        outs, refs = [y1, s1], [y2, s2]

    max_abs = max_rel = 0.0
    ok = True
    for o, r in zip(outs, refs):
        o = np.asarray(o, np.float32)
        r = np.asarray(r, np.float32)
        err = np.abs(o - r)
        max_abs = max(max_abs, float(err.max()))
        denom = np.maximum(np.abs(r), 1e-30)
        max_rel = max(max_rel, float((err / denom).max()))
        ok &= bool(np.allclose(o, r, rtol=rtol, atol=atol))
    return {"kernel": kernel, "dtype": dtype_name, "shape": shape,
            "rtol": rtol, "atol": atol, "max_abs": max_abs,
            "max_rel": max_rel, "ok": ok}


def run_matrix(verbose: bool = False) -> list[dict]:
    reports = []
    for kernel, dtype_name, shape in iter_cases():
        rep = check_case(kernel, dtype_name, shape)
        reports.append(rep)
        if verbose or not rep["ok"]:
            print(f"  {rep['kernel']:16s} {rep['dtype']:9s} "
                  f"{str(rep['shape']):28s} max_abs={rep['max_abs']:.2e} "
                  f"max_rel={rep['max_rel']:.2e} "
                  f"tol=({rep['rtol']:g},{rep['atol']:g}) "
                  f"{'OK' if rep['ok'] else 'FAIL'}")
    return reports


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    import jax
    reports = run_matrix(verbose=args.verbose)
    bad = [r for r in reports if not r["ok"]]
    print(f"kernel-numerics backend={jax.default_backend()} "
          f"cases={len(reports)} failed={len(bad)} -> "
          f"{'OK' if not bad else 'FAIL'}")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
