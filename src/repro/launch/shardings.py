"""Sharding assignment for every dry-run argument pytree: params, ISGD
optimizer state, input batches, and serving caches.

All picks go through rules.pick_spec so non-divisible dims silently fall
back to the next candidate (DESIGN.md §5).

The hybrid DP × TP engine (repro.distributed.data_parallel) places params
through ``hybrid_params_placement`` below: model-axis tensor parallelism
(with FSDP over 'data' by default, as the old pjit runner had) on meshes
with a live tensor axis — the GSPMD strategy is layout-agnostic, GSPMD
gathers what it needs — and replicated on pure-data meshes, where the
manual shard_map strategy *requires* data-axis replication.  It pairs with
``state_shardings``, which mirrors each velocity leaf onto its parameter's
sharding and keeps the ψ queue/counters replicated so the control
statistics stay identical on every device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.sharding import rules


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def batch_shardings(mesh: Mesh, specs: dict, *, seq_shard: bool = False):
    """Input batch: batch dim over (pod, data); long-context fallback shards
    the sequence dim over 'data' (context parallel)."""
    dp = rules.batch_axes(mesh)
    out = {}
    for name, sds in specs.items():
        shape = sds.shape
        if name == "tokens":
            cands = ([(None, "data")] if seq_shard else []) + \
                [(dp, None), (None, "data"), (None, None)]
        else:  # frontend embeds (B, n, d)
            cands = [(dp, None, "model"), (dp, None, None),
                     (None, None, "model"), (None, None, None)]
        out[name] = _ns(mesh, rules.pick_spec(mesh, shape, cands))
    return out


def data_parallel_shardings(mesh: Mesh, specs: dict, *, axis: str = "data"):
    """Pure data-parallel batch layout (the repro.distributed engine):
    leading (batch) dim over ``axis``, everything else unsharded.  A leaf
    whose batch dim doesn't divide the axis falls back to replicated, which
    the engine's shard_map in_specs then reports as a shape error instead of
    silently mis-sharding."""
    out = {}
    for name, sds in specs.items():
        r = len(sds.shape)
        cands = [(axis,) + (None,) * (r - 1), (None,) * r]
        out[name] = _ns(mesh, rules.pick_spec(mesh, sds.shape, cands))
    return out


def cache_shardings(mesh: Mesh, cache_shapes, *, seq_shard: bool = False,
                    mode: str = "feature"):
    """Serving caches.  rank-5 = stacked attn KV / SSD state; rank-4 =
    stacked MLA/conv or unstacked attn; scalars replicated.

    mode="feature": shard kv-heads/head-dim over 'model' (paper-faithful
    baseline layout — mirrors the weight sharding).
    mode="batch": shard only the batch dim; caches replicated over 'model'
    (the §Perf fix: avoids GSPMD reshard/involuntary-remat on the decode
    attention contraction when kv-heads don't divide the model axis).
    """
    dp = rules.batch_axes(mesh)

    def leaf(sds):
        shape = sds.shape
        r = len(shape)
        if r == 5:
            if mode == "batch":
                cands = [(None, dp, None, None, None),
                         (None, None, "data", None, None),
                         (None,) * 5]
            else:
                cands = [(None, dp, None, None, "model"),
                         (None, dp, None, "model", None),
                         (None, None, "data", None, "model"),
                         (None, None, "data", None, None),
                         (None, None, None, None, "model"),
                         (None,) * 5]
        elif r == 4:
            if mode == "batch":
                cands = [(None, dp, None, None),
                         (None, None, "data", None),
                         (None,) * 4]
            else:
                cands = [(None, dp, None, "model"),
                         (None, None, "data", "model"),
                         (None, None, "data", None),
                         (None, None, None, "model"),
                         (None,) * 4]
        elif r == 3:
            cands = [(dp, None, "model"), (None, "data", "model"),
                     (None, None, "model"), (None,) * 3]
            if mode == "batch":
                cands = [(dp, None, None), (None, "data", None), (None,) * 3]
        elif r == 2:
            cands = [(dp, None), (None, None)]
        else:
            return _ns(mesh, P())
        if seq_shard:
            # prefer sequence-sharded candidates first (B=1 long-context)
            cands = [c for c in cands if "data" in c or c == (None,) * r] + cands
        return _ns(mesh, rules.pick_spec(mesh, shape, cands))

    return jax.tree.map(leaf, cache_shapes)


def state_shardings(mesh: Mesh, state_shapes, params_shardings):
    """ISGD state: `base` (velocity) shards exactly like its parameter;
    queue/counters are replicated scalars."""
    rep = _ns(mesh, P())
    base = state_shapes.base
    if not jax.tree.leaves(base):
        base_sh = jax.tree.map(lambda _: rep, base)
    else:
        base_sh = jax.tree.map(lambda _, s: s, base, params_shardings)
    rest = type(state_shapes)(
        base=base_sh,
        queue=jax.tree.map(lambda _: rep, state_shapes.queue),
        iter=rep, accel_count=rep, sub_iters=rep,
    )
    return rest


def params_shardings(mesh: Mesh, params_shapes, *, fsdp: bool = True):
    return rules.params_shardings(mesh, params_shapes, fsdp=fsdp)


def hybrid_params_placement(mesh: Mesh, params, *, fsdp: bool = True):
    """device_put ``params`` for the hybrid engine on ``mesh``; returns
    ``(params, shardings)`` (feed the shardings to ``state_shardings``).

    Tensor/FSDP-sharded per ``params_shardings`` when the mesh has a live
    tensor axis (the engine's GSPMD strategy), replicated otherwise (the
    manual shard_map strategy requires data-axis replication).  The single
    source of truth for the launcher, examples, and benchmarks — keep them
    from drifting apart.
    """
    from repro.distributed.data_parallel import tensor_axes
    if tensor_axes(mesh):
        sh = params_shardings(mesh, jax.eval_shape(lambda: params),
                              fsdp=fsdp)
    else:
        rep = _ns(mesh, P())
        sh = jax.tree.map(lambda _: rep, params)
    return jax.device_put(params, sh), sh
