"""Distributed training launcher.

On real TPU hardware this runs the ISGD train loop under the production
mesh; on this CPU container it runs reduced configs under a host mesh so the
whole path (sharded params, sharded ISGD step with its cond/while_loop,
loss-driven LR) is exercised end-to-end.

Every synchronous engine builds its step through ONE path —
``train.trainer.make_step_core`` wrapped by the hybrid shard_map engine in
``repro.distributed.data_parallel`` — so the loss-driven LR (ψ̄ read with
its one-step lag, Alg.1 line 19) is identical everywhere.  (Historical
note: the old pjit runner hand-rolled its own step closure and froze the
schedule at ``lr_fn(0.0)``; that closure is gone and tests/test_hybrid.py
pins the fix.)  Engines (``--engine``; ``--data-parallel`` remains as an
alias):

  * ``hybrid`` (default; ``pjit`` is an alias) — the DP × TP engine on a
    2-D ``(data, model)`` host mesh: batch sharded over 'data' with
    grads/ψ globally reduced there, params/velocity sharded over 'model'
    (launch/shardings.py, ``--model-parallel M``) with activation
    constraints.  With ``M=1`` the engine runs the manual shard_map
    strategy (explicit AxisReduce pmeans — identical to data-parallel);
    with ``M>1`` the same step body runs as one GSPMD program
    (pjit-with-constraints) — see repro.distributed.data_parallel for why;
  * ``data-parallel`` — the same engine on a 1-D ('data',) mesh: params
    and ISGD state replicated, batch sharded over 'data' (paper §6);
  * ``async-ps`` — the asynchronous parameter-server engine (paper §6.2,
    repro.distributed.async_ps): ``--workers`` threads over per-worker FCPR
    shards push staleness-weighted deltas (``--staleness-decay``, w(τ)) to
    a server that runs the SPC limit/accelerate logic on globally
    consistent statistics; ``--max-staleness`` bounds how far workers may
    drift apart (0 = lockstep rounds — the synchronous schedule).

Two input/dispatch accelerators compose with the synchronous engines
(async-ps is host-orchestrated per worker step and rejects them):

  * ``--device-ring`` — serve batches from the device-resident FCPR ring
    (one epoch upload, batches by dynamic_slice) instead of per-step host
    transfers; falls back to the prefetcher when the epoch busts the byte
    budget;
  * ``--chunk-steps K`` — the fused engine: K full ISGD steps per host
    dispatch (lax.scan over the ring, bit-exact with per-step; the step
    count is rounded up to whole chunks);
  * ``--schedule fcpr|loss-prop|rank`` — batch *selection* policy
    (``repro.sched``): selection runs inside the jitted step over the
    device ring (implied), so loss-aware policies never round-trip their
    table through the host.  ``fcpr`` through the scheduler path is
    bit-exact with the default engines; under ``loss-prop``/``rank`` the
    SPC chart reads the per-batch loss table (ψ-window caveat — see the
    ``repro.sched`` package doc).  Omitting the flag keeps the hard-wired
    FCPR paths.

Fault tolerance (ISSUE 7): ``--checkpoint-dir``/``--checkpoint-every``
write crash-consistent full-engine checkpoints (atomic, checksummed .npz
covering params, optimizer base, ψ queue, sched state, step cursor, and —
async-ps — the server version + per-worker SSP push clocks); ``--resume``
restores the newest one and continues the uninterrupted trajectory
bit-exactly (``repro.train.resume_parity`` proves it per engine).  The
async-ps engine additionally takes ``--elastic`` (evict deadline-missing/
crashed workers, re-stripe their FCPR shard across survivors),
``--deadline``, ``--fault-plan`` (deterministic fault injection,
``repro.fault``) and ``--verify-pushes`` (checksum-reject corrupt deltas,
bounded retry).

Model selection: ``--arch`` names an assigned architecture config
(``repro.configs``, usually with ``--reduced``); ``--model
transformer|moe|ssm`` picks the ``paper_transformer`` zoo family instead
(``--tier tiny|base``).  ``--kernels pallas|reference|interpret`` routes the
step-body hot spots (flash-attention, fused-xent, ssd_scan) —
``pallas`` falls back to the ``ref.py`` paths where Pallas lowering is
unavailable (see ``repro.kernels.policy``); ``--precision bf16|f32`` is the
compute dtype (ψ statistics and the SPC queue stay f32 either way);
``--remat full|tp_out|none`` sets the chunk-scan-boundary checkpoint policy.

Multi-process (ROADMAP: multi-host 3-D mesh scale-out): every runner
accepts the shared ``--coordinator/--num-processes/--process-id`` surface
(``repro.launch.env``).  When present, ``jax.distributed.initialize`` is
wired up before any device use, the mesh factory produces a
``(pod, data, model)`` mesh over the *global* device set (one pod row per
process), ψ/grads reduce over ``("pod", "data")`` deterministically, the
FCPR epoch is striped per process through the :class:`DeviceRing` (each
process uploads only its rows), and checkpoints follow process-0-writes /
all-validate (``repro.train.checkpoints``).  A 2-process ``(2, 2, 1)`` run
is bit-exact with the single-process ``(4, 1)`` run
(``repro.distributed.multihost_parity``).  Library validation errors
(:class:`repro.launch.mesh.MeshError`) are translated to ``SystemExit``
here, at the CLI boundary — library code never exits.

  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --reduced --steps 30 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --model transformer \
      --kernels pallas --chunk-steps 32 --steps 64 --batch 8 --seq 64
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.train --arch internlm2-1.8b --reduced \
      --engine hybrid --model-parallel 2 --chunk-steps 8 --steps 32 \
      --batch 16
  # two cooperating processes on one machine (2 CPU devices each):
  XLA_FLAGS=--xla_force_host_platform_device_count=2 PYTHONPATH=src \
      python -m repro.launch.train --model transformer --steps 16 \
      --batch 8 --coordinator 127.0.0.1:9911 --num-processes 2 \
      --process-id 0   # and the same command with --process-id 1
"""
from __future__ import annotations

import argparse
import contextlib

import jax
import jax.numpy as jnp

import numpy as np

from repro.configs import ZOO_MODELS, ZOO_TIERS, get_config, zoo_config
from repro.core import ISGDConfig
from repro.core.schedule import constant_lr
from repro.data import DeviceRing, FCPRSampler, make_lm_tokens, ring_or_prefetch
from repro.distributed import (PrefetchSampler, batch_sharding,
                               make_chunked_hybrid_step, make_hybrid_step,
                               tensor_axes)
from repro.distributed.data_parallel import replicate_to_mesh
from repro.launch import env as ENV
from repro.launch import shardings as SH
from repro.launch.mesh import (MeshError, is_multiprocess, make_data_mesh,
                               make_training_mesh)
from repro.models import build_model
from repro.obs.timing import StepTimer, maybe_profile
from repro.optim import RULES
from repro.sharding import activation_sharding, rules


def frontend_embeds(cfg, batch_size: int):
    """Constant zero frontend embeddings for vlm/encdec smoke configs —
    hoisted out of the step loop (they never change across steps)."""
    if cfg.family == "vlm":
        shape = (batch_size, cfg.num_image_tokens, cfg.d_model)
    elif cfg.family == "encdec":
        shape = (batch_size, cfg.encoder_seq, cfg.d_model)
    else:
        return {}
    return {"frontend_embeds": jnp.zeros(shape, jnp.bfloat16)}


def ring_epoch(cfg, sampler, batch_size: int):
    """Epoch arrays for a ``DeviceRing``, with the constant frontend extras
    tiled per-sample so an in-scan ring slice reproduces exactly the batch
    dict the per-step loop would have assembled."""
    epoch = dict(sampler.epoch_arrays())
    for k, v in frontend_embeds(cfg, batch_size).items():
        arr = np.asarray(v)
        epoch[k] = np.tile(arr, (sampler.n_batches,) + (1,) * (arr.ndim - 1))
    return epoch


def _drive_chunks(jchunk, state, params, ring, steps: int, k: int, *,
                  start: int = 0, ckpt=None, obs=None):
    """Run from global step ``start`` to ``steps`` (rounded up to whole
    chunks) through a fused chunk fn, printing the last step of each chunk.
    ``start`` may sit mid-chunk relative to the K grid — ``chunk_fn`` takes
    an arbitrary ``j0`` (what makes resume-from-checkpoint possible).
    Returns (state, total_steps).  ``obs`` ingests each chunk's stacked
    metrics at the chunk boundary (the fetch below is already the one host
    sync per chunk — obs adds no dispatches)."""
    j = start
    while j < steps:
        state, params, ms = jchunk(state, params, ring.arrays, j)
        if obs is not None:
            obs.chunk(j, ms)
        j += k
        ENV.p0print(f"step {j:4d} loss={float(ms['loss'][-1]):.4f} "
              f"psi_bar={float(ms['psi_bar'][-1]):.4f} "
              f"limit={float(ms['limit'][-1]):.4f} "
              f"accel={bool(ms['accelerated'][-1])}")
        if ckpt is not None:
            ckpt.maybe_save(j, params=params, state=state)
    return state, j


def _drive_scheduled(jfn, state, params, sched_state, ring, steps: int,
                     k: int, *, start: int = 0, ckpt=None, obs=None):
    """Drive a scheduled engine (per-step when ``k == 1``, fused chunks
    otherwise), printing the last step of each dispatch group including the
    policy's realized batch pick.  Returns (state, total_steps)."""
    from repro.sched.engine import selection_counts
    if k == 1:
        for j in range(start, steps):
            state, params, sched_state, m = jfn(state, params, sched_state,
                                                ring.arrays, j)
            if obs is not None:
                obs.defer(j, m)
            if (j + 1) % 5 == 0 or j == 0:
                if obs is not None:
                    obs.flush()
                ENV.p0print(f"step {j+1:4d} batch={int(m['batch_idx'])} "
                      f"loss={float(m['loss']):.4f} "
                      f"psi_bar={float(m['psi_bar']):.4f} "
                      f"limit={float(m['limit']):.4f} "
                      f"accel={bool(m['accelerated'])}")
            if ckpt is not None:
                ckpt.maybe_save(j + 1, params=params, state=state,
                                sched_state=sched_state)
        if obs is not None:
            obs.flush()
        return state, steps
    j = start
    while j < steps:
        state, params, sched_state, ms = jfn(state, params, sched_state,
                                             ring.arrays, j)
        if obs is not None:
            obs.chunk(j, ms)
        j += k
        visits = selection_counts(ms["batch_idx"], ring.n_batches)
        ENV.p0print(f"step {j:4d} loss={float(ms['loss'][-1]):.4f} "
              f"psi_bar={float(ms['psi_bar'][-1]):.4f} "
              f"limit={float(ms['limit'][-1]):.4f} "
              f"accel={bool(ms['accelerated'][-1])} "
              f"visits={visits.tolist()}")
        if ckpt is not None:
            ckpt.maybe_save(j, params=params, state=state,
                            sched_state=sched_state)
    return state, j


class _TeeCheckpointer:
    """Fan a run's saves out to several ``Checkpointer``s — the
    crash-recovery directory and the serving publish directory can differ
    (different cadences, different pruning) without threading two objects
    through every runner."""

    def __init__(self, ckpts):
        self.ckpts = ckpts
        self.directory = ckpts[0].directory

    def maybe_save(self, step, **kw):
        outs = [c.maybe_save(step, **kw) for c in self.ckpts]
        return next((o for o in outs if o), None)

    def save(self, step, **kw):
        return [c.save(step, **kw) for c in self.ckpts][0]

    def mark(self, step):
        for c in self.ckpts:
            c.mark(step)

    def latest(self):
        return self.ckpts[0].latest()


def _make_checkpointer(args, recorder=None):
    """``--checkpoint-dir``/``--checkpoint-every`` → a ``Checkpointer``;
    ``--publish-dir`` adds (or upgrades to) a *publishing* checkpointer
    that maintains the atomic ``LATEST`` pointer a serving
    ``SnapshotWatcher`` polls (train-and-serve).  None when both are off."""
    import os

    from repro.train.checkpoints import Checkpointer
    publish_dir = args.publish_dir
    same = (publish_dir and args.checkpoint_dir and
            os.path.abspath(publish_dir) == os.path.abspath(args.checkpoint_dir))
    ckpts = []
    if args.checkpoint_dir:
        ckpts.append(Checkpointer(args.checkpoint_dir,
                                  every=args.checkpoint_every,
                                  pointer=bool(same), recorder=recorder))
    if publish_dir and not same:
        every = args.publish_every or args.checkpoint_every
        if not every:
            raise SystemExit("--publish-dir needs --publish-every (or "
                             "--checkpoint-every) to set the snapshot "
                             "cadence")
        ckpts.append(Checkpointer(publish_dir, every=every, pointer=True,
                                  recorder=recorder))
    if not ckpts:
        if args.resume:
            raise SystemExit("--resume needs --checkpoint-dir")
        return None
    return ckpts[0] if len(ckpts) == 1 else _TeeCheckpointer(ckpts)


def _maybe_resume(args, ckpt, *, params_like, state_like, sched_like=None):
    """``--resume``: restore the newest complete checkpoint in the directory
    (atomic saves guarantee completeness) against the freshly initialized
    templates.  Returns the ``EngineCheckpoint`` or None."""
    if not (args.resume and ckpt is not None):
        return None
    from repro.train.checkpoints import restore_engine
    latest = ckpt.latest()
    if latest is None:
        ENV.p0print(f"resume: no checkpoint under {ckpt.directory!r}; "
                    f"starting fresh")
        return None
    ck = restore_engine(latest, params_like=params_like,
                        state_like=state_like, sched_like=sched_like)
    ckpt.mark(ck.step)
    ENV.p0print(f"resume: restored {latest!r} at step {ck.step}")
    return ck


def _make_observer(args, cfg, icfg, engine: str):
    """``--obs-dir`` → a ``TrainObserver`` writing this process's JSONL
    (tagged process_id/engine/model), or None when obs is off.

    The SPC exporter mirrors the queue discipline of the selected engine:
    per-batch table replay for ``uses_table`` schedules, FIFO otherwise.
    Multi-worker async-PS runs push in commit order but observe losses in a
    (possibly different) race order, so their table replay is chart-only —
    counters still reconcile exactly (``replay_exact=False``)."""
    if not args.obs_dir:
        return None
    import os

    from repro.obs import (ConsoleSink, JsonlSink, MetricsRecorder,
                           TrainObserver, jsonl_path)
    topo = ENV.topology()
    os.makedirs(args.obs_dir, exist_ok=True)
    sinks = [JsonlSink(jsonl_path(args.obs_dir, topo.process_id))]
    if args.obs_console_every:
        sinks.append(ConsoleSink(every=args.obs_console_every))
    rec = MetricsRecorder(sinks, tags={"process_id": topo.process_id,
                                       "engine": engine, "model": cfg.name})
    table = False
    if args.schedule is not None and engine != "async-ps":
        from repro.sched import schedule_from_spec
        table = schedule_from_spec(args.schedule).uses_table
    replay_exact = engine != "async-ps" or args.workers == 1
    return TrainObserver(rec, n_batches=icfg.n_batches,
                         k_sigma=icfg.k_sigma, table=table,
                         examples_per_step=args.batch,
                         replay_exact=replay_exact)


def run_sync(args, cfg, model, sampler, rule, icfg, lr_fn, *,
             engine: str = "hybrid", obs=None):
    """The synchronous engines — ``hybrid`` (DP × TP, 2-D mesh) and
    ``data-parallel`` (1-D mesh) — one driving loop, one step path
    (``make_step_core`` under the hybrid shard_map engine).  Returns
    ``(state, wall_seconds, steps_run)``.  ``obs`` (a
    ``repro.obs.TrainObserver``) ingests metrics at the existing chunk/log
    boundaries only."""
    timer = obs.timer if obs is not None else StepTimer()
    if engine == "data-parallel":
        if args.model_parallel != 1:
            raise SystemExit("--model-parallel composes with --engine "
                             "hybrid, not --engine data-parallel")
        mesh = make_data_mesh()
    else:
        # pod defaults to the process count: 2-D (data, model) single-
        # process, 3-D (pod, data, model) over global devices otherwise
        mesh = make_training_mesh(model=args.model_parallel)
    multiproc = is_multiprocess(mesh)
    from repro.distributed.data_parallel import data_axis_size
    n_data = data_axis_size(mesh)
    if args.batch % n_data:
        raise SystemExit(f"--batch {args.batch} must be a multiple of the "
                         f"{n_data} data-axis devices (it is split across "
                         f"them)")
    ENV.p0print(f"arch={cfg.name} engine={engine} mesh={dict(mesh.shape)} "
                f"processes={ENV.topology().num_processes} "
                f"per_device_batch={args.batch // n_data} "
                f"chunk_steps={args.chunk_steps}")

    params = model.init(jax.random.PRNGKey(0), max_seq=args.seq)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    tp = bool(tensor_axes(mesh))
    if multiproc and tp:
        raise SystemExit("--model-parallel > 1 is not wired for "
                         "multi-process runs yet (tensor-sharded param "
                         "placement needs per-process shard assembly); run "
                         "model parallelism single-process or data "
                         "parallelism multi-process")
    if multiproc:
        # every process initialized identical params (same PRNGKey):
        # assemble them into one replicated global array per leaf
        params = replicate_to_mesh(params, mesh)
        from repro.distributed.data_parallel import replicated
        p_sh = jax.tree.map(lambda _: replicated(mesh), params)
    else:
        params, p_sh = SH.hybrid_params_placement(mesh, params)
    if tp:
        # GSPMD strategy: tensor/FSDP-parallel weights + the activation
        # constraint table (valid here — the step is one global program)
        table = rules.activation_rule_table(mesh, args.batch)
        ctx = activation_sharding(rules.make_constrain(mesh, table))
        ENV.p0print(f"params: {n_params/1e6:.1f}M (model/FSDP-sharded)")
    else:
        # manual shard_map strategy: params replicated; constraints would
        # be illegal inside the manual region and are not needed
        ctx = contextlib.nullcontext()
        ENV.p0print(f"params: {n_params/1e6:.1f}M (replicated)")

    schedule = None
    if args.schedule is not None:
        from repro.sched import schedule_from_spec
        schedule = schedule_from_spec(args.schedule)
        ENV.p0print(f"schedule: {schedule} (device-resident selection; "
                    f"non-FCPR policies read SPC limits from the per-batch "
                    f"loss table)")
    if args.chunk_steps > 1:
        init_fn, jstep = make_chunked_hybrid_step(
            model.loss_fn, rule, icfg, mesh, chunk_steps=args.chunk_steps,
            inconsistent=not args.consistent, lr_fn=lr_fn,
            schedule=schedule)
    else:
        init_fn, jstep = make_hybrid_step(
            model.loss_fn, rule, icfg, mesh,
            inconsistent=not args.consistent, lr_fn=lr_fn,
            schedule=schedule)
    state = init_fn(params)
    s_sh = SH.state_shardings(mesh, jax.eval_shape(lambda: state), p_sh)
    ckpt = _make_checkpointer(args,
                              recorder=obs.recorder if obs is not None else None)
    start = 0

    put_repl = ((lambda t, _sh: replicate_to_mesh(t, mesh)) if multiproc
                else jax.device_put)
    with mesh, ctx:
        state = put_repl(state, s_sh)
        if schedule is not None:
            # scheduled engines select on device: the ring is mandatory
            ring = DeviceRing(ring_epoch(cfg, sampler, args.batch),
                              args.batch, mesh=mesh, axis=None,
                              relayout=not tp)
            sched_state = schedule.init(icfg.n_batches)
            ck = _maybe_resume(args, ckpt, params_like=params,
                               state_like=state, sched_like=sched_state)
            if ck is not None:
                params = put_repl(ck.params, p_sh)
                state = put_repl(ck.state, s_sh)
                sched_state, start = ck.sched_state, ck.step
            with timer.span("train"):
                state, steps = _drive_scheduled(jstep, state, params,
                                                sched_state, ring, args.steps,
                                                args.chunk_steps, start=start,
                                                ckpt=ckpt, obs=obs)
            return state, timer.seconds("train"), steps - start
        ck = _maybe_resume(args, ckpt, params_like=params, state_like=state)
        if ck is not None:
            params = put_repl(ck.params, p_sh)
            state = put_repl(ck.state, s_sh)
            start = ck.step
        if args.chunk_steps > 1:
            # fused engine: sharded device ring + K steps per dispatch
            # (manual strategy slices its relaid-out local block; GSPMD
            # strategy slices the global row order)
            ring = DeviceRing(ring_epoch(cfg, sampler, args.batch),
                              args.batch, mesh=mesh, axis=None,
                              relayout=not tp)
            with timer.span("train"):
                state, steps = _drive_chunks(jstep, state, params, ring,
                                             args.steps, args.chunk_steps,
                                             start=start, ckpt=ckpt, obs=obs)
            return state, timer.seconds("train"), steps - start

        if multiproc:
            # the host prefetcher's device_put cannot address other
            # processes' devices: the striped device ring is the only
            # multi-process feed (each process uploads its epoch stripe;
            # frontend extras are tiled into the ring)
            feed = DeviceRing(ring_epoch(cfg, sampler, args.batch),
                              args.batch, mesh=mesh, axis=None,
                              relayout=not tp)
            extra = {}
            ENV.p0print("input: DeviceRing (per-process epoch striping)")
        else:
            b_sh = batch_sharding(mesh)
            extra = {k: jax.device_put(v, b_sh)
                     for k, v in frontend_embeds(cfg, args.batch).items()}
            if args.device_ring:
                feed = ring_or_prefetch(sampler, mesh=mesh, axis=None,
                                        relayout=not tp)  # ring if it fits
                print(f"input: {type(feed).__name__}")
            else:
                feed = PrefetchSampler(
                    sampler,
                    sharding=SH.data_parallel_shardings(mesh, sampler(0)))
        with timer.span("train"):
            for j in range(start, args.steps):
                batch = dict(feed(j), **extra)
                state, params, m = jstep(state, params, batch)
                if obs is not None:
                    obs.defer(j, m)
                if (j + 1) % 5 == 0 or j == 0:
                    # the print below host-syncs anyway: flush obs here too
                    if obs is not None:
                        obs.flush()
                    ENV.p0print(f"step {j+1:4d} loss={float(m['loss']):.4f} "
                          f"psi_bar={float(m['psi_bar']):.4f} "
                          f"limit={float(m['limit']):.4f} "
                          f"accel={bool(m['accelerated'])}")
                if ckpt is not None:
                    ckpt.maybe_save(j + 1, params=params, state=state)
            if obs is not None:
                obs.flush()
        return state, timer.seconds("train"), args.steps - start


def run_async_ps(args, cfg, model, sampler, rule, icfg, lr_fn, *, obs=None):
    from repro.distributed import AsyncPSCoordinator, staleness_reduce_from_spec
    from repro.distributed.async_ps.coordinator import (
        snapshot_engine_kwargs, snapshot_from_checkpoint)

    if cfg.family in ("vlm", "encdec"):
        raise SystemExit("--engine async-ps supports decoder-only/cnn "
                         "configs (no constant frontend-embed plumbing)")
    if args.chunk_steps > 1 or args.device_ring:
        raise SystemExit("--chunk-steps/--device-ring do not compose with "
                         "--engine async-ps (workers dispatch per step from "
                         "host snapshots, there is no fused scan or device "
                         "ring in this engine)")
    if args.schedule is not None:
        raise SystemExit("--schedule does not compose with --engine "
                         "async-ps (workers own fixed FCPR stripes; a "
                         "shared selection policy would race the table)")
    if sampler.n_batches % args.workers:
        # legal since re-striping (ISSUE 7): the strided shards still cover
        # the global cycle, ownership just rotates (see ShardedFeed)
        print(f"note: n_batches={sampler.n_batches} not a multiple of "
              f"--workers {args.workers}; per-worker batch ownership "
              f"rotates through the FCPR cycle")
    faults = None
    if args.fault_plan:
        from repro.fault import FaultPlan
        faults = FaultPlan.from_spec(args.fault_plan)
        print(f"faults: {faults}")
    rctx = staleness_reduce_from_spec(args.staleness_decay)
    print(f"arch={cfg.name} engine=async-ps workers={args.workers} "
          f"max_staleness={args.max_staleness} w(tau)={args.staleness_decay} "
          f"elastic={args.elastic} deadline={args.deadline:.0f}s")

    params = model.init(jax.random.PRNGKey(0), max_seq=args.seq)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.1f}M (canonical copy on the server)")

    kw = dict(elastic=args.elastic, deadline_s=args.deadline,
              verify_pushes=args.verify_pushes)
    if faults is not None:
        kw["faults"] = faults
    coord = AsyncPSCoordinator(
        model.loss_fn, rule, icfg, workers=args.workers,
        max_staleness=args.max_staleness, lr_fn=lr_fn, reduce_ctx=rctx,
        inconsistent=not args.consistent,
        recorder=obs.recorder if obs is not None else None, **kw)

    ckpt = _make_checkpointer(args,
                              recorder=obs.recorder if obs is not None else None)
    resume = None
    if args.resume and ckpt is not None and ckpt.latest() is not None:
        from repro.core import isgd_init
        from repro.train.checkpoints import restore_engine
        ck = restore_engine(ckpt.latest(), params_like=params,
                            state_like=isgd_init(rule, icfg, params))
        ckpt.mark(ck.step)
        resume = snapshot_from_checkpoint(ck)
        print(f"resume: restored {ckpt.latest()!r} at server version "
              f"{ck.server['version']} (worker push clocks: "
              f"{ck.server['pushed']})")

    def checkpoint_fn(snap):
        ek = snapshot_engine_kwargs(snap)
        ckpt.save(ek.pop("step"), **ek)

    run_kw = {}
    if ckpt is not None and args.checkpoint_every:
        run_kw = dict(checkpoint_fn=checkpoint_fn,
                      checkpoint_every=args.checkpoint_every)
    timer = obs.timer if obs is not None else StepTimer()
    with timer.span("train"):
        params, state, records = coord.run(params, sampler, args.steps,
                                           resume=resume, **run_kw)
    dt = timer.seconds("train")
    if obs is not None:
        obs.async_run(records, coord.events)
    for ev in coord.events:
        print(f"event: {ev}")
    for i, r in enumerate(records):
        if (i + 1) % 5 == 0 or i == 0:
            print(f"push {i+1:4d} w{r['worker']} tau={r['tau']} "
                  f"loss={r['loss']:.4f} psi_bar={r['psi_bar']:.4f} "
                  f"limit={r['limit']:.4f} accel={r['accelerated']}")
    taus = [r["tau"] for r in records]
    print(f"staleness: mean_tau={sum(taus)/len(taus):.2f} "
          f"max_tau={max(taus)} "
          f"bound={(2 * args.max_staleness + 1) * (args.workers - 1)}")
    return state, dt, len(records)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="assigned architecture config (repro.configs)")
    ap.add_argument("--model", default=None, choices=list(ZOO_MODELS),
                    help="paper_transformer zoo family (alternative to "
                         "--arch): transformer | moe | ssm")
    ap.add_argument("--tier", default="tiny", choices=list(ZOO_TIERS),
                    help="zoo tier for --model (tiny = CPU CI, base = "
                         "single-host accelerator)")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale variant (CPU)")
    ap.add_argument("--kernels", default="reference",
                    choices=["pallas", "reference", "interpret"],
                    help="step-body hot-spot implementations; pallas falls "
                         "back to the ref.py paths off-TPU "
                         "(repro.kernels.policy)")
    ap.add_argument("--precision", default="bf16", choices=["bf16", "f32"],
                    help="compute dtype for params/activations (psi "
                         "statistics and the SPC queue stay f32)")
    ap.add_argument("--remat", default="full",
                    choices=["full", "tp_out", "none"],
                    help="checkpoint policy at the block-scan boundary "
                         "(tp_out saves post-all-reduce activations)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--rule", default="momentum", choices=list(RULES))
    ap.add_argument("--consistent", action="store_true")
    ap.add_argument("--k-sigma", type=float, default=2.0)
    ap.add_argument("--stop", type=int, default=3)
    ap.add_argument("--n-seqs", type=int, default=64)
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="hybrid engine: devices on the tensor-parallel "
                         "'model' axis (must divide the device count; the "
                         "rest form the 'data' axis)")
    ap.add_argument("--engine", default=None,
                    choices=["hybrid", "pjit", "data-parallel", "async-ps"],
                    help="training engine (default hybrid; 'pjit' is an "
                         "alias for it — see module docstring)")
    ap.add_argument("--data-parallel", action="store_true",
                    help="alias for --engine data-parallel")
    ap.add_argument("--workers", type=int, default=2,
                    help="async-ps: number of worker threads")
    ap.add_argument("--max-staleness", type=int, default=0,
                    help="async-ps: SSP bound — a worker may start step k "
                         "only when every worker finished step k-N; 0 = "
                         "lockstep (synchronous schedule)")
    ap.add_argument("--staleness-decay", default="inverse",
                    help="async-ps: w(tau) family[:alpha] — inverse "
                         "(1/(1+a*tau)), exp (e^-a*tau), none")
    ap.add_argument("--chunk-steps", type=int, default=1,
                    help="K>1 = fused engine: K ISGD steps per dispatch via "
                         "lax.scan over the device-resident FCPR ring "
                         "(bit-exact with the per-step engine)")
    ap.add_argument("--device-ring", action="store_true",
                    help="per-step engine fed from the device-resident "
                         "FCPR ring instead of host batches (implied by "
                         "--chunk-steps > 1)")
    ap.add_argument("--schedule", default=None,
                    help="batch-selection policy (repro.sched): "
                         "fcpr | loss-prop | rank, with options as "
                         "family:k=v,... (e.g. loss-prop:eps=0.2).  "
                         "Selection runs on device over the ring; fcpr is "
                         "bit-exact with the default engines; omit for the "
                         "hard-wired FCPR paths")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for crash-consistent full-engine "
                         "checkpoints (atomic .npz, checksummed; "
                         "repro.train.checkpoints)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="checkpoint cadence in steps (sync engines: saved "
                         "at the first step/chunk boundary past each mark; "
                         "async-ps: every N applied pushes, written under "
                         "the server lock).  0 = never")
    ap.add_argument("--publish-dir", default=None,
                    help="train-and-serve: directory where full-engine "
                         "checkpoints are published for a live serving "
                         "process (atomic LATEST pointer; a "
                         "repro.serve.SnapshotWatcher hot-swaps each one "
                         "between decode steps).  May equal "
                         "--checkpoint-dir")
    ap.add_argument("--publish-every", type=int, default=0,
                    help="publish cadence in steps (0 = inherit "
                         "--checkpoint-every)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest complete checkpoint in "
                         "--checkpoint-dir (a resumed run continues the "
                         "uninterrupted trajectory bit-exactly — "
                         "repro.train.resume_parity)")
    ap.add_argument("--elastic", action="store_true",
                    help="async-ps: evict crashed/deadline-missing workers "
                         "and re-stripe their FCPR shard across survivors "
                         "instead of failing the run")
    ap.add_argument("--deadline", type=float, default=120.0,
                    help="async-ps: heartbeat deadline in seconds — a "
                         "worker blocking the SSP clock without a "
                         "heartbeat for this long is stalled (evicted when "
                         "--elastic, fatal diagnostic otherwise)")
    ap.add_argument("--fault-plan", default=None,
                    help="async-ps: deterministic fault injection spec, "
                         "kind@worker:step[:key=value,...] joined by ';' — "
                         "e.g. 'crash@2:5;hang@1:8:seconds=1.0' "
                         "(repro.fault)")
    ap.add_argument("--verify-pushes", action="store_true",
                    help="async-ps: workers checksum their deltas and the "
                         "server rejects corrupt arrivals (rejected/"
                         "transient pushes retry with backoff)")
    ap.add_argument("--obs-dir", default=None,
                    help="telemetry directory (repro.obs): per-process "
                         "metrics.pN.jsonl with the live SPC control chart, "
                         "counters and events; process 0 folds a merged "
                         "summary.json.  Ingestion only at existing host-"
                         "sync boundaries — zero extra dispatches")
    ap.add_argument("--obs-console-every", type=int, default=0,
                    help="print a one-line obs counter summary every N "
                         "steps (0 = off; needs --obs-dir)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace of the run into this "
                         "directory (named annotations around the chunk "
                         "scan, psi push, accelerate subproblem, PS fold)")
    ENV.add_process_args(ap)
    args = ap.parse_args()

    # before any device use: latency-hiding flags + the process group
    ENV.apply_async_collective_flags()
    try:
        topo = ENV.initialize_from_args(args)
    except (ValueError, RuntimeError) as e:
        raise SystemExit(str(e))
    if topo.num_processes > 1 and (args.engine or "hybrid") == "async-ps":
        raise SystemExit("--engine async-ps is host-thread-parallel; it "
                         "does not compose with --coordinator "
                         "multi-process runs")

    if (args.arch is None) == (args.model is None):
        raise SystemExit("pass exactly one of --arch or --model")
    if args.model is not None:
        cfg = zoo_config(args.model, args.tier)
        if args.reduced:
            raise SystemExit("--reduced applies to --arch configs; the zoo "
                             "CPU tier is --tier tiny")
    else:
        cfg = get_config(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
    from repro.kernels.policy import kernels_note, resolve_kernels
    ENV.p0print(kernels_note(args.kernels, resolve_kernels(args.kernels)))
    model = build_model(
        cfg, kernels=args.kernels,
        param_dtype=jnp.float32 if args.precision == "f32" else jnp.bfloat16,
        remat=args.remat != "none",
        remat_policy="tp_out" if args.remat == "tp_out" else "full")

    data = make_lm_tokens(0, args.n_seqs, args.seq, cfg.vocab_size)
    sampler = FCPRSampler(data, batch_size=args.batch, seed=1)

    rule = RULES[args.rule]()
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=args.k_sigma,
                      stop=args.stop)
    lr_fn = constant_lr(args.lr)

    engine = args.engine or ("data-parallel" if args.data_parallel
                             else "hybrid")
    if engine == "pjit":
        engine = "hybrid"                 # historical alias, same engine
    obs = _make_observer(args, cfg, icfg, engine)
    try:
        with maybe_profile(args.profile_dir):
            if engine == "async-ps":
                state, dt, steps = run_async_ps(args, cfg, model, sampler,
                                                rule, icfg, lr_fn, obs=obs)
            else:
                state, dt, steps = run_sync(args, cfg, model, sampler, rule,
                                            icfg, lr_fn, engine=engine,
                                            obs=obs)
    except MeshError as e:
        # the CLI boundary: library validation errors become exit codes
        raise SystemExit(str(e))
    if obs is not None:
        # a resumed run missed the pre-restart pushes: chart only, no
        # reconcile claim
        final = obs.finalize(None if args.resume else state,
                             steps=steps, wall=dt)
        if ENV.is_coordinator():
            from repro.obs.recorder import write_merged_summary
            write_merged_summary(args.obs_dir)
        ENV.p0print(f"obs: {args.obs_dir} "
                    f"spc_reconciled={final.get('reconciled', 'n/a')} "
                    f"accel_events={final['accel_events']}")
    ENV.p0print(f"done: {steps} steps in {dt:.1f}s "
                f"({dt/steps*1e3:.0f} ms/step) "
                f"accelerated={int(state.accel_count)} "
                f"sub_iters={int(state.sub_iters)}")


if __name__ == "__main__":
    main()
