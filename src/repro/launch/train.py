"""Distributed training launcher.

On real TPU hardware this runs the ISGD train loop under the production
mesh; on this CPU container it runs reduced configs under a host mesh so the
whole path (sharded params, pjit'd ISGD step with its cond/while_loop,
loss-driven LR) is exercised end-to-end.

Three engines (``--engine``; ``--data-parallel`` remains as an alias):

  * ``pjit`` (default) — pjit/GSPMD over a (data, model) mesh: tensor/FSDP
    parallel weights, activation-sharding constraints (launch/shardings.py);
  * ``data-parallel`` — the shard_map engine (repro.distributed): params
    and ISGD state replicated, batch sharded over 'data', gradients and the
    control statistic ψ explicitly all-reduced so every device takes the
    same accelerate branch (paper §6); input batches ride the
    double-buffered host->device prefetcher;
  * ``async-ps`` — the asynchronous parameter-server engine (paper §6.2,
    repro.distributed.async_ps): ``--workers`` threads over per-worker FCPR
    shards push staleness-weighted deltas (``--staleness-decay``, w(τ)) to
    a server that runs the SPC limit/accelerate logic on globally
    consistent statistics; ``--max-staleness`` bounds how far workers may
    drift apart (0 = lockstep rounds — the synchronous schedule).

Two input/dispatch accelerators compose with the pjit and data-parallel
engines (async-ps is host-orchestrated per worker step and rejects them):

  * ``--device-ring`` — serve batches from the device-resident FCPR ring
    (one epoch upload, batches by dynamic_slice) instead of per-step host
    transfers; falls back to the prefetcher when the epoch busts the byte
    budget;
  * ``--chunk-steps K`` — the fused engine: K full ISGD steps per host
    dispatch (lax.scan over the ring, bit-exact with per-step; the step
    count is rounded up to whole chunks).

  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --reduced --steps 30 --batch 8 --seq 128
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.train --arch internlm2-1.8b --reduced \
      --data-parallel --chunk-steps 8 --steps 32 --batch 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import numpy as np

from repro.configs import get_config
from repro.core import ISGDConfig, consistent_step, isgd_init, isgd_step
from repro.core.schedule import constant_lr
from repro.data import DeviceRing, FCPRSampler, make_lm_tokens, ring_or_prefetch
from repro.distributed import (PrefetchSampler, batch_sharding,
                               make_chunked_data_parallel_step,
                               make_data_parallel_step, replicated)
from repro.launch import shardings as SH
from repro.launch.mesh import make_data_mesh, make_host_mesh
from repro.models import build_model
from repro.optim import RULES
from repro.sharding import activation_sharding, rules
from repro.train.chunked import chunk_over_ring
from repro.train.trainer import make_loss_and_grad


def frontend_embeds(cfg, batch_size: int):
    """Constant zero frontend embeddings for vlm/encdec smoke configs —
    hoisted out of the step loop (they never change across steps)."""
    if cfg.family == "vlm":
        shape = (batch_size, cfg.num_image_tokens, cfg.d_model)
    elif cfg.family == "encdec":
        shape = (batch_size, cfg.encoder_seq, cfg.d_model)
    else:
        return {}
    return {"frontend_embeds": jnp.zeros(shape, jnp.bfloat16)}


def ring_epoch(cfg, sampler, batch_size: int):
    """Epoch arrays for a ``DeviceRing``, with the constant frontend extras
    tiled per-sample so an in-scan ring slice reproduces exactly the batch
    dict the per-step loop would have assembled."""
    epoch = dict(sampler.epoch_arrays())
    for k, v in frontend_embeds(cfg, batch_size).items():
        arr = np.asarray(v)
        epoch[k] = np.tile(arr, (sampler.n_batches,) + (1,) * (arr.ndim - 1))
    return epoch


def _drive_chunks(jchunk, state, params, ring, steps: int, k: int):
    """Run ``steps`` (rounded up to whole chunks) through a fused chunk fn,
    printing the last step of each chunk.  Returns (state, total_steps)."""
    n_chunks = -(-steps // k)
    for c in range(n_chunks):
        state, params, ms = jchunk(state, params, ring.arrays, c * k)
        print(f"step {(c+1)*k:4d} loss={float(ms['loss'][-1]):.4f} "
              f"psi_bar={float(ms['psi_bar'][-1]):.4f} "
              f"limit={float(ms['limit'][-1]):.4f} "
              f"accel={bool(ms['accelerated'][-1])}")
    return state, n_chunks * k


def run_data_parallel(args, cfg, model, sampler, rule, icfg, lr_fn):
    mesh = make_data_mesh()
    n_dev = mesh.shape["data"]
    if args.batch % n_dev:
        raise SystemExit(f"--batch {args.batch} must be a multiple of the "
                         f"{n_dev} devices (it is split across them)")
    print(f"arch={cfg.name} engine=data-parallel devices={n_dev} "
          f"per_device_batch={args.batch // n_dev} "
          f"chunk_steps={args.chunk_steps}")

    params = jax.device_put(model.init(jax.random.PRNGKey(0),
                                       max_seq=args.seq), replicated(mesh))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.1f}M (replicated)")

    if args.chunk_steps > 1:
        # fused engine: sharded device ring + K steps per dispatch
        ring = DeviceRing(ring_epoch(cfg, sampler, args.batch), args.batch,
                          mesh=mesh)
        init_fn, jchunk = make_chunked_data_parallel_step(
            model.loss_fn, rule, icfg, mesh, chunk_steps=args.chunk_steps,
            inconsistent=not args.consistent, lr_fn=lr_fn)
        state = init_fn(params)
        t0 = time.perf_counter()
        state, args.steps = _drive_chunks(jchunk, state, params, ring,
                                          args.steps, args.chunk_steps)
        return state, time.perf_counter() - t0

    init_fn, jstep = make_data_parallel_step(
        model.loss_fn, rule, icfg, mesh,
        inconsistent=not args.consistent, lr_fn=lr_fn)
    state = init_fn(params)

    b_sh = batch_sharding(mesh)
    extra = {k: jax.device_put(v, b_sh)
             for k, v in frontend_embeds(cfg, args.batch).items()}
    if args.device_ring:
        feed = ring_or_prefetch(sampler, mesh=mesh)   # ring if it fits
        print(f"input: {type(feed).__name__}")
    else:
        feed = PrefetchSampler(
            sampler, sharding=SH.data_parallel_shardings(mesh, sampler(0)))
    t0 = time.perf_counter()
    for j in range(args.steps):
        batch = dict(feed(j), **extra)
        state, params, m = jstep(state, params, batch)
        if (j + 1) % 5 == 0 or j == 0:
            print(f"step {j+1:4d} loss={float(m['loss']):.4f} "
                  f"psi_bar={float(m['psi_bar']):.4f} "
                  f"limit={float(m['limit']):.4f} "
                  f"accel={bool(m['accelerated'])}")
    return state, time.perf_counter() - t0


def run_async_ps(args, cfg, model, sampler, rule, icfg, lr_fn):
    from repro.distributed import AsyncPSCoordinator, staleness_reduce_from_spec

    if cfg.family in ("vlm", "encdec"):
        raise SystemExit("--engine async-ps supports decoder-only/cnn "
                         "configs (no constant frontend-embed plumbing)")
    if args.chunk_steps > 1 or args.device_ring:
        raise SystemExit("--chunk-steps/--device-ring do not compose with "
                         "--engine async-ps (workers dispatch per step from "
                         "host snapshots, there is no fused scan or device "
                         "ring in this engine)")
    if sampler.n_batches % args.workers:
        raise SystemExit(f"n_batches={sampler.n_batches} must be a multiple "
                         f"of --workers {args.workers} (per-worker FCPR "
                         f"shards)")
    rctx = staleness_reduce_from_spec(args.staleness_decay)
    print(f"arch={cfg.name} engine=async-ps workers={args.workers} "
          f"max_staleness={args.max_staleness} w(tau)={args.staleness_decay}")

    params = model.init(jax.random.PRNGKey(0), max_seq=args.seq)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.1f}M (canonical copy on the server)")

    coord = AsyncPSCoordinator(
        model.loss_fn, rule, icfg, workers=args.workers,
        max_staleness=args.max_staleness, lr_fn=lr_fn, reduce_ctx=rctx,
        inconsistent=not args.consistent)
    t0 = time.perf_counter()
    params, state, records = coord.run(params, sampler, args.steps)
    dt = time.perf_counter() - t0
    args.steps = len(records)
    for i, r in enumerate(records):
        if (i + 1) % 5 == 0 or i == 0:
            print(f"push {i+1:4d} w{r['worker']} tau={r['tau']} "
                  f"loss={r['loss']:.4f} psi_bar={r['psi_bar']:.4f} "
                  f"limit={r['limit']:.4f} accel={r['accelerated']}")
    taus = [r["tau"] for r in records]
    print(f"staleness: mean_tau={sum(taus)/len(taus):.2f} "
          f"max_tau={max(taus)} "
          f"bound={(2 * args.max_staleness + 1) * (args.workers - 1)}")
    return state, dt


def run_pjit(args, cfg, model, sampler, rule, icfg, lr_fn):
    mesh = make_host_mesh(model=args.model_parallel)
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} devices={mesh.size}")

    key = jax.random.PRNGKey(0)
    params = model.init(key, max_seq=args.seq)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.1f}M")

    lg = make_loss_and_grad(model.loss_fn)

    def step(state, params, batch):
        if args.consistent:
            return consistent_step(rule, lg, state, params, batch, lr_fn(0.0))
        return isgd_step(rule, icfg, lg, state, params, batch, lr_fn(0.0))

    p_sh = SH.params_shardings(mesh, jax.eval_shape(lambda: params))
    state = isgd_init(rule, icfg, params)
    s_sh = SH.state_shardings(mesh, jax.eval_shape(lambda: state), p_sh)
    table = rules.activation_rule_table(mesh, args.batch)
    extra = frontend_embeds(cfg, args.batch)
    with mesh, activation_sharding(rules.make_constrain(mesh, table)):
        params = jax.device_put(params, p_sh)
        state = jax.device_put(state, s_sh)
        t0 = time.perf_counter()
        if args.chunk_steps > 1:
            # fused engine under pjit: scan over the (unsharded) ring; GSPMD
            # re-lays-out the sliced batch per the activation constraints
            ring = DeviceRing(ring_epoch(cfg, sampler, args.batch),
                              args.batch)
            jchunk = jax.jit(
                chunk_over_ring(step, icfg.n_batches, args.chunk_steps),
                donate_argnums=(0, 1))
            state, args.steps = _drive_chunks(jchunk, state, params, ring,
                                              args.steps, args.chunk_steps)
            return state, time.perf_counter() - t0
        jstep = jax.jit(step, donate_argnums=(0, 1))
        feed = ring_or_prefetch(sampler) if args.device_ring else \
            (lambda j: {k: jnp.asarray(v) for k, v in sampler(j).items()})
        for j in range(args.steps):
            batch = dict(feed(j), **extra)
            state, params, m = jstep(state, params, batch)
            if (j + 1) % 5 == 0 or j == 0:
                print(f"step {j+1:4d} loss={float(m['loss']):.4f} "
                      f"psi_bar={float(m['psi_bar']):.4f} "
                      f"limit={float(m['limit']):.4f} "
                      f"accel={bool(m['accelerated'])}")
        dt = time.perf_counter() - t0
    return state, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale variant (CPU)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--rule", default="momentum", choices=list(RULES))
    ap.add_argument("--consistent", action="store_true")
    ap.add_argument("--k-sigma", type=float, default=2.0)
    ap.add_argument("--stop", type=int, default=3)
    ap.add_argument("--n-seqs", type=int, default=64)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--engine", default=None,
                    choices=["pjit", "data-parallel", "async-ps"],
                    help="training engine (default pjit; see module "
                         "docstring)")
    ap.add_argument("--data-parallel", action="store_true",
                    help="alias for --engine data-parallel")
    ap.add_argument("--workers", type=int, default=2,
                    help="async-ps: number of worker threads")
    ap.add_argument("--max-staleness", type=int, default=0,
                    help="async-ps: SSP bound — a worker may start step k "
                         "only when every worker finished step k-N; 0 = "
                         "lockstep (synchronous schedule)")
    ap.add_argument("--staleness-decay", default="inverse",
                    help="async-ps: w(tau) family[:alpha] — inverse "
                         "(1/(1+a*tau)), exp (e^-a*tau), none")
    ap.add_argument("--chunk-steps", type=int, default=1,
                    help="K>1 = fused engine: K ISGD steps per dispatch via "
                         "lax.scan over the device-resident FCPR ring "
                         "(bit-exact with the per-step engine)")
    ap.add_argument("--device-ring", action="store_true",
                    help="per-step engine fed from the device-resident "
                         "FCPR ring instead of host batches (implied by "
                         "--chunk-steps > 1)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)

    data = make_lm_tokens(0, args.n_seqs, args.seq, cfg.vocab_size)
    sampler = FCPRSampler(data, batch_size=args.batch, seed=1)

    rule = RULES[args.rule]()
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=args.k_sigma,
                      stop=args.stop)
    lr_fn = constant_lr(args.lr)

    engine = args.engine or ("data-parallel" if args.data_parallel else "pjit")
    runner = {"pjit": run_pjit, "data-parallel": run_data_parallel,
              "async-ps": run_async_ps}[engine]
    state, dt = runner(args, cfg, model, sampler, rule, icfg, lr_fn)
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({dt/args.steps*1e3:.0f} ms/step) "
          f"accelerated={int(state.accel_count)} "
          f"sub_iters={int(state.sub_iters)}")


if __name__ == "__main__":
    main()
