"""Serving launcher: batched prefill + decode against a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --reduced \
      --batch 4 --prompt-len 32 --decode-steps 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key, max_seq=args.max_seq)
    engine = ServeEngine(model, params, max_seq=args.max_seq)

    prompts = np.random.RandomState(0).randint(
        0, cfg.vocab_size, size=(args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.perf_counter()
    out = engine.generate(prompts, steps=args.decode_steps)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"decoded={args.decode_steps} tokens in {dt:.2f}s "
          f"({args.decode_steps*args.batch/dt:.1f} tok/s)")
    print("sample continuation:", out[0, args.prompt_len:
                                      args.prompt_len + args.decode_steps])


if __name__ == "__main__":
    main()
