"""Serving launcher: one-shot batched generate, or the continuous-batching
slot engine with hot snapshot swap (train-and-serve).

One-shot (the seed path — whole batch prefilled together, decode blocks
until every row finishes):

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --reduced \\
      --engine oneshot --batch 4 --prompt-len 32 --decode-steps 16

Continuous batching (request-level admission into preallocated KV slots;
``repro.serve.scheduler``):

  PYTHONPATH=src python -m repro.launch.serve --model transformer \\
      --requests 16 --mixed-lengths --max-decode-batch 8

Train-and-serve — run concurrently with a trainer publishing snapshots:

  PYTHONPATH=src python -m repro.launch.train --model transformer \\
      --steps 200 --publish-dir /tmp/pub --publish-every 20 &
  PYTHONPATH=src python -m repro.launch.serve --model transformer \\
      --watch --publish-dir /tmp/pub --requests 32

``--watch`` blocks until the first published snapshot, then hot-swaps each
newer one between decode steps (in-flight requests keep their KV; each
completion records the snapshot generations that served it).

``--kernels`` honors the same kernel-selection contract as training
(``repro.kernels.policy``): ``pallas`` resolves to the reference paths
off-TPU.  Timed throughput excludes compile: a warmup pass runs first and
its wall (≈ jit compile) is reported separately.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ZOO_MODELS, ZOO_TIERS, get_config, zoo_config
from repro.models import build_model
from repro.obs.stats import percentile
from repro.obs.timing import maybe_profile
from repro.serve import (ContinuousScheduler, Request, ServeEngine,
                         SnapshotWatcher)


def build_cfg(args):
    if (args.arch is None) == (args.model is None):
        raise SystemExit("pass exactly one of --arch or --model")
    if args.model is not None:
        if args.reduced:
            raise SystemExit("--reduced applies to --arch configs; the zoo "
                             "CPU tier is --tier tiny")
        return zoo_config(args.model, args.tier)
    cfg = get_config(args.arch)
    return cfg.reduced() if args.reduced else cfg


def workload(args, vocab: int) -> list[Request]:
    """Deterministic request set.  ``--mixed-lengths`` varies prompt length
    and token budget 4x (the regime where request-level batching beats the
    batch-blocking one-shot engine)."""
    rng = np.random.RandomState(0)
    reqs = []
    for i in range(args.requests):
        if args.mixed_lengths:
            plen = args.prompt_len * (1, 2, 4)[i % 3]
            steps = max(1, args.decode_steps * (4, 1, 2)[i % 3] // 4)
        else:
            plen, steps = args.prompt_len, args.decode_steps
        prompt = rng.randint(0, vocab, size=(plen,)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=steps))
    return reqs


def run_oneshot(args, cfg, model, params):
    engine = ServeEngine(model, params, max_seq=args.max_seq)
    prompts = np.random.RandomState(0).randint(
        0, cfg.vocab_size, size=(args.batch, args.prompt_len)).astype(np.int32)
    # warmup: same shapes as the timed run, so the timed wall is all decode
    t0 = time.perf_counter()
    engine.generate(prompts, steps=args.decode_steps)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    out = engine.generate(prompts, steps=args.decode_steps)
    dt = time.perf_counter() - t0
    n_tok = args.decode_steps * args.batch
    print(f"arch={cfg.name} engine=oneshot batch={args.batch} "
          f"prompt={args.prompt_len} decoded={args.decode_steps}")
    print(f"compile+first-run: {compile_s:.2f}s (excluded from tok/s)")
    print(f"{n_tok} tokens in {dt:.2f}s ({n_tok/dt:.1f} tok/s)")
    print("sample continuation:", out[0, args.prompt_len:
                                      args.prompt_len + args.decode_steps])


def run_continuous(args, cfg, model, params, watcher, recorder=None):
    reqs = workload(args, cfg.vocab_size)

    sched = ContinuousScheduler(
        model, params, max_batch=args.max_batch, max_seq=args.max_seq,
        max_decode_batch=args.max_decode_batch, max_queue=args.max_queue,
        watcher=watcher, swap_poll_every=args.swap_poll_every,
        recorder=recorder)

    # warmup on the same scheduler (jit caches are per-SlotKV instance):
    # a miniature copy of the workload covers every prompt-length bucket,
    # so the timed run below is compile-free
    t0 = time.perf_counter()
    plens = sorted({len(r.prompt) for r in reqs})
    sched.warmup([Request(rid=-1 - i, prompt=np.zeros(p, np.int32),
                          max_new_tokens=2) for i, p in enumerate(plens)])
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    comps = sched.run(reqs)
    dt = time.perf_counter() - t0
    n_tok = sum(len(c.tokens) for c in comps)
    lat = [t for c in comps for t in c.token_times[1:]]   # steady-state gaps
    gens = sorted({c.gen_finished for c in comps})
    print(f"arch={cfg.name} engine=continuous requests={len(reqs)} "
          f"max_batch={args.max_batch} max_decode_batch={sched.max_decode_batch}")
    print(f"compile+warmup: {compile_s:.2f}s (excluded from tok/s)")
    print(f"{n_tok} tokens in {dt:.2f}s ({n_tok/dt:.1f} tok/s)  "
          f"per-token latency p50={percentile(lat, 50)*1e3:.1f}ms "
          f"p95={percentile(lat, 95)*1e3:.1f}ms")
    print(f"snapshot generations served: {gens or [0]} "
          f"(swaps: {len(sched.swap_events)})")
    for ev in sched.swap_events:
        print(f"  swap @step {ev.step}: generation {ev.generation} "
              f"(trainer step {ev.trainer_step}, load {ev.load_seconds:.2f}s)")
    if recorder is not None:
        recorder.event("serve.summary", tokens=n_tok, wall_s=dt,
                       tokens_per_s=n_tok / dt if dt else 0.0,
                       compile_s=compile_s, **sched.latency_summary())
        recorder.flush()
    c0 = comps[0]
    print("sample continuation:", np.asarray(c0.tokens))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="assigned architecture config (repro.configs)")
    ap.add_argument("--model", default=None, choices=list(ZOO_MODELS),
                    help="paper_transformer zoo family (alternative to "
                         "--arch)")
    ap.add_argument("--tier", default="tiny", choices=list(ZOO_TIERS))
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale variant of --arch (CPU)")
    ap.add_argument("--kernels", default="reference",
                    choices=["pallas", "reference", "interpret"],
                    help="hot-spot implementations — the same contract as "
                         "training (repro.kernels.policy; pallas falls "
                         "back to reference off-TPU)")
    ap.add_argument("--precision", default="bf16", choices=["bf16", "f32"],
                    help="param/compute dtype; must match the trainer's "
                         "when restoring published snapshots")
    ap.add_argument("--engine", default="continuous",
                    choices=["oneshot", "continuous"],
                    help="oneshot = the seed batch-blocking generate; "
                         "continuous = slot-based continuous batching")
    ap.add_argument("--batch", type=int, default=4,
                    help="oneshot: rows per generate call")
    ap.add_argument("--requests", type=int, default=16,
                    help="continuous: workload size")
    ap.add_argument("--mixed-lengths", action="store_true",
                    help="continuous: vary prompt length and token budget "
                         "4x across requests")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16,
                    help="new tokens per request (max_new_tokens)")
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=8,
                    help="continuous: preallocated KV slots")
    ap.add_argument("--max-decode-batch", type=int, default=0,
                    help="continuous: admission-control cap on concurrently "
                         "decoding requests (0 = max-batch; the serving "
                         "mirror of the paper's batch-size knob)")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="continuous: bounded request backlog; submits "
                         "beyond it are shed")
    ap.add_argument("--watch", action="store_true",
                    help="poll --publish-dir and hot-swap each newer "
                         "snapshot between decode steps")
    ap.add_argument("--publish-dir", default=None)
    ap.add_argument("--watch-timeout", type=float, default=120.0,
                    help="seconds to wait for the first published snapshot")
    ap.add_argument("--swap-poll-every", type=int, default=8,
                    help="decode steps between watcher polls")
    ap.add_argument("--obs-dir", default=None,
                    help="write structured metrics/event JSONL here "
                         "(repro.obs; admit/retire/swap events, token-gap "
                         "histograms, final latency summary)")
    ap.add_argument("--obs-console-every", type=int, default=0,
                    help="with --obs-dir: also print a console metrics "
                         "line at flush boundaries (0 = off)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace of the serve run "
                         "into this directory")
    args = ap.parse_args()

    cfg = build_cfg(args)
    from repro.kernels.policy import kernels_note, resolve_kernels
    print(kernels_note(args.kernels, resolve_kernels(args.kernels)))
    model = build_model(
        cfg, kernels=args.kernels,
        param_dtype=jnp.float32 if args.precision == "f32" else jnp.bfloat16)
    params = model.init(jax.random.PRNGKey(0), max_seq=args.max_seq)

    recorder = None
    if args.obs_dir:
        from repro.obs import (ConsoleSink, JsonlSink, MetricsRecorder,
                               jsonl_path, write_merged_summary)
        sinks = [JsonlSink(jsonl_path(args.obs_dir, 0))]
        if args.obs_console_every:
            sinks.append(ConsoleSink(every=args.obs_console_every,
                                     step_counter="serve/retired"))
        recorder = MetricsRecorder(
            sinks, tags={"process_id": 0, "engine": f"serve-{args.engine}",
                         "model": cfg.name})

    watcher = None
    if args.watch:
        if not args.publish_dir:
            raise SystemExit("--watch needs --publish-dir")
        if args.engine != "continuous":
            raise SystemExit("--watch requires --engine continuous (the "
                             "one-shot engine has no between-step swap "
                             "point)")
        watcher = SnapshotWatcher(args.publish_dir, params_like=params,
                                  recorder=recorder)
        snap = watcher.wait_for_first(timeout=args.watch_timeout)
        params = snap.params
        print(f"serving snapshot generation {snap.generation} "
              f"(trainer step {snap.step}, {snap.path})")

    with maybe_profile(args.profile_dir):
        if args.engine == "oneshot":
            run_oneshot(args, cfg, model, params)
        else:
            run_continuous(args, cfg, model, params, watcher,
                           recorder=recorder)

    if recorder is not None:
        recorder.close()
        write_merged_summary(args.obs_dir)
        print(f"obs: {args.obs_dir}")


if __name__ == "__main__":
    main()
