import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks the device count at
# first backend init.  512 host devices stand in for 2 pods × 256 v5e chips.

_DOC = """Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) combination this lowers the
appropriate step function (train_step w/ ISGD, prefill, or serve_step)
against ShapeDtypeStruct inputs — no allocation — then ``.compile()``s it
under the production mesh and records memory_analysis / cost_analysis /
collective traffic for the roofline report.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
      --shape train_4k [--multi-pod] [--fsdp/--no-fsdp] [--out DIR]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import dataclasses
import json
import time
from functools import partial

import jax

from repro.analysis import roofline
from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, shape_applicable
from repro.core import ISGDConfig, isgd_init, isgd_step
from repro.core.schedule import constant_lr
from repro.launch import shardings as SH
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim import momentum
from repro.sharding import activation_sharding, rules
from repro.train.trainer import make_loss_and_grad


def _mesh_name(mesh) -> str:
    return "x".join(f"{mesh.shape[a]}{a}" for a in mesh.axis_names)


def build_step(model, mesh, shape, *, inconsistent=True, fsdp=True,
               isgd_stop=5, cache_shard="feature", micro=1):
    """Returns (fn, arg_shapes, in_shardings, out_shardings, donate)."""
    cfg = model.cfg
    key = jax.random.PRNGKey(0)
    seq_shard = shape.kind != "train" and shape.global_batch == 1
    max_seq = shape.seq_len if cfg.family == "encdec" else 4096
    params_shapes = jax.eval_shape(partial(model.init, max_seq=max_seq), key)
    p_sh = SH.params_shardings(mesh, params_shapes, fsdp=fsdp)

    if shape.kind == "train":
        rule = momentum(0.9)
        icfg = ISGDConfig(n_batches=64, stop=isgd_stop)
        lg = make_loss_and_grad(model.loss_fn, micro_batches=micro)
        lr_fn = constant_lr(0.01)

        def train_step(state, params, batch):
            if inconsistent:
                state, params, metrics = isgd_step(
                    rule, icfg, lg, state, params, batch, lr_fn(0.0))
            else:
                from repro.core import consistent_step
                state, params, metrics = consistent_step(
                    rule, lg, state, params, batch, lr_fn(0.0))
            return state, params, metrics["loss"]

        state_shapes = jax.eval_shape(partial(isgd_init, rule, icfg),
                                      params_shapes)
        s_sh = SH.state_shardings(mesh, state_shapes, p_sh)
        b_specs = model.input_specs(shape)
        b_sh = SH.batch_shardings(mesh, b_specs)
        return (train_step, (state_shapes, params_shapes, b_specs),
                (s_sh, p_sh, b_sh), (s_sh, p_sh, None))

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill_fn(params, batch)

        b_specs = model.input_specs(shape)
        b_sh = SH.batch_shardings(mesh, b_specs)
        return (prefill_step, (params_shapes, b_specs), (p_sh, b_sh), None)

    # decode
    cache_shapes = jax.eval_shape(
        partial(model.init_cache, shape.global_batch, shape.seq_len))
    c_sh = SH.cache_shardings(mesh, cache_shapes, seq_shard=seq_shard,
                              mode=cache_shard)

    def serve_step(params, cache, tokens):
        return model.decode_fn(params, cache, tokens)

    tok = model.input_specs(shape)["tokens"]
    t_sh = SH.batch_shardings(mesh, {"tokens": tok})["tokens"]
    return (serve_step, (params_shapes, cache_shapes, tok),
            (p_sh, c_sh, t_sh), (None, c_sh))


def dryrun_one(arch: str, shape_name: str, *, multi_pod=False, fsdp=True,
               inconsistent=True, out_dir="experiments/dryrun", quiet=False,
               isgd_stop=5, tag="", cache_shard="feature", micro=1,
               remat_policy="full"):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        if not quiet:
            print(f"SKIP {arch} × {shape_name}: {reason}")
        return None
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    model = build_model(cfg, remat_policy=remat_policy)
    t0 = time.time()
    fn, arg_shapes, in_sh, out_sh = build_step(
        model, mesh, shape, fsdp=fsdp, inconsistent=inconsistent,
        isgd_stop=isgd_stop, cache_shard=cache_shard, micro=micro)

    table = rules.activation_rule_table(
        mesh, shape.global_batch,
        seq_shard=(shape.kind != "train" and shape.global_batch == 1))
    with mesh, activation_sharding(rules.make_constrain(mesh, table)):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*arg_shapes)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mf = roofline.model_flops(cfg, shape, chips)
    rl = roofline.analyze(compiled, arch=arch, shape=shape_name,
                          mesh_name=_mesh_name(mesh), chips=chips,
                          model_flops_per_device=mf)
    mem = compiled.memory_analysis()
    if not quiet:
        print(f"PASS {arch} × {shape_name} × {rl.mesh}  "
              f"lower={t_lower:.0f}s compile={t_compile:.0f}s")
        print(f"  mem/device: args={mem.argument_size_in_bytes/1e9:.2f}GB "
              f"temp={mem.temp_size_in_bytes/1e9:.2f}GB "
              f"out={mem.output_size_in_bytes/1e9:.2f}GB")
        print(f"  per-device: {rl.hlo_gflops:.1f} GFLOP, {rl.hlo_gbytes:.1f} GB "
              f"HBM, {rl.collective_gbytes:.3f} GB collective")
        print(f"  roofline: compute={rl.compute_s*1e3:.2f}ms "
              f"memory={rl.memory_s*1e3:.2f}ms "
              f"collective={rl.collective_s*1e3:.2f}ms -> {rl.bottleneck}-bound; "
              f"useful-flops={rl.useful_flops_ratio:.2f}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        rec = dataclasses.asdict(rl)
        rec.update(lower_s=t_lower, compile_s=t_compile, fsdp=fsdp,
                   inconsistent=inconsistent, micro=micro,
                   cache_shard=cache_shard,
                   arg_gb=mem.argument_size_in_bytes / 1e9,
                   temp_gb=mem.temp_size_in_bytes / 1e9)
        fname = f"{arch}_{shape_name}_{rl.mesh}{tag}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
    return rl


def _cfg_with_blocks(cfg, k: int):
    """Config truncated to k layer-blocks (same pattern) for extrapolation."""
    from repro.models.transformer import stack_plan
    prefix, block, n_blocks = stack_plan(cfg)
    repl = {"num_layers": cfg.first_dense + k * len(block)}
    if cfg.family == "encdec":
        # encoder layers scale with the same k (whisper: 1 enc layer per block)
        repl["encoder_layers"] = max(1, k * cfg.encoder_layers // n_blocks)
    return dataclasses.replace(cfg, **repl), n_blocks


def analysis_one(arch: str, shape_name: str, *, multi_pod=False, fsdp=True,
                 inconsistent=True, isgd_stop=5, out_dir="experiments/roofline",
                 quiet=False, tag="", build_step_fn=None,
                 cache_shard="feature", micro=1, remat_policy="full"):
    """Trip-count-honest roofline terms via two-point extrapolation over
    n_blocks (analysis/mode.py).  Records a Roofline JSON per pair."""
    from repro.analysis.mode import analysis_mode

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        if not quiet:
            print(f"SKIP {arch} × {shape_name}: {reason}")
        return None
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    builder = build_step_fn or build_step

    raw = {}
    for k in (1, 2):
        cfg_k, n_blocks = _cfg_with_blocks(cfg, k)
        model = build_model(cfg_k, remat_policy=remat_policy)
        fn, arg_shapes, in_sh, out_sh = builder(
            model, mesh, shape, fsdp=fsdp, inconsistent=inconsistent,
            isgd_stop=isgd_stop, cache_shard=cache_shard, micro=micro)
        table = rules.activation_rule_table(
            mesh, shape.global_batch,
            seq_shard=(shape.kind != "train" and shape.global_batch == 1))
        with mesh, activation_sharding(rules.make_constrain(mesh, table)), \
                analysis_mode():
            compiled = jax.jit(fn, in_shardings=in_sh,
                               out_shardings=out_sh).lower(*arg_shapes).compile()
        cost = compiled.cost_analysis()
        cstats = roofline.collective_stats(compiled.as_text())
        raw[k] = dict(
            flops=float(cost.get("flops", 0.0)),
            bytes=float(cost.get("bytes accessed", 0.0)),
            cbytes=float(sum(v["bytes"] for v in cstats.values())),
            cstats=cstats,
        )

    def extrap(key):
        return raw[1][key] + (n_blocks - 1) * (raw[2][key] - raw[1][key])

    flops, bytes_, cbytes = extrap("flops"), extrap("bytes"), extrap("cbytes")
    hw = roofline.V5E
    compute_s = flops / hw["peak_flops"]
    memory_s = bytes_ / hw["hbm_bw"]
    collective_s = cbytes / hw["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    mf = roofline.model_flops(cfg, shape, chips)
    rl = roofline.Roofline(
        arch=arch, shape=shape_name, mesh=_mesh_name(mesh), chips=chips,
        hlo_gflops=flops / 1e9, hlo_gbytes=bytes_ / 1e9,
        collective_gbytes=cbytes / 1e9, compute_s=compute_s,
        memory_s=memory_s, collective_s=collective_s,
        bottleneck=max(terms, key=terms.get),
        model_gflops=mf / 1e9,
        useful_flops_ratio=(mf / flops) if flops else 0.0,
        collectives={k: {"count": raw[2]["cstats"][k]["count"],
                         "bytes": raw[1]["cstats"][k]["bytes"]
                         + (n_blocks - 1) * (raw[2]["cstats"][k]["bytes"]
                                             - raw[1]["cstats"][k]["bytes"])}
                     for k in raw[2]["cstats"]},
    )
    if not quiet:
        print(f"ROOFLINE {arch} × {shape_name} × {rl.mesh}: "
              f"compute={compute_s*1e3:.2f}ms memory={memory_s*1e3:.2f}ms "
              f"collective={collective_s*1e3:.2f}ms -> {rl.bottleneck}-bound "
              f"useful={rl.useful_flops_ratio:.2f}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        rec = dataclasses.asdict(rl)
        rec.update(fsdp=fsdp, inconsistent=inconsistent, isgd_stop=isgd_stop,
                   cache_shard=cache_shard, micro=micro,
                   remat_policy=remat_policy)
        with open(os.path.join(
                out_dir, f"{arch}_{shape_name}_{rl.mesh}{tag}.json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-fsdp", dest="fsdp", action="store_false")
    ap.add_argument("--consistent", dest="inconsistent", action="store_false",
                    help="lower the baseline (non-ISGD) train step")
    ap.add_argument("--isgd-stop", type=int, default=5)
    ap.add_argument("--cache-shard", default="feature",
                    choices=["feature", "batch"],
                    help="decode cache layout (§Perf lever)")
    ap.add_argument("--micro", type=int, default=1,
                    help="gradient-accumulation micro-batches (§Perf lever)")
    ap.add_argument("--remat-policy", default="full",
                    choices=["full", "tp_out"],
                    help="activation-checkpoint policy (§Perf lever)")
    ap.add_argument("--tag", default="", help="suffix for the output json")
    ap.add_argument("--out", default=None)
    ap.add_argument("--mode", default="dryrun", choices=["dryrun", "analysis"],
                    help="dryrun = full-depth lower+compile (deliverable e); "
                         "analysis = trip-honest roofline extrapolation (g)")
    args = ap.parse_args()
    out_dir = args.out or ("experiments/dryrun" if args.mode == "dryrun"
                           else "experiments/roofline")

    pairs = []
    if args.all:
        pairs = [(a, s) for a in ARCH_IDS for s in INPUT_SHAPES]
    else:
        archs = [args.arch] if args.arch else ARCH_IDS
        shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
        pairs = [(a, s) for a in archs for s in shapes]

    run = dryrun_one if args.mode == "dryrun" else analysis_one
    failures = []
    for arch, shape in pairs:
        try:
            run(arch, shape, multi_pod=args.multi_pod, fsdp=args.fsdp,
                inconsistent=args.inconsistent, out_dir=out_dir,
                isgd_stop=args.isgd_stop, tag=args.tag,
                cache_shard=args.cache_shard, micro=args.micro,
                remat_policy=args.remat_policy)
        except Exception as e:  # noqa: BLE001 — report all failures at end
            failures.append((arch, shape, repr(e)[:200]))
            print(f"FAIL {arch} × {shape}: {e!r}"[:400])
    if failures:
        print(f"\n{len(failures)} FAILURES")
        raise SystemExit(1)
    print("\nALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()
