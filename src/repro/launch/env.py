"""Process/runtime environment layer: ``jax.distributed.initialize`` wiring
and XLA flag composition (ROADMAP: multi-host 3-D mesh scale-out).

Everything here must run BEFORE jax initializes its backend — XLA reads
``XLA_FLAGS`` exactly once, and ``jax.distributed.initialize`` must precede
the first device query.  The helpers are therefore pure environment/config
edits with three hard guarantees (pinned by ``tests/test_env.py``):

  * **append, never clobber** — a user-set ``XLA_FLAGS`` survives; our
    flags are appended after it and a flag the user already set is left
    alone (the user's value wins);
  * **idempotent** — calling any helper twice composes to the same
    environment as calling it once (re-entry before
    ``jax.distributed.initialize`` is a no-op);
  * **single init** — :func:`initialize_distributed` initializes the
    process group exactly once and returns the same
    :class:`ProcessTopology` on re-entry.

Flag sets (modeled on the bayespec config exemplar, SNIPPETS.md §1): the
GPU latency-hiding group overlaps async collectives with compute — exactly
the Eq.21 C2 sync-overhead term the paper's batch-size study amortizes, so
on a real cluster these flags move the measured knee.  On CPU the helper
instead selects the gloo cross-process collective implementation, which is
what lets the same-machine multi-process parity harness
(``repro.distributed.multihost_parity``) run real cross-process psums.

CLI wiring: ``add_process_args`` / ``initialize_from_args`` give every
launcher the same ``--coordinator/--num-processes/--process-id`` surface:

    PYTHONPATH=src python -m repro.launch.train ... \
        --coordinator 127.0.0.1:12345 --num-processes 2 --process-id 0
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Mapping, Optional, Sequence

#: GPU async-collective / latency-hiding flags (SNIPPETS.md §1).  Names
#: only here — values are applied via :func:`apply_xla_flags` so a user
#: override of any one of them wins.
GPU_ASYNC_FLAGS = (
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def _flag_name(flag: str) -> str:
    """``--xla_foo=3`` -> ``--xla_foo`` (XLA flags are name[=value])."""
    return flag.split("=", 1)[0]


def apply_xla_flags(flags: Sequence[str], *, env: Optional[Mapping] = None,
                    override: bool = False) -> str:
    """Append ``flags`` to ``env['XLA_FLAGS']`` without clobbering it.

    A flag whose *name* already appears in the variable is skipped (the
    existing — usually user-set — value wins) unless ``override=True``, in
    which case the existing occurrence is removed and the new value
    appended (later flags win in XLA's parser anyway; removing keeps the
    variable readable).  Both paths are idempotent: re-applying the same
    flags leaves the variable unchanged.  Returns the new value.
    """
    env = os.environ if env is None else env
    current = [f for f in env.get("XLA_FLAGS", "").split() if f]
    have = {_flag_name(f) for f in current}
    for flag in flags:
        name = _flag_name(flag)
        if name in have:
            if not override or flag in current:
                continue
            current = [f for f in current if _flag_name(f) != name]
        current.append(flag)
        have.add(name)
    env["XLA_FLAGS"] = " ".join(current)
    return env["XLA_FLAGS"]


def apply_async_collective_flags(platform: Optional[str] = None, *,
                                 env: Optional[Mapping] = None) -> str:
    """Latency-hiding/async-collective environment for ``platform``
    (default: ``$JAX_PLATFORMS`` or cpu).  GPU gets the SNIPPETS.md §1 flag
    group; CPU/TPU need no XLA flags (CPU cross-process collectives are
    selected in :func:`initialize_distributed` via the gloo config knob,
    not XLA_FLAGS).  Append-only and idempotent like every helper here."""
    env = os.environ if env is None else env
    platform = platform or env.get("JAX_PLATFORMS", "cpu").split(",")[0]
    if platform == "gpu":
        return apply_xla_flags(GPU_ASYNC_FLAGS, env=env)
    return env.get("XLA_FLAGS", "")


def force_host_device_count(n: int, *, env: Optional[Mapping] = None) -> str:
    """Split the host CPU into ``n`` XLA devices (test/parity harnesses).
    Overrides an existing count (forcing is the point) but preserves every
    other flag in the variable."""
    return apply_xla_flags(
        [f"--xla_force_host_platform_device_count={int(n)}"],
        env=env, override=True)


@dataclass(frozen=True)
class ProcessTopology:
    """The process grid a run executes on — recorded by benchmarks
    (``fig8_scaling`` JSON schema) so multi-host cells can't be conflated
    with single-host ones in the Eq.21 fits."""

    process_id: int = 0
    num_processes: int = 1
    coordinator: Optional[str] = None

    @property
    def is_coordinator(self) -> bool:
        return self.process_id == 0


_TOPOLOGY: Optional[ProcessTopology] = None


def initialize_distributed(coordinator: Optional[str] = None,
                           num_processes: Optional[int] = None,
                           process_id: Optional[int] = None,
                           ) -> ProcessTopology:
    """Wire up ``jax.distributed.initialize`` for a multi-process run.

    Single-process (no coordinator, or ``num_processes in (None, 1)``) is a
    no-op that returns the trivial topology — callers can call this
    unconditionally.  On CPU the gloo cross-process collective
    implementation is selected first (the default 'none' cannot execute
    cross-process psums).  Idempotent: a second call returns the topology
    of the first and never re-initializes; a second call with *different*
    arguments raises, because a half-switched process group is undebuggable.
    """
    global _TOPOLOGY
    if coordinator is None and (num_processes or 1) == 1:
        return _TOPOLOGY or ProcessTopology()
    if num_processes is None or process_id is None:
        raise ValueError("--coordinator needs both --num-processes and "
                         "--process-id")
    topo = ProcessTopology(process_id=int(process_id),
                           num_processes=int(num_processes),
                           coordinator=coordinator)
    if _TOPOLOGY is not None:
        if _TOPOLOGY != topo:
            raise RuntimeError(
                f"jax.distributed already initialized as {_TOPOLOGY}; "
                f"cannot re-initialize as {topo}")
        return _TOPOLOGY
    import jax
    if os.environ.get("JAX_PLATFORMS", "cpu").split(",")[0] in ("", "cpu"):
        # cross-process CPU collectives need a real implementation
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=int(num_processes),
                               process_id=int(process_id))
    _TOPOLOGY = topo
    return topo


def topology() -> ProcessTopology:
    """The current process topology as jax sees it (valid after backend
    init; falls back to the recorded init arguments before that)."""
    import jax
    try:
        return ProcessTopology(process_id=jax.process_index(),
                               num_processes=jax.process_count(),
                               coordinator=(_TOPOLOGY.coordinator
                                            if _TOPOLOGY else None))
    except Exception:
        return _TOPOLOGY or ProcessTopology()


def is_coordinator() -> bool:
    """True on the process that owns logging/checkpoint-writing duties."""
    return topology().is_coordinator


def p0print(*args, **kwargs) -> None:
    """Print only on process 0 — delegates to the obs console sink
    (``repro.obs.console.CONSOLE``), the one mechanism that keeps non-zero
    processes quiet for progress lines and warnings alike."""
    from repro.obs.console import CONSOLE
    CONSOLE.print(*args, **kwargs)


def add_process_args(parser) -> None:
    """The shared ``--coordinator/--num-processes/--process-id`` CLI
    surface (launch/train, parity harnesses, benchmarks)."""
    parser.add_argument("--coordinator", default=None,
                        help="host:port of process 0's coordination "
                             "service; presence switches the run to "
                             "multi-process (jax.distributed.initialize)")
    parser.add_argument("--num-processes", type=int, default=None,
                        help="total process count of the multi-process run")
    parser.add_argument("--process-id", type=int, default=None,
                        help="this process's index in [0, num_processes)")


def initialize_from_args(args) -> ProcessTopology:
    """``add_process_args`` namespace -> initialized topology (no-op when
    the run is single-process)."""
    return initialize_distributed(coordinator=args.coordinator,
                                  num_processes=args.num_processes,
                                  process_id=args.process_id)
