"""Process-aware training meshes: one factory from the single-host debug
mesh to the multi-pod production grid.

A function, not a module-level constant — importing this module must never
touch jax device state (the dry-run and the launchers set XLA_FLAGS and
``jax.distributed.initialize`` before first init; see ``repro.launch.env``).

:func:`make_training_mesh` is the single factory.  It builds over the
**global** device set (every process's devices, ordered process-major) and
produces

  * ``(data, model)`` when the pod axis is trivial — the historical
    single-host hybrid mesh, byte-compatible with what
    ``make_host_mesh`` always returned;
  * ``(pod, data, model)`` when ``pod > 1`` — one pod row per process by
    default (``pod = jax.process_count()``), so the flattened
    ``("pod", "data")`` order walks process 0's devices first, then
    process 1's, …  That ordering is load-bearing: the FCPR data layer
    stripes the permuted epoch by process index against exactly this
    flat order (``repro.data.device_ring``), and ψ/grad reduction over
    ``("pod", "data")`` in flat shard order reproduces the single-host
    ``("data",)`` reduction bit-exactly (``core/reduce.py``,
    ``AxisReduce(deterministic=True)``).

Validation failures raise :class:`MeshError` (a ``ValueError``) — library
code never calls ``SystemExit``; the CLI boundary in ``launch/train.py``
translates.  ``make_host_mesh``/``make_data_mesh``/``make_production_mesh``
remain as thin views of the factory for their existing callers.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np


class MeshError(ValueError):
    """A requested mesh shape cannot be built from the available devices
    (non-divisible axis sizes, or a device order that breaks the
    process-striping contract)."""


def _make_mesh(shape, axes, devices=None):
    """jax.make_mesh across jax versions: ``axis_types`` only exists on
    newer jax (jax.sharding.AxisType landed after 0.4.x); default behaviour
    there is Auto, which is what we want everywhere."""
    kwargs = {}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kwargs["axis_types"] = (axis_type.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, devices=devices, **kwargs)


def global_device_order(devices=None) -> list:
    """The canonical global device order: process-major, then id — the
    order the pod axis, the FCPR stripes, and the deterministic reduction
    all key on."""
    devs = list(devices) if devices is not None else jax.devices()
    return sorted(devs, key=lambda d: (d.process_index, d.id))


def data_axes(mesh) -> tuple:
    """The data sub-axes of a training mesh, in reduction (pod-major flat)
    order — what ``AxisReduce``/``P`` specs should span for ψ/grad
    reduction and batch sharding.  ``("pod", "data")`` on a 3-D mesh,
    ``("data",)`` otherwise."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def is_multiprocess(mesh) -> bool:
    """True when the mesh spans devices of more than one process."""
    procs = {d.process_index for d in mesh.devices.flat}
    return len(procs) > 1


def _check_pod_rows(mesh) -> None:
    """Multi-process meshes must keep each process's devices contiguous
    along the flattened ``(pod, data)`` order, or the data layer's
    process striping would interleave rows across hosts."""
    if not is_multiprocess(mesh):
        return
    rows = mesh.devices.reshape(-1, mesh.shape["model"])
    procs = [rows[i, 0].process_index for i in range(rows.shape[0])]
    for i in range(1, len(procs)):
        if procs[i] < procs[i - 1]:
            raise MeshError(
                f"mesh devices are not process-contiguous along the "
                f"flattened (pod, data) order (process sequence {procs}); "
                f"the FCPR striping contract needs process p's devices in "
                f"one contiguous block — build the mesh through "
                f"make_training_mesh over global_device_order()")


def make_training_mesh(model: int = 1, *, pod: Optional[int] = None,
                       devices=None):
    """THE mesh factory: ``(pod, data, model)`` over the global device set.

    ``model`` devices go to the tensor-parallel axis; ``pod`` (default: the
    process count, so one pod per host process) splits the remainder's
    outer dim; what's left is ``data``.  ``pod == 1`` drops the pod axis
    and returns the historical 2-D ``(data, model)`` mesh so single-host
    callers (and their compiled-program caches) see exactly what
    ``make_host_mesh`` always built.  An explicit ``devices`` list pins a
    sub-mesh (parity tests build ``(1, 1)`` meshes on multi-device
    processes).

    Raises :class:`MeshError` on non-divisible shapes — library callers
    get a ``ValueError`` they can handle; only the CLI translates it to an
    exit code.
    """
    devs = global_device_order(devices)
    n = len(devs)
    if model < 1 or n % model:
        raise MeshError(
            f"model-parallel degree must divide the device count: "
            f"n={n} devices, M={model} (choose M from the divisors of {n})")
    if pod is None:
        pod = len({d.process_index for d in devs})
    if pod < 1 or n % (pod * model):
        raise MeshError(
            f"pod axis must divide the non-model device count: n={n} "
            f"devices, pod={pod}, M={model} (n must be a multiple of "
            f"pod*M={pod * model})")
    if pod == 1:
        return _make_mesh((n // model, model), ("data", "model"),
                          devices=devs)
    mesh = _make_mesh((pod, n // (pod * model), model),
                      ("pod", "data", "model"), devices=devs)
    _check_pod_rows(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (v5e-256).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips across DCI."""
    return make_training_mesh(model=16, pod=2 if multi_pod else 1)


def make_host_mesh(model: int = 1, devices=None):
    """2-D ``(data, model)`` mesh over however many (CPU) devices exist —
    the hybrid DP × TP engine's single-host debug mesh.  ``model`` of the
    devices go to the tensor-parallel axis; the rest form the data axis.
    An explicit ``devices`` list pins a sub-mesh (parity tests use it to
    build a ``(1, 1)`` mesh on a multi-device process).  Raises
    :class:`MeshError` when ``model`` doesn't divide the device count."""
    return make_training_mesh(model=model, pod=1, devices=devices)


def make_data_mesh(devices=None):
    """1-axis ('data',) mesh for the pure data-parallel ISGD engine
    (repro.distributed): params/state replicated, batch sharded.  Uses every
    device unless an explicit list is given."""
    n = len(devices) if devices is not None else len(jax.devices())
    return _make_mesh((n,), ("data",), devices=devices)


def local_data_block(mesh, axis=None) -> tuple:
    """This process's contiguous block ``(lo, hi, total)`` of flattened
    data-shard positions on ``mesh`` — the index range the FCPR data layer
    stripes the global epoch by (``repro.data.device_ring``).

    ``axis`` defaults to :func:`data_axes`.  On a single-process mesh the
    block is ``(0, total, total)``.  Raises :class:`MeshError` when this
    process's devices do not form one contiguous run (the striping
    contract; meshes from :func:`make_training_mesh` always satisfy it).
    """
    axes = data_axes(mesh) if axis is None else (
        (axis,) if isinstance(axis, str) else tuple(axis))
    # flatten device grid to (flat_data, model): move data axes first, in
    # pod-major order, then everything else
    names = list(mesh.axis_names)
    order = [names.index(a) for a in axes] + [
        i for i, a in enumerate(names) if a not in axes]
    grid = np.transpose(mesh.devices, order)
    total = int(np.prod([mesh.shape[a] for a in axes]))
    flat = grid.reshape(total, -1)
    pid = jax.process_index()
    mine = [i for i in range(total)
            if flat[i, 0].process_index == pid]
    if not mine:
        raise MeshError(f"process {pid} owns no devices on this mesh")
    lo, hi = mine[0], mine[-1] + 1
    if mine != list(range(lo, hi)):
        raise MeshError(
            f"process {pid}'s data-shard positions {mine} are not "
            f"contiguous; build the mesh through make_training_mesh")
    return lo, hi, total
