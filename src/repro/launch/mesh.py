"""Production mesh definitions (TPU v5e).

A function, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes, devices=None):
    """jax.make_mesh across jax versions: ``axis_types`` only exists on
    newer jax (jax.sharding.AxisType landed after 0.4.x); default behaviour
    there is Auto, which is what we want everywhere."""
    kwargs = {}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kwargs["axis_types"] = (axis_type.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, devices=devices, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (v5e-256).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips across DCI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Debug mesh over however many (CPU) devices exist."""
    n = len(jax.devices())
    data = n // model
    return _make_mesh((data, model), ("data", "model"))


def make_data_mesh(devices=None):
    """1-axis ('data',) mesh for the pure data-parallel ISGD engine
    (repro.distributed): params/state replicated, batch sharded.  Uses every
    device unless an explicit list is given."""
    n = len(devices) if devices is not None else len(jax.devices())
    return _make_mesh((n,), ("data",), devices=devices)
