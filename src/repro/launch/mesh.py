"""Production mesh definitions (TPU v5e).

A function, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes, devices=None):
    """jax.make_mesh across jax versions: ``axis_types`` only exists on
    newer jax (jax.sharding.AxisType landed after 0.4.x); default behaviour
    there is Auto, which is what we want everywhere."""
    kwargs = {}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kwargs["axis_types"] = (axis_type.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, devices=devices, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (v5e-256).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips across DCI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model: int = 1, devices=None):
    """2-D ``(data, model)`` mesh over however many (CPU) devices exist —
    the hybrid DP × TP engine's debug mesh.  ``model`` of the devices go to
    the tensor-parallel axis; the rest form the data axis.  An explicit
    ``devices`` list pins a sub-mesh (parity tests use it to build a
    ``(1, 1)`` mesh on a multi-device process)."""
    devs = list(devices) if devices is not None else jax.devices()
    n = len(devs)
    if model < 1 or n % model:
        raise SystemExit(
            f"model-parallel degree must divide the device count: "
            f"n={n} devices, M={model} (choose M from the divisors of {n})")
    return _make_mesh((n // model, model), ("data", "model"), devices=devs)


def make_data_mesh(devices=None):
    """1-axis ('data',) mesh for the pure data-parallel ISGD engine
    (repro.distributed): params/state replicated, batch sharded.  Uses every
    device unless an explicit list is given."""
    n = len(devices) if devices is not None else len(jax.devices())
    return _make_mesh((n,), ("data",), devices=devices)
