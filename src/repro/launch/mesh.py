"""Production mesh definitions (TPU v5e).

A function, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (v5e-256).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips across DCI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Debug mesh over however many (CPU) devices exist."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
