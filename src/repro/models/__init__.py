from repro.models.api import Model, build_model
from repro.models.cnn import cnn_accuracy, cnn_logits, cnn_loss_fn, init_cnn

__all__ = ["Model", "build_model", "init_cnn", "cnn_logits", "cnn_loss_fn",
           "cnn_accuracy"]
