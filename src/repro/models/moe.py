"""Mixture-of-Experts layer: top-k router + GShard-style capacity dispatch.

TPU-native design (DESIGN.md §3): tokens are processed in groups of
``GROUP_SIZE``; within each group, one-hot dispatch/combine tensors of shape
(g, E, C) route tokens to per-expert buffers.  The dispatch tensor size is
g·topk·cf per token — independent of the expert count — so DeepSeek-V2's 64
experts cost the same routing memory as Mixtral's 8.  The expert dimension is
sharded over the "model" mesh axis when divisible (expert parallelism ⇒
all-to-all under GSPMD); otherwise the per-expert hidden dim is sharded
(the Mixtral 8-expert fallback).

Router load-balancing uses the standard auxiliary loss (Switch §2.2) — the
mean over experts of (fraction of tokens routed) × (mean router prob).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

GROUP_SIZE = 128


def init_moe(key, cfg, dtype=jnp.bfloat16):
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "wg": dense_init(ks[1], (E, d, ff), dtype),
        "wi": dense_init(ks[2], (E, d, ff), dtype),
        "wo": dense_init(ks[3], (E, ff, d), dtype),
    }
    if cfg.num_shared_experts:
        sff = ff * cfg.num_shared_experts
        p["swg"] = dense_init(ks[4], (d, sff), dtype)
        p["swi"] = dense_init(ks[5], (d, sff), dtype)
        p["swo"] = dense_init(ks[6], (sff, d), dtype)
    return p


def _capacity(g: int, top_k: int, num_experts: int, cf: float) -> int:
    c = int(g * top_k * cf / num_experts)
    return max(4, min(g, c))


def _route_group(params, x, top_k: int, num_experts: int, cf: float = 1.25):
    """x: (g, d) one token group -> (y, aux_loss)."""
    g, d = x.shape
    E = num_experts
    C = _capacity(g, top_k, E, cf)
    logits = (x.astype(jnp.float32) @ params["router"])          # (g, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)          # (g, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) slot inside its expert's buffer
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)      # (g, k, E)
    flat = onehot.reshape(g * top_k, E)
    pos = jnp.cumsum(flat, axis=0) - flat                        # (g*k, E)
    pos = (pos * flat).sum(-1).reshape(g, top_k)                 # (g, k)
    keep = pos < C
    gate_vals = gate_vals * keep

    # dispatch (g, E, C) / combine (g, E, C)
    disp = (jax.nn.one_hot(expert_idx, E, dtype=x.dtype)[..., None]
            * jax.nn.one_hot(jnp.where(keep, pos, C), C + 1, dtype=x.dtype)[..., :C][:, :, None, :])
    disp = disp.sum(1)                                           # (g, E, C)
    comb = (gate_vals[..., None, None].astype(x.dtype)
            * jax.nn.one_hot(expert_idx, E, dtype=x.dtype)[..., None]
            * jax.nn.one_hot(jnp.where(keep, pos, C), C + 1, dtype=x.dtype)[..., :C][:, :, None, :])
    comb = comb.sum(1)                                           # (g, E, C)

    xe = jnp.einsum("gec,gd->ecd", disp, x)                      # (E, C, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["wg"]))
    h = h * jnp.einsum("ecd,edf->ecf", xe, params["wi"])
    ye = jnp.einsum("ecf,efd->ecd", h, params["wo"])             # (E, C, d)
    y = jnp.einsum("gec,ecd->gd", comb, ye)

    # Switch aux load-balance loss
    me = probs.mean(0)                                           # mean prob per expert
    ce = jax.nn.one_hot(expert_idx[:, 0], E).mean(0)             # top-1 routed fraction
    aux = E * jnp.sum(me * ce)
    return y, aux


def moe_forward(params, cfg, x):
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    g = min(GROUP_SIZE, S)
    tokens = x.reshape(B * S // g, g, d)
    y, aux = jax.vmap(lambda t: _route_group(
        params, t, cfg.top_k, cfg.num_experts, cfg.moe_capacity_factor))(tokens)
    y = y.reshape(B, S, d)
    if cfg.num_shared_experts:
        h = jax.nn.silu(x @ params["swg"]) * (x @ params["swi"])
        y = y + h @ params["swo"]
    return y, aux.mean()


def moe_decode(params, cfg, x):
    """Decode-time MoE for a single position: dense gather-free top-k.

    x: (B, 1, d).  At batch sizes ~128 a dispatch over the batch is fine.
    """
    B, _, d = x.shape
    y, aux = _route_group(params, x.reshape(B, d), cfg.top_k, cfg.num_experts,
                          cfg.moe_capacity_factor)
    y = y.reshape(B, 1, d)
    if cfg.num_shared_experts:
        h = jax.nn.silu(x @ params["swg"]) * (x @ params["swi"])
        y = y + h @ params["swo"]
    return y, aux
