"""The paper's benchmark CNNs (LeNet / CIFAR-quick / AlexNet-class) in pure
JAX — used by the faithful ISGD reproduction (§5 of the paper).

Loss matches the paper's Eq. 6: softmax cross entropy + (λ/2)·‖w‖² weight
decay *inside* ψ, so the ISGD control limit sees exactly the quantity the
paper monitors.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.paper_cnns import CNNConfig


def _conv_init(key, k, cin, cout):
    scale = 1.0 / math.sqrt(k * k * cin)
    return jax.random.normal(key, (k, k, cin, cout), jnp.float32) * scale


def init_cnn(key, cfg: CNNConfig):
    params = {"convs": [], "dense": []}
    cin = cfg.channels
    size = cfg.image_size
    for i, c in enumerate(cfg.convs):
        key, k1 = jax.random.split(key)
        params["convs"].append({
            "w": _conv_init(k1, c.kernel, cin, c.features),
            "b": jnp.zeros((c.features,), jnp.float32),
        })
        size = math.ceil(size / c.stride)
        if c.pool:
            size = math.ceil(size / c.pool_stride)
        cin = c.features
    feat = size * size * cin
    dims = (feat,) + tuple(cfg.hidden) + (cfg.num_classes,)
    for i in range(len(dims) - 1):
        key, k1 = jax.random.split(key)
        params["dense"].append({
            "w": jax.random.normal(k1, (dims[i], dims[i + 1]), jnp.float32)
                 / math.sqrt(dims[i]),
            "b": jnp.zeros((dims[i + 1],), jnp.float32),
        })
    return params


def cnn_logits(params, cfg: CNNConfig, images):
    """images: (B, H, W, C) -> (B, num_classes)."""
    x = images
    for spec, p in zip(cfg.convs, params["convs"]):
        x = jax.lax.conv_general_dilated(
            x, p["w"], window_strides=(spec.stride, spec.stride),
            padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + p["b"])
        if spec.pool:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max,
                (1, spec.pool, spec.pool, 1),
                (1, spec.pool_stride, spec.pool_stride, 1), "SAME")
    x = x.reshape(x.shape[0], -1)
    for i, p in enumerate(params["dense"]):
        x = x @ p["w"] + p["b"]
        if i < len(params["dense"]) - 1:
            x = jax.nn.relu(x)
    return x


def cnn_loss_fn(params, cfg: CNNConfig, batch, weight_decay: float = 1e-4):
    """Paper Eq.6: cross entropy + (λ/2)‖w‖²."""
    logits = cnn_logits(params, cfg, batch["images"])
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    ce = (lse - gold).mean()
    l2 = 0.5 * weight_decay * sum(
        jnp.sum(jnp.square(w)) for w in jax.tree.leaves(params))
    return ce + l2, ce


def cnn_accuracy(params, cfg: CNNConfig, images, labels, batch: int = 1000):
    n = images.shape[0]
    correct = 0
    for i in range(0, n, batch):
        lg = cnn_logits(params, cfg, images[i:i + batch])
        correct += int((jnp.argmax(lg, -1) == labels[i:i + batch]).sum())
    return correct / n
