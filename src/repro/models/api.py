"""Unified model API: every architecture exposes the same surface.

``build_model(cfg)`` -> ``Model`` with:
  init(key, shape)           -> params (real arrays; use jax.eval_shape for abstract)
  loss_fn(params, batch)     -> (total_loss, data_loss)   [train]
  prefill_fn(params, batch)  -> (last logits, caches)     [inference-prefill]
  decode_fn(params, cache, tokens) -> (logits, cache)     [inference-decode]
  init_cache(B, S)           -> zero caches
  input_specs(shape)         -> {name: ShapeDtypeStruct} for train/prefill/decode
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer as T


@dataclass
class Model:
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable
    prefill_fn: Callable
    decode_fn: Callable
    init_cache: Callable
    input_specs: Callable


def _frontend_spec(cfg, B):
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct((B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family in ("encdec", "audio"):
        return jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return None


def build_model(cfg: ModelConfig, *, remat: bool = True,
                use_fused_xent: bool = False,
                remat_policy: str = "full",
                kernels: str = "reference",
                param_dtype=jnp.bfloat16) -> Model:
    """``kernels`` ∈ {'pallas', 'reference', 'interpret'} picks the step-body
    hot-spot implementations (``repro.kernels.policy``): 'pallas' resolves
    to the reference paths off-TPU (interpret mode is a correctness harness,
    not a training path).  The choice is baked at build time — one HLO per
    model, no in-step branching.

    ``param_dtype`` is the mixed-precision policy's compute dtype (params +
    activations; bf16 default).  Norm scales, ψ statistics, the loss scalars
    and the SPC queue stay f32 regardless — see ``T.lm_loss_fn`` and
    ``trainer.make_loss_and_grad``.
    """
    from repro.kernels.policy import resolve_kernels
    use_pallas = resolve_kernels(kernels) != "reference"

    def init(key, max_seq: int = 4096):
        return T.init_params(key, cfg, max_seq=max_seq, dtype=param_dtype)

    def loss_fn(params, batch):
        return T.lm_loss_fn(params, cfg, batch, remat=remat,
                            use_fused_xent=use_fused_xent,
                            remat_policy=remat_policy,
                            use_pallas=use_pallas)

    def prefill_fn(params, batch):
        return T.prefill(params, cfg, batch["tokens"],
                         batch.get("frontend_embeds"))

    def decode_fn(params, cache, tokens):
        return T.decode_step(params, cfg, cache, tokens)

    def init_cache(B, S):
        return T.init_cache(cfg, B, S)

    def input_specs(shape: InputShape):
        B, S = shape.global_batch, shape.seq_len
        specs = {"tokens": jax.ShapeDtypeStruct(
            (B, 1 if shape.kind == "decode" else S), jnp.int32)}
        fe = _frontend_spec(cfg, B)
        if fe is not None and shape.kind != "decode":
            specs["frontend_embeds"] = fe
        return specs

    return Model(cfg, init, loss_fn, prefill_fn, decode_fn, init_cache,
                 input_specs)
