"""Core transformer layers: RMSNorm, RoPE, SwiGLU MLP, GQA attention with
optional sliding window, and DeepSeek-V2 MLA (multi-head latent attention).

All functions are pure (params passed explicitly) and shard-friendly: the
attention reference path chunks queries with ``lax.scan`` so the materialized
score block is (B, H, q_chunk, S) rather than (B, H, S, S) — the same tiling
the Pallas flash kernel uses, which keeps the dry-run memory profile honest.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.analysis.mode import scan_unroll

DEFAULT_Q_CHUNK = 512


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype=jnp.bfloat16):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x, scale, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                                  # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv      # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]                          # (..., seq, 1, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d_model, d_ff, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wg": dense_init(k1, (d_model, d_ff), dtype),
        "wi": dense_init(k2, (d_model, d_ff), dtype),
        "wo": dense_init(k3, (d_ff, d_model), dtype),
    }


def mlp(params, x, activation="silu"):
    act = jax.nn.silu if activation == "silu" else jax.nn.gelu
    h = act(x @ params["wg"]) * (x @ params["wi"])
    return h @ params["wo"]


# ---------------------------------------------------------------------------
# chunked-attention core (shared by self/cross, train/prefill)
# ---------------------------------------------------------------------------
def _attend_chunked(q, k, v, *, causal: bool, window: Optional[int],
                    q_offset=0, q_chunk: int = DEFAULT_Q_CHUNK):
    """q: (B, Sq, H, hd); k/v: (B, Sk, K, hd) with H = K*rep.

    Scans over query chunks; materializes (B, H, qc, Sk) scores per chunk.
    ``q_offset`` is the absolute position of q[0] relative to k[0].
    """
    B, Sq, H, hd = q.shape
    _, Sk, K, _ = k.shape
    rep = H // K
    qc = min(q_chunk, Sq)
    while Sq % qc:                   # largest divisor of Sq <= q_chunk
        qc -= 1
    n_chunks = Sq // qc

    qr = q.reshape(B, n_chunks, qc, K, rep, hd).transpose(1, 0, 2, 3, 4, 5)
    scale = 1.0 / math.sqrt(hd)
    kpos = jnp.arange(Sk)

    def chunk(carry, inputs):
        ci, qb = inputs                                       # qb: (B, qc, K, rep, hd)
        s = jnp.einsum("bqkrd,bskd->bkrqs", qb, k).astype(jnp.float32) * scale
        qpos = q_offset + ci * qc + jnp.arange(qc)            # (qc,)
        mask = jnp.ones((qc, Sk), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bkrqs,bskd->bqkrd", p, v)
        return carry, o

    _, out = jax.lax.scan(chunk, None, (jnp.arange(n_chunks), qr),
                          unroll=scan_unroll())
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, v.shape[-1])
    return out


def decode_attend(q, k_cache, v_cache, t, *, window: Optional[int]):
    """Single-token attention against a cache.

    q: (B, 1, H, hd); caches: (B, S, K, hd); t: index of the new token —
    a scalar, or a (B,) vector of per-row cursors (continuous-batching
    slots, where every row of the batch sits at its own position).
    """
    B, _, H, hd = q.shape
    _, S, K, _ = k_cache.shape
    dv = v_cache.shape[-1]               # MLA: value dim != query dim
    rep = H // K
    qr = q.reshape(B, K, rep, hd)
    s = jnp.einsum("bkrd,bskd->bkrs", qr, k_cache).astype(jnp.float32)
    s *= 1.0 / math.sqrt(hd)
    kpos = jnp.arange(S)
    tb = jnp.asarray(t, jnp.int32).reshape(-1)[:, None]      # (B,1) or (1,1)
    mask = kpos[None, :] <= tb
    if window is not None:
        mask &= kpos[None, :] > tb - window
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bkrs,bskd->bkrd", p, v_cache)
    return o.reshape(B, 1, H, dv)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------
def init_attn(key, cfg, dtype=jnp.bfloat16):
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, H * hd), dtype),
        "wk": dense_init(ks[1], (d, K * hd), dtype),
        "wv": dense_init(ks[2], (d, K * hd), dtype),
        "wo": dense_init(ks[3], (H * hd, d), dtype),
    }


def attn_forward(params, cfg, x, positions, *, window, use_rope=True,
                 q_chunk=DEFAULT_Q_CHUNK, use_flash=False):
    """Full-sequence causal attention. x: (B, S, d).

    ``use_flash`` swaps the chunked-scan reference path for the Pallas
    flash kernel (same GQA layout; numerically equal within the
    ``repro.kernels.numerics`` tolerances, bit-identical in neither
    direction — the switch is per-``build_model``, never per-step).
    """
    B, S, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, S, H, hd)
    k = (x @ params["wk"]).reshape(B, S, K, hd)
    v = (x @ params["wv"]).reshape(B, S, K, hd)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if use_flash:
        from repro.kernels.flash_attention.ops import gqa_flash
        o = gqa_flash(q, k, v, causal=True, window=window)
    else:
        o = _attend_chunked(q, k, v, causal=True, window=window,
                            q_chunk=q_chunk)
    return o.reshape(B, S, H * hd) @ params["wo"], (k, v)


def attn_decode(params, cfg, x, cache_k, cache_v, t, *, window, use_rope=True):
    """One-token decode. x: (B, 1, d); caches (B, S, K, hd); returns (out, k, v).

    ``t`` may be a scalar (all rows at the same position — the one-shot
    engine) or a (B,) vector of per-row cursors (slot-based continuous
    batching): the vector path scatters each row's k/v at its own cursor
    and masks attention per row.
    """
    B = x.shape[0]
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    per_slot = jnp.ndim(t) == 1
    q = (x @ params["wq"]).reshape(B, 1, H, hd)
    k = (x @ params["wk"]).reshape(B, 1, K, hd)
    v = (x @ params["wv"]).reshape(B, 1, K, hd)
    if use_rope:
        pos = jnp.asarray(t)[:, None] if per_slot else jnp.full((1, 1), t)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    if per_slot:
        rows = jnp.arange(B)
        cache_k = cache_k.at[rows, t].set(k[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[rows, t].set(v[:, 0].astype(cache_v.dtype))
    else:
        cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, t, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, t, 0, 0))
    o = decode_attend(q, cache_k, cache_v, t, window=window)
    return o.reshape(B, 1, H * hd) @ params["wo"], cache_k, cache_v


def cross_attn_forward(params, cfg, x, enc_kv, q_chunk=DEFAULT_Q_CHUNK):
    """Cross attention (whisper decoder): keys/values from encoder output."""
    B, S, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    Se = enc_kv.shape[1]
    q = (x @ params["wq"]).reshape(B, S, H, hd)
    k = (enc_kv @ params["wk"]).reshape(B, Se, K, hd)
    v = (enc_kv @ params["wv"]).reshape(B, Se, K, hd)
    o = _attend_chunked(q, k, v, causal=False, window=None, q_chunk=q_chunk)
    return o.reshape(B, S, H * hd) @ params["wo"]


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------
def init_mla(key, cfg, dtype=jnp.bfloat16):
    d, H = cfg.d_model, cfg.num_heads
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    dn, dv = cfg.qk_nope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, H * (dn + dr)), dtype),
        "wkv_a": dense_init(ks[1], (d, r + dr), dtype),
        "wk_b": dense_init(ks[2], (r, H * dn), dtype),
        "wv_b": dense_init(ks[3], (r, H * dv), dtype),
        "wo": dense_init(ks[4], (H * dv, d), dtype),
        "kv_norm": jnp.zeros((r,), jnp.float32),
    }


def _mla_qkv(params, cfg, x, positions):
    B, S, _ = x.shape
    H = cfg.num_heads
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    dn, dv = cfg.qk_nope_head_dim, cfg.v_head_dim
    q = (x @ params["wq"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv = x @ params["wkv_a"]                                   # (B, S, r + dr)
    c_kv = rms_norm(kv[..., :r], params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, r:], positions, cfg.rope_theta)  # (B,S,1,dr)
    return q_nope, q_rope, c_kv, k_rope


def _mla_attend(params, cfg, q_nope, q_rope, c_kv, k_rope, *, causal, q_offset=0):
    """Expands the latent cache and runs chunked attention.

    q_*: (B, Sq, H, *); c_kv: (B, Sk, r); k_rope: (B, Sk, 1, dr).
    """
    B, Sq, H, dn = q_nope.shape
    dv = cfg.v_head_dim
    k_nope = (c_kv @ params["wk_b"]).reshape(B, -1, H, dn)
    v = (c_kv @ params["wv_b"]).reshape(B, -1, H, dv)
    # fold rope part in by concatenation (k_rope broadcast over heads)
    k_rope_b = jnp.broadcast_to(k_rope, (B, k_nope.shape[1], H, k_rope.shape[-1]))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    o = _attend_chunked(q, k, v, causal=causal, window=None, q_offset=q_offset)
    return o.reshape(B, Sq, H * dv) @ params["wo"]


def mla_forward(params, cfg, x, positions):
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x, positions)
    out = _mla_attend(params, cfg, q_nope, q_rope, c_kv, k_rope, causal=True)
    return out, (c_kv, k_rope.squeeze(2))


def _mla_attend_decode(params, cfg, q_nope, q_rope, c_kv, k_rope_cache, t):
    """Single-token MLA attention with per-row cursors ``t`` (B,).

    Expands the latent cache like :func:`_mla_attend` but runs the masked
    one-token attend (``decode_attend`` with K == H), which supports a
    vector ``t`` — the chunked path's scalar ``q_offset`` cannot.
    """
    B, _, H, dn = q_nope.shape
    dv = cfg.v_head_dim
    k_nope = (c_kv @ params["wk_b"]).reshape(B, -1, H, dn)
    v = (c_kv @ params["wv_b"]).reshape(B, -1, H, dv)
    k_rope_b = jnp.broadcast_to(k_rope_cache[:, :, None, :],
                                (B, k_nope.shape[1], H, k_rope_cache.shape[-1]))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    o = decode_attend(q, k, v, t, window=None)
    return o.reshape(B, 1, H * dv) @ params["wo"]


def mla_decode(params, cfg, x, cache_ckv, cache_krope, t):
    """cache_ckv: (B, S, r); cache_krope: (B, S, dr) — the compressed MLA cache.

    ``t`` scalar or (B,) per-row cursors (see :func:`attn_decode`).
    """
    B = x.shape[0]
    per_slot = jnp.ndim(t) == 1
    pos = jnp.asarray(t)[:, None] if per_slot else jnp.full((1, 1), t)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x, pos)
    if per_slot:
        rows = jnp.arange(B)
        cache_ckv = cache_ckv.at[rows, t].set(c_kv[:, 0].astype(cache_ckv.dtype))
        cache_krope = cache_krope.at[rows, t].set(
            k_rope[:, 0, 0].astype(cache_krope.dtype))
        out = _mla_attend_decode(params, cfg, q_nope, q_rope, cache_ckv,
                                 cache_krope, t)
        return out, cache_ckv, cache_krope
    cache_ckv = jax.lax.dynamic_update_slice(cache_ckv, c_kv.astype(cache_ckv.dtype), (0, t, 0))
    cache_krope = jax.lax.dynamic_update_slice(
        cache_krope, k_rope.squeeze(2).astype(cache_krope.dtype), (0, t, 0))
    # mask future positions by zeroing their value contribution via score mask:
    # reuse chunked attend with q_offset=t over the full cache, masking via causal
    out = _mla_attend(params, cfg, q_nope, q_rope, cache_ckv,
                      cache_krope[:, :, None, :], causal=True, q_offset=t)
    return out, cache_ckv, cache_krope
