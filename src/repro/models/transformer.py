"""Decoder(-only / hybrid / enc-dec) stack builder.

The layer pattern of every assigned architecture is periodic (DESIGN.md §5):
``block_size()`` layers form one block, and the stack is a ``lax.scan`` over
``n_blocks`` stacked parameter trees — HLO size stays O(block) regardless of
depth, which keeps 512-device dry-run compiles tractable.

Supported per-position specs: mixer ∈ {attn, mla, ssm}, window ∈ {None, int},
mlp ∈ {swiglu, gelu2, moe}, plus a cross-attention slot for enc-dec decoders.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.analysis.mode import scan_unroll
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.sharding import constrain

LOSS_CHUNK = 512


# ---------------------------------------------------------------------------
# layer specs
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class LayerSpec:
    mixer: str                   # 'attn' | 'mla' | 'ssm'
    window: Optional[int]
    mlp: str                     # 'swiglu' | 'gelu2' | 'moe'
    cross: bool = False


def _mixer_for(cfg, i: int) -> tuple[str, Optional[int]]:
    if cfg.family == "ssm":
        return "ssm", None
    if cfg.family == "hybrid" and cfg.attn_every and not cfg._is_attn_layer(i):
        return "ssm", None
    if cfg.mla:
        return "mla", None
    window = cfg.sliding_window
    if cfg.global_every and (i % cfg.global_every == cfg.global_every - 1):
        window = None                                   # global layer
    return "attn", window


def _mlp_for(cfg, i: int) -> str:
    if cfg._is_moe_layer(i):
        return "moe"
    if cfg.d_ff == 0:
        return "none"                                   # mamba2: mixer-only layers
    return "gelu2" if cfg.family == "encdec" else "swiglu"


def layer_spec(cfg, i: int) -> LayerSpec:
    mixer, window = _mixer_for(cfg, i)
    return LayerSpec(mixer, window, _mlp_for(cfg, i), cross=(cfg.family == "encdec"))


def stack_plan(cfg):
    """-> (prefix_specs, block_specs, n_blocks)."""
    prefix = [layer_spec(cfg, i) for i in range(cfg.first_dense)]
    P = cfg.block_size()
    rest = cfg.num_layers - cfg.first_dense
    assert rest % P == 0, (cfg.name, rest, P)
    n_blocks = rest // P
    block = [layer_spec(cfg, cfg.first_dense + p) for p in range(P)]
    # the pattern must repeat exactly for scan correctness
    for b in range(1, n_blocks):
        for p in range(P):
            assert layer_spec(cfg, cfg.first_dense + b * P + p) == block[p], \
                (cfg.name, b, p)
    return prefix, block, n_blocks


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_layer(key, cfg, spec: LayerSpec, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    p = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
         "ln2": jnp.zeros((cfg.d_model,), jnp.float32)}
    if spec.mixer == "attn":
        p["mixer"] = L.init_attn(ks[0], cfg, dtype)
    elif spec.mixer == "mla":
        p["mixer"] = L.init_mla(ks[0], cfg, dtype)
    else:
        p["mixer"] = S.init_ssm(ks[0], cfg, dtype)
    if spec.mlp == "none":
        p.pop("ln2")
        p["mlp"] = {}
    elif spec.mlp == "moe":
        p["mlp"] = M.init_moe(ks[1], cfg, dtype)
    elif spec.mlp == "gelu2":
        p["mlp"] = {"wi": L.dense_init(ks[1], (cfg.d_model, cfg.d_ff), dtype),
                    "wo": L.dense_init(ks[2], (cfg.d_ff, cfg.d_model), dtype)}
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    if spec.cross:
        p["ln_x"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["cross"] = L.init_attn(ks[3], cfg, dtype)
    return p


def init_params(key, cfg, max_seq: int = 0, dtype=jnp.bfloat16):
    prefix, block, n_blocks = stack_plan(cfg)
    keys = jax.random.split(key, 8)
    Vp, d = cfg.padded_vocab, cfg.d_model
    params = {
        "embed": L.dense_init(keys[0], (Vp, d), dtype),
        "final_norm": jnp.zeros((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(keys[1], (d, Vp), dtype)
    params["prefix"] = [
        _init_layer(k, cfg, sp, dtype)
        for k, sp in zip(jax.random.split(keys[2], max(1, len(prefix))), prefix)
    ]
    bkeys = jax.random.split(keys[3], n_blocks)
    params["blocks"] = tuple(
        jax.vmap(lambda k: _init_layer(k, cfg, sp, dtype))(
            jax.vmap(lambda k: jax.random.fold_in(k, p))(bkeys))
        for p, sp in enumerate(block)
    )
    if cfg.family == "encdec":
        enc_spec = LayerSpec("attn", None, "gelu2", cross=False)
        ekeys = jax.random.split(keys[4], cfg.encoder_layers)
        params["encoder"] = jax.vmap(lambda k: _init_layer(k, cfg, enc_spec, dtype))(ekeys)
        params["enc_final_norm"] = jnp.zeros((d,), jnp.float32)
        params["enc_pos"] = L.dense_init(keys[5], (cfg.encoder_seq, d), dtype)
        params["pos_embed"] = L.dense_init(keys[6], (max(max_seq, 1), d), dtype)
    return params


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------
def _apply_mlp(p, spec: LayerSpec, cfg, x, decode: bool):
    if spec.mlp == "moe":
        fn = M.moe_decode if decode else M.moe_forward
        y, aux = fn(p, cfg, x)
        return y, aux
    if spec.mlp == "gelu2":
        return jax.nn.gelu(x @ p["wi"]) @ p["wo"], 0.0
    return L.mlp(p, x), 0.0


def apply_layer(p, cfg, spec: LayerSpec, x, positions, enc_out=None,
                use_pallas=False):
    """Full-sequence pass. Returns (x, cache_entry, aux).

    ``use_pallas`` routes the mixer hot spots through the Pallas kernels
    (flash attention / ssd_scan); MLA keeps the reference path — its latent
    expansion has no kernel counterpart yet.
    """
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    use_rope = cfg.family != "encdec"
    if spec.mixer == "attn":
        o, cache = L.attn_forward(p["mixer"], cfg, h, positions,
                                  window=spec.window, use_rope=use_rope,
                                  use_flash=use_pallas)
    elif spec.mixer == "mla":
        o, cache = L.mla_forward(p["mixer"], cfg, h, positions)
    else:
        o, cache = S.ssm_forward(p["mixer"], cfg, h, use_pallas=use_pallas)
    # tag the row-parallel projection outputs: under remat_policy="tp_out"
    # these (post-all-reduce) activations are SAVED, so the backward pass
    # does not re-run the forward TP all-reduces (§Perf)
    o = jax.ad_checkpoint.checkpoint_name(o, "tp_out")
    x = x + o
    if spec.cross:
        hx = L.rms_norm(x, p["ln_x"], cfg.norm_eps)
        ck = (enc_out @ p["cross"]["wk"]).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.num_kv_heads, cfg.head_dim)
        cv = (enc_out @ p["cross"]["wv"]).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.num_kv_heads, cfg.head_dim)
        o = L.cross_attn_forward(p["cross"], cfg, hx, enc_out)
        x = x + o
        cache = cache + (ck, cv)
    if spec.mlp != "none":
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        y, aux = _apply_mlp(p["mlp"], spec, cfg, h, decode=False)
        y = jax.ad_checkpoint.checkpoint_name(y, "tp_out")
        x = x + y
    else:
        aux = 0.0
    return constrain(x, "hidden"), cache, aux


def apply_layer_decode(p, cfg, spec: LayerSpec, x, cache, t):
    """One-token pass. cache is this layer's entry; returns (x, cache, aux)."""
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    use_rope = cfg.family != "encdec"
    if spec.mixer == "attn":
        o, ck, cv = L.attn_decode(p["mixer"], cfg, h, cache[0], cache[1], t,
                                  window=spec.window, use_rope=use_rope)
        new_cache = (ck, cv) + tuple(cache[2:])
    elif spec.mixer == "mla":
        o, ckv, krope = L.mla_decode(p["mixer"], cfg, h, cache[0], cache[1], t)
        new_cache = (ckv, krope)
    else:
        o, conv_s, ssd_s = S.ssm_decode(p["mixer"], cfg, h, cache[0], cache[1])
        new_cache = (conv_s, ssd_s)
    x = x + o
    if spec.cross:
        hx = L.rms_norm(x, p["ln_x"], cfg.norm_eps)
        ck, cv = cache[2], cache[3]
        q = (hx @ p["cross"]["wq"]).reshape(x.shape[0], 1, cfg.num_heads, cfg.head_dim)
        o = L.decode_attend(q, ck, cv, ck.shape[1] - 1, window=None)
        o = o.reshape(x.shape[0], 1, cfg.num_heads * cfg.head_dim) @ p["cross"]["wo"]
        x = x + o
    if spec.mlp == "none":
        return x, new_cache, 0.0
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    y, aux = _apply_mlp(p["mlp"], spec, cfg, h, decode=True)
    return x + y, new_cache, aux


# ---------------------------------------------------------------------------
# encoder (whisper)
# ---------------------------------------------------------------------------
def encoder_forward(params, cfg, frames):
    """frames: (B, Se, d) stub embeddings -> (B, Se, d)."""
    x = frames + params["enc_pos"][None, :frames.shape[1]]
    spec = LayerSpec("attn", None, "gelu2", cross=False)

    def body(x, lp):
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        B, Se, _ = h.shape
        H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        q = (h @ lp["mixer"]["wq"]).reshape(B, Se, H, hd)
        k = (h @ lp["mixer"]["wk"]).reshape(B, Se, K, hd)
        v = (h @ lp["mixer"]["wv"]).reshape(B, Se, K, hd)
        o = L._attend_chunked(q, k, v, causal=False, window=None,
                              q_chunk=min(L.DEFAULT_Q_CHUNK, Se))
        x = x + o.reshape(B, Se, H * hd) @ lp["mixer"]["wo"]
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        y, _ = _apply_mlp(lp["mlp"], spec, cfg, h, decode=False)
        return x + y, None

    x, _ = jax.lax.scan(body, x, params["encoder"], unroll=scan_unroll())
    return L.rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# full-sequence forward
# ---------------------------------------------------------------------------
def _embed(params, cfg, tokens, frontend_embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family == "vlm" and frontend_embeds is not None:
        n = frontend_embeds.shape[1]
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x[:, n:]], axis=1)
    if cfg.family == "encdec":
        x = x + params["pos_embed"][None, :tokens.shape[1]]
    return constrain(x, "hidden")


def forward(params, cfg, tokens, frontend_embeds=None, *, want_cache=False,
            remat=True, remat_policy="full", use_pallas=False):
    """-> (hidden (B,S,d), caches or None, aux)."""
    prefix_specs, block_specs, n_blocks = stack_plan(cfg)
    B, Sq = tokens.shape
    # positions as (1, S): broadcasting into rope stays replicated under
    # GSPMD (a (B, S) positions tensor gets batch-sharded and breeds
    # partial-sum all-reduces of the cos/sin tables — §Perf)
    positions = jnp.arange(Sq)[None, :]
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encoder_forward(params, cfg, frontend_embeds)
    x = _embed(params, cfg, tokens, frontend_embeds)

    prefix_caches, aux_total = [], 0.0
    for sp, lp in zip(prefix_specs, params["prefix"]):
        x, cache, aux = apply_layer(lp, cfg, sp, x, positions, enc_out,
                                    use_pallas=use_pallas)
        aux_total += aux
        prefix_caches.append(cache)

    def block_body(carry, block_params):
        x, aux = carry
        caches = []
        for p, sp in enumerate(block_specs):
            x, cache, a = apply_layer(block_params[p], cfg, sp, x, positions,
                                      enc_out, use_pallas=use_pallas)
            aux += a
            caches.append(cache)
        ys = tuple(caches) if want_cache else None
        return (x, aux), ys

    if remat and not want_cache:
        if remat_policy == "tp_out":
            policy = jax.checkpoint_policies.save_only_these_names("tp_out")
            body = jax.checkpoint(block_body, policy=policy)
        else:
            body = jax.checkpoint(block_body)
    else:
        body = block_body
    (x, aux_total), block_caches = jax.lax.scan(
        body, (x, aux_total), params["blocks"], unroll=scan_unroll())
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    caches = (prefix_caches, block_caches) if want_cache else None
    return x, caches, aux_total


def logits_head(params, cfg, h):
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (h @ w).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return constrain(logits, "logits")


# ---------------------------------------------------------------------------
# loss (chunked over sequence; mirrors kernels/fused_xent)
# ---------------------------------------------------------------------------
def chunked_xent(params, cfg, h, labels, mask, chunk: int = LOSS_CHUNK):
    """h: (B,S,d); labels/mask: (B,S). Returns (sum_nll, sum_mask)."""
    B, Sq, d = h.shape
    c = min(chunk, Sq)
    while Sq % c:                 # largest dividing chunk <= requested
        c -= 1
    n = Sq // c
    hr = jnp.moveaxis(h.reshape(B, n, c, d), 1, 0)
    yr = jnp.moveaxis(labels.reshape(B, n, c), 1, 0)
    mr = jnp.moveaxis(mask.reshape(B, n, c), 1, 0)

    def body(carry, inp):
        tot, cnt = carry
        hc, yc, mc = inp
        logits = logits_head(params, cfg, hc)               # (B,c,Vp) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via masked reduction, NOT take_along_axis: a gather
        # along the vocab-sharded axis forces GSPMD to all-gather the full
        # logits (§Perf); the where+sum partitions cleanly.
        col = jnp.arange(logits.shape[-1])
        gold = jnp.sum(jnp.where(col == yc[..., None], logits, 0.0), axis=-1)
        nll = (lse - gold) * mc
        return (tot + nll.sum(), cnt + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (hr, yr, mr),
                                 unroll=scan_unroll())
    return tot, cnt


def lm_loss_fn(params, cfg, batch, *, aux_weight=0.01, remat=True,
               use_fused_xent=False, remat_policy="full", use_pallas=False):
    """Next-token CE averaged over valid positions. batch: {'tokens', ...}.

    Returns f32 ``(total_loss, data_loss)`` scalars regardless of the
    compute dtype — ψ statistics and the SPC queue are f32 by contract
    (the head matmul runs in f32 either way; this pins the output dtype).
    """
    tokens = batch["tokens"]
    fe = batch.get("frontend_embeds")
    h, _, aux = forward(params, cfg, tokens, fe, want_cache=False, remat=remat,
                        remat_policy=remat_policy, use_pallas=use_pallas)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
    if cfg.family == "vlm":
        n = cfg.num_image_tokens
        mask = mask.at[:, :n].set(0.0)
    if use_fused_xent or use_pallas:
        from repro.kernels.fused_xent.ops import fused_xent_sum
        w = params["embed"].T if cfg.tie_embeddings else params["head"]
        tot, cnt = fused_xent_sum(h, w, labels, mask, cfg.vocab_size)
    else:
        tot, cnt = chunked_xent(params, cfg, h, labels, mask)
    loss = (tot / jnp.maximum(cnt, 1.0)).astype(jnp.float32)
    return loss + aux_weight * jnp.asarray(aux, jnp.float32), loss


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------
def init_cache(cfg, B: int, S: int, dtype=jnp.bfloat16):
    """Zero caches for every (prefix, block-position) layer."""
    prefix_specs, block_specs, n_blocks = stack_plan(cfg)

    def entry(sp: LayerSpec, stacked: bool):
        lead = (n_blocks,) if stacked else ()
        if sp.mixer == "attn":
            e = (jnp.zeros(lead + (B, S, cfg.num_kv_heads, cfg.head_dim), dtype),
                 jnp.zeros(lead + (B, S, cfg.num_kv_heads, cfg.head_dim), dtype))
        elif sp.mixer == "mla":
            e = (jnp.zeros(lead + (B, S, cfg.kv_lora_rank), dtype),
                 jnp.zeros(lead + (B, S, cfg.qk_rope_head_dim), dtype))
        else:
            e = (jnp.zeros(lead + (B, cfg.conv_width - 1, S_conv(cfg)), dtype),
                 jnp.zeros(lead + (B, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state),
                           jnp.float32))
        if sp.cross:
            e = e + (jnp.zeros(lead + (B, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
                     jnp.zeros(lead + (B, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim), dtype))
        return e

    prefix_cache = [entry(sp, False) for sp in prefix_specs]
    block_cache = tuple(entry(sp, True) for sp in block_specs)
    return {"prefix": prefix_cache, "blocks": block_cache,
            "t": jnp.zeros((), jnp.int32)}


def S_conv(cfg):
    return S.conv_channels(cfg)


def decode_step(params, cfg, cache, tokens):
    """One decode step. tokens: (B, 1) -> (logits (B, Vp), new cache).

    ``cache['t']`` is a scalar (one-shot serving: every row at the same
    position) or a (B,) vector of per-row cursors (slot-based continuous
    batching — ``repro.serve.slots``); the layer decode paths accept both.
    """
    prefix_specs, block_specs, n_blocks = stack_plan(cfg)
    t = cache["t"]
    if jnp.ndim(t) == 1 and cfg.family == "encdec":
        raise NotImplementedError(
            "per-slot decode cursors are not supported for enc-dec configs "
            "(learned pos_embed lookup + cross-attention assume one shared "
            "position)")
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family == "encdec":
        x = x + jax.lax.dynamic_slice_in_dim(params["pos_embed"], t, 1, axis=0)[None, 0:1]
    x = constrain(x, "decode_hidden")

    new_prefix = []
    for sp, lp, ce in zip(prefix_specs, params["prefix"], cache["prefix"]):
        x, ce, _ = apply_layer_decode(lp, cfg, sp, x, ce, t)
        new_prefix.append(ce)

    def block_body(x, inp):
        block_params, block_cache = inp
        new_entries = []
        for p, sp in enumerate(block_specs):
            x, ce, _ = apply_layer_decode(block_params[p], cfg, sp, x,
                                          block_cache[p], t)
            new_entries.append(ce)
        return x, tuple(new_entries)

    x, new_blocks = jax.lax.scan(block_body, x, (params["blocks"], cache["blocks"]),
                                 unroll=scan_unroll())
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_head(params, cfg, x)[:, 0]
    new_cache = {"prefix": new_prefix, "blocks": new_blocks, "t": t + 1}
    return logits, new_cache


def prefill(params, cfg, tokens, frontend_embeds=None):
    """Full-sequence prefill -> (last-token logits, caches-as-scan-stacked)."""
    h, caches, _ = forward(params, cfg, tokens, frontend_embeds,
                           want_cache=True, remat=False)
    logits = logits_head(params, cfg, h[:, -1:])[:, 0]
    return logits, caches
