"""Mamba2 mixer — SSD (state-space duality) form [arXiv:2405.21060].

TPU adaptation (DESIGN.md §2): the within-chunk computation is expressed as
decay-masked block matmuls (MXU-friendly), and the cross-chunk recurrence is a
``lax.scan`` over chunk states — O(S/chunk) sequential steps instead of O(S).
The same chunk decomposition backs the Pallas ``ssd_scan`` kernel.

Decode is the dual recurrent form: an O(1) state update per token; the "KV
cache" of an SSM layer is just (conv_state, ssd_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.mode import scan_unroll
from repro.models.layers import dense_init, rms_norm


def conv_channels(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def init_ssm(key, cfg, dtype=jnp.bfloat16):
    d, di, nh = cfg.d_model, cfg.d_inner, cfg.ssm_nheads
    cch = conv_channels(cfg)
    ks = jax.random.split(key, 3)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * cfg.ssm_ngroups * cfg.ssm_state + nh), dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_width, cch), dtype),
        "conv_b": jnp.zeros((cch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 0.01, jnp.float32))),
        "gnorm": jnp.zeros((di,), jnp.float32),
        "out_proj": dense_init(ks[2], (di, d), dtype),
    }


def _split_proj(cfg, zxbcdt):
    di, gs = cfg.d_inner, cfg.ssm_ngroups * cfg.ssm_state
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:2 * di + 2 * gs]
    dt = zxbcdt[..., 2 * di + 2 * gs:]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv, width cw. xBC: (B, S, C); w: (cw, C)."""
    cw = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i] for i in range(cw))
    return jax.nn.silu(out + b)


def _segsum(dAh):
    """dAh: (..., cl) cumulative-decay matrix L[i,j] = exp(Σ_{j<m<=i} dA_m), i>=j."""
    cl = dAh.shape[-1]
    cum = jnp.cumsum(dAh, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((cl, cl), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """SSD forward over chunks.

    x: (b, S, nh, hd); dt: (b, S, nh) (post-softplus); A: (nh,) negative;
    B, C: (b, S, G, ds).  Returns (y: (b, S, nh, hd), final_state:
    (b, nh, hd, ds)).
    """
    b, S, nh, hd = x.shape
    G, ds = B.shape[-2], B.shape[-1]
    cl = min(chunk, S)
    while S % cl:                # largest dividing chunk (kernel twin agrees)
        cl -= 1
    nc = S // cl
    rep = nh // G

    # broadcast groups -> heads
    Bh = jnp.repeat(B, rep, axis=-2).reshape(b, nc, cl, nh, ds)
    Ch = jnp.repeat(C, rep, axis=-2).reshape(b, nc, cl, nh, ds)
    xr = x.reshape(b, nc, cl, nh, hd)
    dtr = dt.reshape(b, nc, cl, nh)
    xdt = xr * dtr[..., None]

    dAh = jnp.moveaxis(dtr * A, -1, -2)                          # (b, nc, nh, cl)
    cum = jnp.cumsum(dAh, axis=-1)                               # (b, nc, nh, cl)

    # --- intra-chunk: decay-masked block matmul ------------------------------
    L = _segsum(dAh)                                             # (b, nc, nh, cl, cl)
    CB = jnp.einsum("bnihd,bnjhd->bnhij", Ch.astype(jnp.float32),
                    Bh.astype(jnp.float32))
    Y_diag = jnp.einsum("bnhij,bnjhp->bnihp", CB * L, xdt.astype(jnp.float32))

    # --- chunk states --------------------------------------------------------
    decay_states = jnp.exp(cum[..., -1:] - cum)                  # (b, nc, nh, cl)
    states = jnp.einsum("bnhj,bnjhp,bnjhd->bnhpd",
                        decay_states, xdt.astype(jnp.float32),
                        Bh.astype(jnp.float32))                  # (b, nc, nh, hd, ds)
    chunk_decay = jnp.exp(cum[..., -1])                          # (b, nc, nh)

    # --- inter-chunk recurrence ----------------------------------------------
    init = (jnp.zeros((b, nh, hd, ds), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))

    def step(state, inp):
        s_n, decay_n = inp                                       # (b,nh,hd,ds), (b,nh)
        new = state * decay_n[..., None, None] + s_n
        return new, state                                        # emit state BEFORE chunk

    final_state, prevs = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
        unroll=scan_unroll())
    prevs = jnp.moveaxis(prevs, 0, 1)                            # (b, nc, nh, hd, ds)

    # --- inter-chunk contribution --------------------------------------------
    Y_off = jnp.einsum("bnihd,bnhpd,bnhi->bnihp",
                       Ch.astype(jnp.float32), prevs, jnp.exp(cum))
    y = (Y_diag + Y_off).reshape(b, S, nh, hd)
    return y, final_state


def ssm_forward(params, cfg, x, use_pallas: bool = False):
    """Full-sequence Mamba2 mixer. x: (B, S, d) -> (y, (conv_state, ssd_state))."""
    b, S, d = x.shape
    di, nh, hd = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_headdim
    G, ds = cfg.ssm_ngroups, cfg.ssm_state
    zxbcdt = x @ params["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    conv_state = xBC[:, -(cfg.conv_width - 1):, :]               # cache tail
    xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"])
    xs = xBC[..., :di].reshape(b, S, nh, hd)
    Bm = xBC[..., di:di + G * ds].reshape(b, S, G, ds)
    Cm = xBC[..., di + G * ds:].reshape(b, S, G, ds)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    if use_pallas:
        from repro.kernels.ssd_scan.ops import ssd_chunked_pallas
        y, ssd_state = ssd_chunked_pallas(xs, dt, A, Bm, Cm, chunk=cfg.ssm_chunk)
    else:
        y, ssd_state = ssd_chunked(xs, dt, A, Bm, Cm, chunk=cfg.ssm_chunk)
    y = y + params["D"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(b, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["gnorm"], cfg.norm_eps)
    return y @ params["out_proj"], (conv_state, ssd_state)


def ssm_decode(params, cfg, x, conv_state, ssd_state):
    """One-token recurrent update.

    x: (B, 1, d); conv_state: (B, cw-1, cch); ssd_state: (B, nh, hd, ds).
    """
    b = x.shape[0]
    di, nh, hd = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_headdim
    G, ds = cfg.ssm_ngroups, cfg.ssm_state
    zxbcdt = x @ params["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)                        # (B,1,*)
    window = jnp.concatenate([conv_state, xBC], axis=1)          # (B, cw, cch)
    new_conv_state = window[:, 1:, :]
    out = jnp.einsum("bwc,wc->bc", window, params["conv_w"]) + params["conv_b"]
    xBC = jax.nn.silu(out)[:, None, :]                           # (B,1,cch)
    xs = xBC[..., :di].reshape(b, nh, hd)
    Bm = jnp.repeat(xBC[..., di:di + G * ds].reshape(b, G, ds), nh // G, axis=1)
    Cm = jnp.repeat(xBC[..., di + G * ds:].reshape(b, G, ds), nh // G, axis=1)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B, nh)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt1 * A)                                     # (B, nh)
    xdt = xs.astype(jnp.float32) * dt1[..., None]                # (B, nh, hd)
    new_state = (ssd_state * decay[..., None, None]
                 + jnp.einsum("bhp,bhd->bhpd", xdt, Bm.astype(jnp.float32)))
    y = jnp.einsum("bhpd,bhd->bhp", new_state, Cm.astype(jnp.float32))
    y = y + params["D"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["gnorm"], cfg.norm_eps)
    return y @ params["out_proj"], new_conv_state, new_state
