"""Preallocated slot-based KV/state cache for continuous batching.

The decode batch is ``max_batch`` *slots*, allocated once at ``max_seq``
length.  Each slot holds one in-flight request: its per-layer KV (or SSM
conv/state) rows, a per-slot position cursor ``t``, an ``active`` flag and
the last emitted token.  Requests *join* (``admit``) and *leave*
(``retire``) between decode steps:

  * ``admit`` prefills one request (B=1 exact-length prefill — no padding,
    so SSM recurrent state is exact) and scatters the prefill cache into
    the slot's rows via ``dynamic_update_slice`` at a **traced** slot
    index.  One compile per distinct prompt length; the slot index never
    triggers recompilation.
  * ``decode`` runs one fused decode step over all ``max_batch`` slots with
    per-slot cursors (vector ``t`` through ``transformer.decode_step``).
    Exactly one compile for the lifetime of the engine — admitting or
    retiring never flushes in-flight work.
  * ``retire`` clears the active flag; the slot's cache rows are left as
    garbage.  This is safe: a retired slot's cursor is parked (``t`` only
    advances for active slots), attention masks every position ``> t``, the
    decode write lands *before* the attend so a re-admitted tenant
    overwrites stale rows as its cursor reaches them, and SSM admit
    replaces the recurrent state rows wholesale.

``swap_params`` replaces the served weight pytree between decode steps
(same avals ⇒ no recompile); in-flight KV survives the swap, so a request
can start under one snapshot generation and finish under another — the
consistency contract is in ``serve/README.md``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

UNSERVABLE_FAMILIES = ("encdec", "vlm", "audio", "cnn")


def _write_slot(buf, new, batch_axis: int, slot):
    """Scatter a single-request cache array into its slot rows.

    ``buf``: preallocated slot buffer; ``new``: the request's prefill entry
    (batch axis has size 1, the sequence axis — if any — size <= max_seq).
    ``slot`` is a traced int32 scalar.
    """
    start = tuple(slot if i == batch_axis else 0 for i in range(buf.ndim))
    return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype), start)


def admit_cache(cache, pre_caches, slot):
    """Write one request's prefill caches into slot ``slot``.

    Mirrors the ``init_cache`` structure: ``prefix`` entries carry the
    batch at axis 0, scan-stacked ``blocks`` entries at axis 1 (axis 0 is
    ``n_blocks``).
    """
    prefix_new, blocks_new = pre_caches
    prefix = [tuple(_write_slot(b, n, 0, slot) for b, n in zip(be, ne))
              for be, ne in zip(cache["prefix"], prefix_new)]
    blocks = tuple(tuple(_write_slot(b, n, 1, slot) for b, n in zip(be, ne))
                   for be, ne in zip(cache["blocks"], blocks_new))
    return {"prefix": prefix, "blocks": blocks, "t": cache["t"]}


class SlotKV:
    """Slot-based serving state + the three jitted entry points.

    Device state: the slot cache (per-slot ``t`` cursors), ``active``
    flags, and ``cur_tok`` (each slot's last emitted token — the next
    decode input).  Host-side, the scheduler owns which request occupies
    which slot.
    """

    def __init__(self, model, params, *, max_batch: int, max_seq: int):
        if model.cfg.family in UNSERVABLE_FAMILIES:
            raise ValueError(
                f"slot-based serving supports decoder-only families, not "
                f"{model.cfg.family!r} (shared-position frontends don't "
                f"compose with per-slot cursors)")
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        cache = model.init_cache(max_batch, max_seq)
        cache["t"] = jnp.zeros((max_batch,), jnp.int32)   # per-slot cursors
        self.cache = cache
        self.active = jnp.zeros((max_batch,), bool)
        self.cur_tok = jnp.zeros((max_batch,), jnp.int32)

        self._prefill = jax.jit(model.prefill_fn)

        def _admit(cache, active, cur_tok, slot, pre, t0, tok0):
            cache = admit_cache(cache, pre, slot)
            cache["t"] = cache["t"].at[slot].set(t0)
            return (cache, active.at[slot].set(True),
                    cur_tok.at[slot].set(tok0))

        def _retire(active, slot):
            return active.at[slot].set(False)

        vocab = model.cfg.vocab_size

        def _decode(params, cache, active, cur_tok):
            t_prev = cache["t"]
            logits, cache = model.decode_fn(params, cache, cur_tok[:, None])
            nxt = jnp.argmax(logits[:, :vocab], axis=-1).astype(jnp.int32)
            # park retired slots: cursor frozen, token frozen (their write
            # landed at the parked cursor and stays masked/overwritable)
            cache["t"] = jnp.where(active, t_prev + 1, t_prev)
            return cache, jnp.where(active, nxt, cur_tok)

        self._admit = jax.jit(_admit, donate_argnums=(0, 1, 2))
        self._retire = jax.jit(_retire, donate_argnums=(0,))
        # active is read-only in decode (not returned) — do not donate it
        self._decode = jax.jit(_decode, donate_argnums=(1, 3))

    # -- request lifecycle --------------------------------------------------
    def prefill(self, prompt: np.ndarray):
        """B=1 exact-length prefill -> (first greedy token, pre_caches).

        Compiles once per distinct prompt length (production would bucket;
        see README).  Kept separate from ``admit`` so the scheduler can
        time prefill against decode.
        """
        logits, pre = self._prefill(self.params,
                                    {"tokens": jnp.asarray(prompt)[None, :]})
        tok = int(jnp.argmax(logits[0, :self.model.cfg.vocab_size]))
        return tok, pre

    def admit(self, slot: int, prompt: np.ndarray) -> int:
        """Prefill ``prompt`` and install it in ``slot``; returns the first
        generated token (the prompt's greedy continuation)."""
        assert len(prompt) < self.max_seq, (len(prompt), self.max_seq)
        tok, pre = self.prefill(prompt)
        self.cache, self.active, self.cur_tok = self._admit(
            self.cache, self.active, self.cur_tok,
            jnp.asarray(slot, jnp.int32), pre,
            jnp.asarray(len(prompt), jnp.int32),
            jnp.asarray(tok, jnp.int32))
        return tok

    def retire(self, slot: int) -> None:
        self.active = self._retire(self.active,
                                   jnp.asarray(slot, jnp.int32))

    def decode(self) -> np.ndarray:
        """One decode step over all slots -> (max_batch,) next tokens
        (host).  Retired slots return their frozen last token."""
        self.cache, self.cur_tok = self._decode(
            self.params, self.cache, self.active, self.cur_tok)
        return np.asarray(self.cur_tok)

    def cursor(self, slot: int) -> int:
        return int(self.cache["t"][slot])

    # -- hot snapshot swap ---------------------------------------------------
    def swap_params(self, params) -> None:
        """Swap the served weights between decode steps.  The new pytree
        must match the old avals (same model config/precision), so the
        jitted decode is a cache hit — in-flight KV is untouched."""
        old = jax.tree.leaves(self.params)
        new = jax.tree.leaves(params)
        if [(x.shape, x.dtype) for x in old] != [(x.shape, x.dtype) for x in new]:
            raise ValueError("snapshot params do not match the served "
                             "model's shapes/dtypes")
        self.params = params

    # -- introspection -------------------------------------------------------
    def compile_counts(self) -> dict:
        """Jit-cache sizes: decode must stay at 1 across the engine's
        lifetime; admit grows with distinct (not total) prompt lengths."""
        return {"decode": self._decode._cache_size(),
                "admit": self._admit._cache_size(),
                "prefill": self._prefill._cache_size(),
                "retire": self._retire._cache_size()}
