"""Request queue + continuous-batching decode loop.

The serving mirror of the paper's batch-size study: the decode loop's
*admission control* decides how many requests co-batch per step
(``max_decode_batch`` — AdaBatch motivates treating it as a knob, not a
constant), while the slot cache (``serve/slots.py``) makes joins/leaves
free of recompilation.  One scheduler iteration:

  1. **retire** slots whose request hit EOS / its token budget / max_seq;
  2. **admit** queued requests into free slots (per-request B=1 prefill)
     up to ``max_decode_batch`` concurrently active;
  3. **swap** — every ``swap_poll_every`` steps, poll the snapshot watcher
     and hot-swap params (in-flight requests keep their KV; their
     completions record both the admitting and finishing generation);
  4. **decode** — one fused step over all slots; per-request latency
     accounting on the emitted tokens.

``submit`` is bounded-queue admission control: it returns False (request
rejected) when ``max_queue`` requests are already waiting — the caller
sheds load instead of growing an unbounded backlog.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.serve.slots import SlotKV


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (Sp,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    t_submit: float = 0.0


@dataclass
class Completion:
    """One finished request and its accounting."""
    rid: int
    prompt: np.ndarray
    tokens: list[int]                  # generated continuation (<= max_new)
    gen_admitted: int                  # snapshot generation at admit
    gen_finished: int                  # snapshot generation at completion
    t_submit: float
    t_admit: float = 0.0
    t_first: float = 0.0               # first token (prefill) done
    t_done: float = 0.0
    token_times: list[float] = field(default_factory=list)  # per-token gaps
    truncated: bool = False            # hit max_seq before max_new_tokens

    @property
    def text(self) -> np.ndarray:
        return np.concatenate([self.prompt, np.asarray(self.tokens,
                                                       np.int32)])


@dataclass
class SwapEvent:
    step: int                          # scheduler step index of the swap
    generation: int
    trainer_step: int
    load_seconds: float                # restore+validate+swap stall


class _Slot:
    __slots__ = ("req", "comp", "last_emit")

    def __init__(self, req: Request, comp: Completion, now: float):
        self.req = req
        self.comp = comp
        self.last_emit = now


class ContinuousScheduler:
    def __init__(self, model, params, *, max_batch: int, max_seq: int,
                 max_decode_batch: Optional[int] = None, max_queue: int = 256,
                 watcher=None, swap_poll_every: int = 8,
                 eos_id: Optional[int] = None, recorder=None):
        # obs: admit/retire/swap are host boundaries already — events and
        # per-token gap observations ride them; None = zero obs cost
        self.recorder = recorder
        self.kv = SlotKV(model, params, max_batch=max_batch, max_seq=max_seq)
        self.max_seq = max_seq
        self.max_decode_batch = min(max_decode_batch or max_batch, max_batch)
        self.max_queue = max_queue
        self.watcher = watcher
        self.swap_poll_every = max(1, swap_poll_every)
        self.eos_id = eos_id
        self.queue: deque[Request] = deque()
        self.slots: dict[int, _Slot] = {}            # slot idx -> occupancy
        self.free: list[int] = list(range(max_batch))[::-1]
        self.generation = watcher.generation if watcher else 0
        self.swap_events: list[SwapEvent] = []
        self.completions: list[Completion] = []
        self.rejected = 0
        self.step_count = 0

    # -- admission control ---------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Enqueue; False = queue full, request shed (bounded backlog)."""
        if len(self.queue) >= self.max_queue:
            self.rejected += 1
            if self.recorder is not None:
                self.recorder.counter("serve/rejected")
            return False
        if not req.t_submit:
            req.t_submit = time.perf_counter()
        self.queue.append(req)
        return True

    @property
    def n_active(self) -> int:
        return len(self.slots)

    @property
    def pending(self) -> int:
        return len(self.queue) + len(self.slots)

    def _admit_ready(self) -> None:
        while (self.queue and self.free
               and self.n_active < self.max_decode_batch):
            req = self.queue.popleft()
            now = time.perf_counter()
            comp = Completion(rid=req.rid, prompt=req.prompt, tokens=[],
                              gen_admitted=self.generation,
                              gen_finished=self.generation,
                              t_submit=req.t_submit, t_admit=now)
            budget = self.max_seq - len(req.prompt)
            if req.max_new_tokens > budget:
                comp.truncated = True
                req.max_new_tokens = budget
            if req.max_new_tokens <= 0:
                # steps=0 contract: the prompt comes back unchanged —
                # no prefill, no slot, no token (also the degenerate
                # prompt-fills-max_seq truncation case)
                comp.t_first = comp.t_done = now
                self.completions.append(comp)
                continue
            slot = self.free.pop()
            tok = self.kv.admit(slot, req.prompt)
            now = time.perf_counter()
            comp.t_first = now
            comp.tokens.append(tok)
            comp.token_times.append(now - comp.t_admit)
            self.slots[slot] = _Slot(req, comp, now)
            if self.recorder is not None:
                self.recorder.counter("serve/admitted")
                self.recorder.observe("serve/queue_wait_s",
                                      comp.t_admit - comp.t_submit)
                self.recorder.event("serve.admit", rid=req.rid, slot=slot,
                                    queue_depth=len(self.queue),
                                    active=self.n_active)
            if self._finished(req, comp):
                self._retire(slot)

    def _finished(self, req: Request, comp: Completion) -> bool:
        if len(comp.tokens) >= req.max_new_tokens:
            return True
        if req.eos_id is not None and comp.tokens[-1] == req.eos_id:
            return True
        if self.eos_id is not None and comp.tokens[-1] == self.eos_id:
            return True
        return False

    def _retire(self, slot: int) -> None:
        occ = self.slots.pop(slot)
        occ.comp.t_done = time.perf_counter()
        occ.comp.gen_finished = self.generation
        self.completions.append(occ.comp)
        self.kv.retire(slot)
        self.free.append(slot)
        if self.recorder is not None:
            c = occ.comp
            self.recorder.counter("serve/retired")
            self.recorder.counter("serve/tokens", len(c.tokens))
            for gap in c.token_times[1:]:      # [0] is prefill, not a gap
                self.recorder.observe("serve/token_gap_s", gap)
            self.recorder.event("serve.retire", rid=c.rid, slot=slot,
                                tokens=len(c.tokens), truncated=c.truncated,
                                queue_depth=len(self.queue),
                                active=self.n_active)

    # -- snapshot swap ---------------------------------------------------------
    def poll_snapshot(self) -> Optional[SwapEvent]:
        """Poll the watcher; on a new snapshot, hot-swap between steps."""
        if self.watcher is None:
            return None
        t0 = time.perf_counter()
        snap = self.watcher.poll()
        if snap is None:
            return None
        self.kv.swap_params(snap.params)
        self.generation = snap.generation
        ev = SwapEvent(step=self.step_count, generation=snap.generation,
                       trainer_step=snap.step,
                       load_seconds=time.perf_counter() - t0)
        self.swap_events.append(ev)
        if self.recorder is not None:
            self.recorder.counter("serve/swaps")
            self.recorder.event("serve.swap", step=ev.step,
                                generation=ev.generation,
                                trainer_step=ev.trainer_step,
                                load_seconds=ev.load_seconds,
                                active=self.n_active)
        return ev

    # -- the loop ----------------------------------------------------------------
    def step(self) -> list[Completion]:
        """One scheduler iteration; returns requests finished this step."""
        n_done = len(self.completions)
        self._admit_ready()
        if self.step_count % self.swap_poll_every == 0:
            self.poll_snapshot()
        self.step_count += 1
        if not self.slots:
            return self.completions[n_done:]
        from repro.obs.timing import annotate
        with annotate("obs/decode_step"):
            toks = self.kv.decode()
        now = time.perf_counter()
        for slot, occ in list(self.slots.items()):
            tok = int(toks[slot])
            occ.comp.tokens.append(tok)
            occ.comp.token_times.append(now - occ.last_emit)
            occ.last_emit = now
            if self._finished(occ.req, occ.comp):
                self._retire(slot)
        return self.completions[n_done:]

    def warmup(self, requests) -> None:
        """Run and discard — populates this scheduler's jit caches (prefill
        per distinct prompt length, admit, decode, retire) so a subsequent
        timed ``run`` is compile-free.  The caches live on the underlying
        ``SlotKV`` jit wrappers, so warming a *different* scheduler instance
        does not help.  Resets completion/latency/step accounting (the obs
        recorder is detached for the duration so warmup traffic never
        reaches the metrics stream)."""
        rec, self.recorder = self.recorder, None
        try:
            self.run(list(requests))
        finally:
            self.recorder = rec
        self.completions.clear()
        self.swap_events.clear()
        self.rejected = 0
        self.step_count = 0

    def run(self, requests=None, *, until=None) -> list[Completion]:
        """Drive until the queue and all slots drain (and ``until()`` — if
        given — returns True).  Returns all completions, submit order."""
        for req in requests or []:
            if not self.submit(req):
                raise RuntimeError(f"queue full at rid={req.rid} "
                                   f"(max_queue={self.max_queue})")
        while self.pending or (until is not None and not until()):
            self.step()
            if not self.pending and until is not None and not until():
                time.sleep(0.01)     # idle: wait for more work / condition
        self.completions.sort(key=lambda c: c.rid)
        return self.completions

    def latency_summary(self) -> dict:
        """Per-token latency stats over every completion so far: prefill
        (first token after admit) and inter-token decode gaps, each as a
        count/mean/min/max/p50/p95 dict (``repro.obs.stats.summarize``)."""
        from repro.obs.stats import summarize
        prefill = [c.token_times[0] for c in self.completions
                   if c.token_times]
        gaps = [g for c in self.completions for g in c.token_times[1:]]
        return {"prefill_s": summarize(prefill),
                "token_gap_s": summarize(gaps),
                "completions": len(self.completions),
                "rejected": self.rejected,
                "swaps": len(self.swap_events)}
