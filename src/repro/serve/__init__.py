from repro.serve.engine import ServeEngine, merge_prefill_cache
from repro.serve.scheduler import (Completion, ContinuousScheduler, Request,
                                   SwapEvent)
from repro.serve.slots import SlotKV, admit_cache
from repro.serve.snapshot import (Snapshot, SnapshotWatcher, publish_pointer,
                                  read_pointer)

__all__ = [
    "ServeEngine", "merge_prefill_cache",
    "SlotKV", "admit_cache",
    "Request", "Completion", "SwapEvent", "ContinuousScheduler",
    "Snapshot", "SnapshotWatcher", "publish_pointer", "read_pointer",
]
