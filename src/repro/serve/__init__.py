from repro.serve.engine import ServeEngine, merge_prefill_cache

__all__ = ["ServeEngine", "merge_prefill_cache"]
