"""Snapshot watcher: hot-swap trained params into a running serve loop.

Publish-directory protocol (writer side is ``train/checkpoints.py``):

  * the trainer writes crash-consistent engine checkpoints
    (``ckpt_<step>.npz``, atomic tmp+fsync+rename, crc32 checksum) into the
    publish directory via the existing ``Checkpointer``;
  * after each save it atomically replaces a ``LATEST`` pointer file whose
    content is the newest checkpoint's *filename* — readers never race a
    directory listing against pruning.

The watcher polls the pointer; on change it restores **only the params
subtree** through the checkpoint module's checksum/template-validated
restore path (extra keys — optimizer base, ψ queue, … — are ignored by the
template restore), stamps it with a monotonically increasing *generation*
number, and hands it to the serve loop, which swaps it in between decode
steps.  A pointed-to file that vanished under pruning, or a checkpoint
that fails its checksum/template validation, is skipped and retried on the
next poll — the serve loop keeps running on its current snapshot.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Any, Optional

from repro.train.checkpoints import (CheckpointError, load_extra, restore,
                                     tree_checksum)

LATEST_POINTER = "LATEST"


def publish_pointer(directory: str, path: str) -> str:
    """Atomically point ``directory/LATEST`` at checkpoint ``path``
    (basename is stored; the pointer and its target share a directory)."""
    name = os.path.basename(path)
    target = os.path.join(directory, LATEST_POINTER)
    tmp = f"{target}.tmp-{os.getpid()}"
    with open(tmp, "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, target)
    return target


def read_pointer(directory: str) -> Optional[str]:
    """-> full path of the pointed-to checkpoint, or None (no pointer yet)."""
    try:
        with open(os.path.join(directory, LATEST_POINTER)) as f:
            name = f.read().strip()
    except FileNotFoundError:
        return None
    return os.path.join(directory, name) if name else None


@dataclass
class Snapshot:
    """One restored snapshot: the params subtree + its provenance."""
    params: Any
    generation: int        # watcher-local monotonic counter (1-based)
    path: str              # checkpoint file it came from
    step: int              # trainer step recorded in the checkpoint
    params_checksum: str   # tree_checksum of the restored params subtree


class SnapshotWatcher:
    """Polls a publish directory and yields validated param snapshots.

    ``params_like`` is the serving model's freshly initialized params — the
    restore template (shapes/dtypes must match the trainer's, i.e. same
    config + precision).
    """

    def __init__(self, publish_dir: str, params_like, *,
                 min_poll_interval: float = 0.0, recorder=None):
        self.publish_dir = publish_dir
        self.params_like = params_like
        self.min_poll_interval = min_poll_interval
        self.recorder = recorder
        self.generation = 0
        self._last_path: Optional[str] = None
        self._last_poll = 0.0

    def poll(self) -> Optional[Snapshot]:
        """-> a new Snapshot when the pointer moved, else None.  Never
        raises on a torn/pruned/corrupt target — skips and retries."""
        now = time.monotonic()
        if now - self._last_poll < self.min_poll_interval:
            return None
        self._last_poll = now
        path = read_pointer(self.publish_dir)
        if path is None or path == self._last_path:
            return None
        t0 = time.monotonic()
        try:
            tree = restore(path, {"params": self.params_like})
            step = int(load_extra(path).get("step", -1))
        except CheckpointError:
            return None                      # pruned or invalid: retry later
        self._last_path = path
        self.generation += 1
        params = tree["params"]
        if self.recorder is not None:
            self.recorder.event("serve.snapshot_load",
                                generation=self.generation, step=step,
                                path=path, seconds=time.monotonic() - t0)
        return Snapshot(params=params, generation=self.generation, path=path,
                        step=step,
                        params_checksum=tree_checksum({"params": params}))

    def wait_for_first(self, timeout: float = 120.0,
                       poll_every: float = 0.2) -> Snapshot:
        """Block until the trainer publishes its first snapshot."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            snap = self.poll()
            if snap is not None:
                return snap
            time.sleep(poll_every)
        raise TimeoutError(
            f"no snapshot appeared under {self.publish_dir!r} within "
            f"{timeout:.0f}s (is the trainer running with --publish-dir?)")
