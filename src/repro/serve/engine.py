"""Batched serving engine: prefill -> KV cache -> greedy decode loop.

The prefill pass emits per-layer cache entries sized to the prompt; they are
scattered into the preallocated max_seq cache buffers (generic rule: the
first axis whose size differs is the sequence axis; SSM conv/state entries
match exactly and are copied through).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np


def _merge_entry(buf, new):
    """Write a prefill cache array into its preallocated buffer."""
    if buf.shape == new.shape:
        return new.astype(buf.dtype)
    assert len(buf.shape) == len(new.shape), (buf.shape, new.shape)
    # first differing axis = sequence axis
    axis = next(i for i, (a, b) in enumerate(zip(buf.shape, new.shape))
                if a != b)
    start = tuple(jnp.zeros((), jnp.int32) for _ in buf.shape)
    return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype), start)


def merge_prefill_cache(cache, prefill_caches):
    """cache: from model.init_cache; prefill_caches: (prefix, blocks)."""
    prefix_new, blocks_new = prefill_caches
    merged_prefix = [
        tuple(_merge_entry(b, n) for b, n in zip(be, ne))
        for be, ne in zip(cache["prefix"], prefix_new)
    ]
    merged_blocks = tuple(
        tuple(_merge_entry(b, n) for b, n in zip(be, ne))
        for be, ne in zip(cache["blocks"], blocks_new)
    )
    return {"prefix": merged_prefix, "blocks": merged_blocks,
            "t": cache["t"]}


class ServeEngine:
    def __init__(self, model, params, *, max_seq: int):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(model.prefill_fn)
        self._decode = jax.jit(model.decode_fn, donate_argnums=(1,))

    def _frontend(self, B):
        cfg = self.model.cfg
        if cfg.family == "vlm":
            return jnp.zeros((B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.family == "encdec":
            return jnp.zeros((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        return None

    def generate(self, prompts: np.ndarray, steps: int) -> np.ndarray:
        """prompts: (B, Sp) int32 -> (B, Sp+steps) greedy continuation.

        ``steps=0`` returns the prompt unchanged; ``steps=1`` exactly one
        token (the prefill argmax) — the prefill token counts toward
        ``steps``, it is not a freebie on top.
        """
        B, Sp = prompts.shape
        assert Sp + steps <= self.max_seq
        if steps == 0:
            return np.asarray(prompts).copy()
        batch = {"tokens": jnp.asarray(prompts)}
        fe = self._frontend(B)
        if fe is not None:
            batch["frontend_embeds"] = fe
        logits, pre_caches = self._prefill(self.params, batch)
        cache = self.model.init_cache(B, self.max_seq)
        cache = merge_prefill_cache(cache, pre_caches)
        cache["t"] = jnp.asarray(Sp, jnp.int32)

        toks = [jnp.argmax(logits[:, :self.model.cfg.vocab_size], -1)]
        for _ in range(steps - 1):
            logits, cache = self._decode(self.params, cache,
                                         toks[-1][:, None].astype(jnp.int32))
            toks.append(jnp.argmax(logits[:, :self.model.cfg.vocab_size], -1))
        gen = np.stack([np.asarray(t) for t in toks], axis=1)
        return np.concatenate([prompts, gen], axis=1)
