"""Deterministic fault injection for the async-PS engine.

A :class:`FaultPlan` is a list of :class:`FaultEvent`s targeting specific
``(worker, local step)`` coordinates, threaded into
``repro.distributed.async_ps`` behind a no-op default (``NO_FAULTS``).
Because every event is pinned to a worker/step pair — and the seeded
:meth:`FaultPlan.random` generator derives those pairs from a
``numpy.random.RandomState`` — a CI run injects exactly the same faults
every time, so recovery behavior (eviction, re-striping, retry) is testable
rather than anecdotal.

Event kinds:

  * ``crash``   — the worker raises :class:`InjectedCrash` before running
    the step (after it passed the SSP gate, so the crash holds a gate slot
    exactly like a real mid-protocol death);
  * ``hang``    — the worker sleeps ``seconds`` before the step while
    holding its gate slot; if that exceeds the coordinator's heartbeat
    deadline the worker is evicted while it sleeps;
  * ``slow``    — the worker's steps in ``[step, until]`` (``until=None`` =
    forever) take ``factor``× their measured wall time (the paper's §6.2
    heterogeneous/straggler worker);
  * ``corrupt`` — the push payload is corrupted *after* the worker computed
    its integrity checksum (a bit flip in transit): a verifying server
    rejects the delta and the worker's bounded retry resends it clean;
  * ``transient`` — the push transport raises :class:`TransientPushError`
    once; the worker's retry-with-backoff absorbs it.

One-shot semantics: each event fires at most once per plan instance (a
retried push must not re-trip the same corruption).  Plans are therefore
stateful across a run; call :meth:`reset` (the coordinator does) before
reusing one.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Iterable, Optional

import numpy as np


class InjectedCrash(RuntimeError):
    """A crash injected by a FaultPlan (stands in for a real worker death)."""


class TransientPushError(RuntimeError):
    """A transient, retryable push-transport failure injected by a FaultPlan."""


@dataclass(frozen=True)
class FaultEvent:
    kind: str                      # crash | hang | slow | corrupt | transient
    worker: int                    # target worker id
    step: int                      # local step at which the event fires
    seconds: float = 0.5           # hang duration
    factor: float = 2.0            # slow multiplier (>= 1)
    until: Optional[int] = None    # slow: last affected step (None = forever)

    KINDS = ("crash", "hang", "slow", "corrupt", "transient")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {self.KINDS}")


def _corrupt_tree(tree):
    """Flip the first element of the first leaf by a large offset — a
    detectable in-transit corruption that keeps shapes/dtypes valid."""
    import jax
    import jax.numpy as jnp

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        return tree
    l0 = jnp.asarray(leaves[0])
    flat = l0.reshape(-1) if l0.ndim else l0.reshape(1)
    flat = flat.at[0].add(jnp.asarray(1e3, flat.dtype))
    leaves = [flat.reshape(l0.shape)] + leaves[1:]
    return jax.tree_util.tree_unflatten(treedef, leaves)


class FaultPlan:
    """An injectable, per-worker-targeted, reproducible fault schedule.

    The async-PS :class:`~repro.distributed.async_ps.worker.Worker` calls
    ``before_step``/``slow_factor`` around each step and ``on_transit`` on
    each push attempt; with the default empty plan every hook is a cheap
    no-op, so the fault machinery costs nothing when unused.
    """

    def __init__(self, events: Iterable[FaultEvent] = ()):
        self.events = tuple(events)
        self._fired: set[int] = set()
        self._lock = threading.Lock()

    def __bool__(self) -> bool:
        return bool(self.events)

    def __repr__(self) -> str:
        return f"FaultPlan({list(self.events)!r})"

    def reset(self) -> None:
        """Forget which one-shot events fired (start of a fresh run)."""
        with self._lock:
            self._fired.clear()

    def _take(self, i: int) -> bool:
        """Atomically claim one-shot event ``i``; False if already fired."""
        with self._lock:
            if i in self._fired:
                return False
            self._fired.add(i)
            return True

    # -- worker hooks -------------------------------------------------------
    def before_step(self, wid: int, k: int) -> None:
        """Crash/hang injection, called after the worker passed the SSP gate
        for local step ``k`` (so the fault holds a gate slot, exactly like a
        real mid-protocol failure)."""
        for i, e in enumerate(self.events):
            if e.worker != wid or e.step != k:
                continue
            if e.kind == "crash" and self._take(i):
                raise InjectedCrash(
                    f"injected crash: worker {wid} at local step {k}")
            if e.kind == "hang" and self._take(i):
                time.sleep(e.seconds)

    def slow_factor(self, wid: int, k: int) -> float:
        """Product of the slow multipliers active for (wid, k); 1.0 = full
        speed.  Slow events are windows, not one-shots."""
        f = 1.0
        for e in self.events:
            if (e.kind == "slow" and e.worker == wid and e.step <= k
                    and (e.until is None or k <= e.until)):
                f *= e.factor
        return f

    def on_transit(self, wid: int, k: int, tree):
        """The push-transport hook: may corrupt the payload (after checksum
        computation — i.e. in transit) or raise a one-shot transient
        failure.  Returns the (possibly corrupted) payload tree."""
        for i, e in enumerate(self.events):
            if e.worker != wid or e.step != k:
                continue
            if e.kind == "transient" and self._take(i):
                raise TransientPushError(
                    f"injected transient push failure: worker {wid} at "
                    f"local step {k}")
            if e.kind == "corrupt" and self._take(i):
                return _corrupt_tree(tree)
        return tree

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_spec(cls, spec: str) -> "FaultPlan":
        """Parse ``kind@worker:step[:key=value,...]`` events joined by
        ``;`` — e.g. ``"crash@2:5;hang@1:8:seconds=1.0;slow@0:0:factor=3"``.
        """
        events = []
        for part in filter(None, (p.strip() for p in spec.split(";"))):
            try:
                head, rest = part.split("@", 1)
                fields = rest.split(":")
                worker, step = int(fields[0]), int(fields[1])
                kw = {}
                for opt in fields[2:]:
                    key, val = opt.split("=", 1)
                    if key not in ("seconds", "factor", "until"):
                        raise ValueError(f"unknown option {key!r}")
                    kw[key] = int(val) if key == "until" else float(val)
                events.append(FaultEvent(kind=head.strip(), worker=worker,
                                         step=step, **kw))
            except (ValueError, IndexError) as e:
                raise ValueError(
                    f"bad fault spec {part!r} (want "
                    f"kind@worker:step[:key=value,...] with kind in "
                    f"{FaultEvent.KINDS}): {e}") from e
        return cls(events)

    @classmethod
    def random(cls, n_workers: int, steps_per_worker: int, *, seed: int,
               crashes: int = 1, hangs: int = 1, hang_seconds: float = 0.5,
               lo_frac: float = 0.2, hi_frac: float = 0.8) -> "FaultPlan":
        """Seeded random plan: ``crashes + hangs`` distinct workers fail at
        steps drawn from the middle ``[lo_frac, hi_frac)`` of the run (so
        warm-up and the final epoch stay fault-free).  Deterministic in
        ``seed`` — the reproducibility contract CI relies on."""
        assert crashes + hangs < n_workers, (
            "at least one worker must survive the plan")
        rng = np.random.RandomState(seed)
        workers = rng.choice(n_workers, size=crashes + hangs, replace=False)
        lo = max(1, int(steps_per_worker * lo_frac))
        hi = max(lo + 1, int(steps_per_worker * hi_frac))
        events = []
        for i, w in enumerate(workers):
            kind = "crash" if i < crashes else "hang"
            events.append(FaultEvent(kind=kind, worker=int(w),
                                     step=int(rng.randint(lo, hi)),
                                     seconds=hang_seconds))
        return cls(events)


NO_FAULTS = FaultPlan()
