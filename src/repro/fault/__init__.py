"""Deterministic fault injection (``FaultPlan``) for the async-PS engine.

See ``repro.fault.plan`` for the event model and
``repro.distributed.async_ps`` for where the hooks land.  Everything is
importable lazily so ``python -m`` entry points can set XLA flags before
jax initializes.
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    "FaultEvent": "repro.fault.plan",
    "FaultPlan": "repro.fault.plan",
    "NO_FAULTS": "repro.fault.plan",
    "InjectedCrash": "repro.fault.plan",
    "TransientPushError": "repro.fault.plan",
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)


def __dir__():
    return sorted(_EXPORTS)
