from repro.analysis.roofline import (
    V5E,
    Roofline,
    analyze,
    collective_stats,
    model_flops,
)

__all__ = ["V5E", "Roofline", "analyze", "collective_stats", "model_flops"]
