"""Analysis mode: make compiled-cost trip counts honest.

XLA's HloCostAnalysis counts a while-loop body ONCE (verified in
EXPERIMENTS.md §Roofline caveats), so a rolled ``lax.scan`` hides
(trips−1)/trips of the real FLOPs/bytes.  Under ``analysis_mode()``:

  * inner scans (attention q-chunks, loss chunks, SSD inter-chunk,
    encoder stack) fully unroll, so their cost is counted exactly;
  * the ISGD subproblem ``while_loop`` is replaced by a python-unrolled,
    convergence-masked loop of exactly ``stop`` iterations (the paper's
    early-stopping upper bound).

The outer scan over layer blocks stays rolled — its cost is recovered by
two-point extrapolation over n_blocks (analysis/roofline.extrapolate), which
is exact because every block is shape-identical.
"""
from __future__ import annotations

import contextlib
import threading

_state = threading.local()


def in_analysis_mode() -> bool:
    return getattr(_state, "on", False)


@contextlib.contextmanager
def analysis_mode(on: bool = True):
    prev = in_analysis_mode()
    _state.on = on
    try:
        yield
    finally:
        _state.on = prev


def scan_unroll() -> bool | int:
    """Pass as lax.scan's ``unroll=`` for inner (non-block) scans."""
    return True if in_analysis_mode() else 1
