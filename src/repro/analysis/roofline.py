"""Roofline analysis from a compiled dry-run artifact (DESIGN.md §7).

compute_s    = HLO_FLOPs / (chips · 197e12)         [bf16 MXU peak, v5e]
memory_s     = HLO_bytes / (chips · 819e9)          [HBM BW]
collective_s = Σ collective bytes / (chips · 50e9)  [ICI per link]

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are NOT
in cost_analysis — we parse the post-SPMD HLO text and apply a per-op byte
model (all-reduce counts 2× operand for its reduce-scatter+all-gather phases;
all-gather counts result bytes; reduce-scatter / all-to-all / permute count
operand bytes).  The post-partitioning module is per-device, so sums are
per-chip traffic.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field

V5E = {
    "peak_flops": 197e12,      # bf16 per chip
    "hbm_bw": 819e9,           # bytes/s per chip
    "ici_bw": 50e9,            # bytes/s per link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'f32[128,256]' -> bytes."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def _tuple_or_single_bytes(sig: str) -> int:
    """Result signature may be a tuple '(f32[..], f32[..])' or single."""
    return sum(_shape_bytes(s) for s in
               re.findall(r"\w+\[[\d,]*\]", sig))


def collective_stats(hlo_text: str) -> dict:
    """Per-op-kind traffic (bytes) + counts from post-SPMD HLO text."""
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        # '%x = TYPE[SHAPE] op-name(OPERANDS...), ...'
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|\w+\[[\d,]*\]\S*)\s+([\w\-]+)", line)
        if not m:
            continue
        result_sig, op = m.groups()
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op == c + "-done":
                kind = c
                break
        if kind is None:
            continue
        if op.endswith("-done"):
            continue                        # counted at -start
        result_bytes = _tuple_or_single_bytes(result_sig)
        operand_bytes = sum(_shape_bytes(s) for s in
                            re.findall(r"\w+\[[\d,]*\]", line[m.end():]))
        if kind == "all-reduce":
            traffic = 2 * result_bytes      # RS + AG phases
        elif kind == "all-gather":
            traffic = result_bytes
        else:                               # RS / A2A / permute
            traffic = operand_bytes or result_bytes
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += traffic
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_gflops: float            # per device
    hlo_gbytes: float            # per device
    collective_gbytes: float     # per device
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_gflops: float          # 6·N·D useful flops per device
    useful_flops_ratio: float
    collectives: dict = field(default_factory=dict)
    memory_per_device_gb: float = 0.0

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=1)


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops_per_device: float = 0.0, hw: dict = V5E) -> Roofline:
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text()
    cstats = collective_stats(text)
    cbytes = sum(v["bytes"] for v in cstats.values())

    compute_s = flops / hw["peak_flops"]
    memory_s = bytes_ / hw["hbm_bw"]
    collective_s = cbytes / hw["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    mem = compiled.memory_analysis()
    per_dev_gb = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                  + mem.temp_size_in_bytes + mem.generated_code_size_in_bytes) / 1e9

    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_gflops=flops / 1e9, hlo_gbytes=bytes_ / 1e9,
        collective_gbytes=cbytes / 1e9,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck,
        model_gflops=model_flops_per_device / 1e9,
        useful_flops_ratio=(model_flops_per_device / flops) if flops else 0.0,
        collectives=cstats,
        memory_per_device_gb=per_dev_gb,
    )


def model_flops(cfg, shape, chips: int) -> float:
    """6·N·D (dense) or 6·N_active·D (MoE) per device per step-equivalent."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        factor = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        factor = 2.0
    else:
        tokens = shape.global_batch          # one token per sequence
        factor = 2.0
    return factor * n_active * tokens / chips
