"""Shared latency/summary statistics.

One percentile implementation for every consumer — ``launch/serve.py``,
``benchmarks/bench_serve.py``, and the serve scheduler's latency
accounting each had their own copy.  Semantics are pinned by
``tests/test_obs.py``: linear interpolation between order statistics
(numpy's default), ``nan`` on empty input.
"""
from __future__ import annotations

import math
from typing import Dict, Sequence


def percentile(xs: Sequence[float], q: float) -> float:
    """q-th percentile (0..100) with linear interpolation; nan if empty."""
    s = sorted(float(x) for x in xs)   # list() first: len-1 ndarray truthiness
    if not s:
        return float("nan")
    if len(s) == 1:
        return s[0]
    pos = (len(s) - 1) * (q / 100.0)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(s) - 1)
    frac = pos - lo
    return s[lo] * (1.0 - frac) + s[hi] * frac


def summarize(xs: Sequence[float]) -> Dict[str, float]:
    """count/mean/min/max/p50/p95 — the obs histogram-record payload."""
    xs = [float(x) for x in xs]
    if not xs:
        return {"count": 0}
    return {
        "count": len(xs),
        "mean": sum(xs) / len(xs),
        "min": min(xs),
        "max": max(xs),
        "p50": percentile(xs, 50.0),
        "p95": percentile(xs, 95.0),
    }
