"""Unified telemetry: structured metrics, live SPC chart export, timing.

jax-free at import time (profiler hooks lazy-import jax) — safe to import
from the sweep/multihost parent processes that must not initialize jax.
See README.md in this package for the record schema and the sync-boundary
contract.
"""
from repro.obs.console import CONSOLE, Console
from repro.obs.observer import TrainObserver
from repro.obs.recorder import (ConsoleSink, JsonlSink, MemorySink,
                                MetricsRecorder, jsonl_path, read_jsonl,
                                validate_record, write_merged_summary)
from repro.obs.spc import SPCExporter
from repro.obs.stats import percentile, summarize
from repro.obs.timing import (EstimatedWallError, StepTimer, annotate,
                              maybe_profile, named_scope,
                              require_measured_walls)

__all__ = [
    "CONSOLE", "Console", "ConsoleSink", "EstimatedWallError", "JsonlSink",
    "MemorySink", "MetricsRecorder", "SPCExporter", "StepTimer",
    "TrainObserver", "annotate", "jsonl_path", "maybe_profile",
    "named_scope", "percentile", "read_jsonl", "require_measured_walls",
    "summarize", "validate_record", "write_merged_summary",
]
