"""Process-aware structured metrics: counters, gauges, histograms, events.

Design contract (the "zero-sync" rule): the recorder is **numpy/stdlib
only** — no jax imports, no device values.  Ingestion happens exclusively
at *existing* host-sync boundaries (the chunk-scan metric fetch, log/eval
flushes, async-PS push commits, serve admit/retire), so enabling
observability adds zero device round-trips to the fused K-step path.
``tests/test_obs.py`` enforces this with a dispatch-counting wrapper in
the style of ``SlotKV.compile_counts``.

Record schema (one JSON object per JSONL line)::

    {"v": 1, "kind": "counter|gauge|histogram|event", "name": str,
     "wall": float-seconds-since-recorder-start, "seq": int,
     "tags": {"process_id": int, ...}, ...kind payload}

    counter   -> {"value": increment, "total": running-total}
    gauge     -> {"value": number}
    histogram -> {"stats": {"count", "mean", "min", "max", "p50", "p95"}}
    event     -> {"data": {...}}

Counters and histogram observations accumulate in memory and are emitted
as records on :meth:`MetricsRecorder.flush` (one record per name covering
the interval since the previous flush) — hot boundaries touch a dict, not
a file.  Gauges and events emit immediately.  Multi-process runs write one
JSONL per process (``metrics.p{process_id}.jsonl``); the coordinator folds
them into ``summary.json`` via :func:`write_merged_summary`.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List, Optional, Sequence

from repro.obs.console import CONSOLE
from repro.obs.stats import summarize

SCHEMA_VERSION = 1
KINDS = ("counter", "gauge", "histogram", "event")


def _jsonable(v):
    """Best-effort conversion to a JSON-serializable value (numpy scalars
    and 0-d arrays become python scalars; arrays become lists)."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    item = getattr(v, "item", None)
    if item is not None and getattr(v, "ndim", None) in (None, 0):
        return item()
    tolist = getattr(v, "tolist", None)
    if tolist is not None:
        return tolist()
    return repr(v)


# ---------------------------------------------------------------- sinks

class Sink:
    def emit(self, record: dict) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def close(self) -> None:
        pass


class MemorySink(Sink):
    """Keeps records in a list — the test harness sink."""

    def __init__(self):
        self.records: List[dict] = []

    def emit(self, record: dict) -> None:
        self.records.append(record)

    def by_name(self, name: str) -> List[dict]:
        return [r for r in self.records if r["name"] == name]


class JsonlSink(Sink):
    """One JSON object per line; flushed per record so a crashed run still
    leaves a readable chart."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._fh = open(path, "w")

    def emit(self, record: dict) -> None:
        self._fh.write(json.dumps(record) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.close()


class ConsoleSink(Sink):
    """Periodic one-line counter summary through the process-0 console.

    Prints whenever the ``train/steps`` running total crosses a multiple of
    ``every`` (counter records arrive at flush boundaries, so cadence is
    boundary-quantized, never mid-hot-path)."""

    def __init__(self, every: int = 0, step_counter: str = "train/steps"):
        self.every = int(every)
        self.step_counter = step_counter
        self._totals: Dict[str, float] = {}
        self._last_bucket = 0

    def emit(self, record: dict) -> None:
        if record["kind"] != "counter":
            return
        self._totals[record["name"]] = record["total"]
        if self.every <= 0 or record["name"] != self.step_counter:
            return
        bucket = int(record["total"]) // self.every
        if bucket > self._last_bucket:
            self._last_bucket = bucket
            parts = " ".join(f"{k}={self._totals[k]:g}" for k in sorted(self._totals))
            CONSOLE.print(f"[obs] {parts}")


# ------------------------------------------------------------- recorder

class MetricsRecorder:
    """Counters / gauges / histograms / typed events over pluggable sinks.

    ``tags`` ride on every record (``process_id`` is required — multi-host
    charts are useless without it; engine/model identify the run)."""

    def __init__(self, sinks: Sequence[Sink], tags: Optional[dict] = None,
                 clock=time.perf_counter):
        self.sinks = list(sinks)
        self.tags = dict(tags or {})
        self.tags.setdefault("process_id", 0)
        self._clock = clock
        self._t0 = clock()
        self._seq = 0
        self._totals: Dict[str, float] = {}
        self._pending_counters: Dict[str, float] = {}
        self._observations: Dict[str, List[float]] = {}
        self._closed = False
        # async-PS worker threads observe() concurrently with the
        # coordinator's event()/flush(); all mutation goes under one lock
        self._lock = threading.Lock()

    # -- emission core (callers hold self._lock)
    def _emit_locked(self, kind: str, name: str, payload: dict) -> None:
        rec = {
            "v": SCHEMA_VERSION,
            "kind": kind,
            "name": name,
            "wall": self._clock() - self._t0,
            "seq": self._seq,
            "tags": self.tags,
        }
        rec.update(payload)
        self._seq += 1
        for s in self.sinks:
            s.emit(rec)

    # -- public surface
    def counter(self, name: str, inc: float = 1) -> None:
        """Accumulate; the record (value=interval delta, total=running) is
        emitted at the next flush()."""
        inc = float(inc)
        with self._lock:
            self._totals[name] = self._totals.get(name, 0.0) + inc
            self._pending_counters[name] = \
                self._pending_counters.get(name, 0.0) + inc

    def gauge(self, name: str, value) -> None:
        with self._lock:
            self._emit_locked("gauge", name, {"value": float(value)})

    def observe(self, name: str, value) -> None:
        """Add one observation to a histogram; stats emit at flush()."""
        with self._lock:
            self._observations.setdefault(name, []).append(float(value))

    def event(self, name: str, **data) -> None:
        payload = {"data": {k: _jsonable(v) for k, v in data.items()}}
        with self._lock:
            self._emit_locked("event", name, payload)

    def total(self, name: str) -> float:
        with self._lock:
            return self._totals.get(name, 0.0)

    def flush(self) -> None:
        """Materialize accumulated counters/histograms as records."""
        with self._lock:
            for name in sorted(self._pending_counters):
                self._emit_locked("counter", name, {
                    "value": self._pending_counters[name],
                    "total": self._totals[name],
                })
            self._pending_counters.clear()
            for name in sorted(self._observations):
                xs = self._observations[name]
                if xs:
                    self._emit_locked("histogram", name,
                                      {"stats": summarize(xs)})
            self._observations.clear()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self.flush()
        for s in self.sinks:
            s.close()


# ------------------------------------------------------------ validation

def validate_record(rec) -> List[str]:
    """Return a list of schema violations (empty = valid)."""
    errs: List[str] = []
    if not isinstance(rec, dict):
        return ["record is not an object"]
    if rec.get("v") != SCHEMA_VERSION:
        errs.append(f"v != {SCHEMA_VERSION}")
    kind = rec.get("kind")
    if kind not in KINDS:
        errs.append(f"bad kind {kind!r}")
    if not isinstance(rec.get("name"), str) or not rec.get("name"):
        errs.append("missing name")
    if not isinstance(rec.get("wall"), (int, float)) or rec.get("wall", -1) < 0:
        errs.append("bad wall")
    if not isinstance(rec.get("seq"), int) or rec.get("seq", -1) < 0:
        errs.append("bad seq")
    tags = rec.get("tags")
    if not isinstance(tags, dict) or not isinstance(tags.get("process_id"), int):
        errs.append("tags.process_id missing")
    if kind == "counter":
        if not isinstance(rec.get("total"), (int, float)):
            errs.append("counter missing total")
    elif kind == "gauge":
        if not isinstance(rec.get("value"), (int, float)):
            errs.append("gauge missing value")
    elif kind == "histogram":
        stats = rec.get("stats")
        if not isinstance(stats, dict) or not isinstance(stats.get("count"), int):
            errs.append("histogram missing stats.count")
    elif kind == "event":
        if not isinstance(rec.get("data"), dict):
            errs.append("event missing data")
    return errs


def jsonl_path(obs_dir: str, process_id: int) -> str:
    return os.path.join(obs_dir, f"metrics.p{process_id}.jsonl")


def read_jsonl(path: str) -> List[dict]:
    with open(path) as fh:
        return [json.loads(line) for line in fh if line.strip()]


def write_merged_summary(obs_dir: str, out_name: str = "summary.json") -> dict:
    """Fold per-process JSONL files into one summary (coordinator-only call
    in multi-process runs; assumes the shared FS the checkpoint layer
    already requires).  Counters sum across processes (final totals),
    events count per name."""
    counters: Dict[str, float] = {}
    events: Dict[str, int] = {}
    per_process: Dict[str, dict] = {}
    n_records = 0
    for fname in sorted(os.listdir(obs_dir)):
        if not fname.endswith(".jsonl"):
            continue
        finals: Dict[str, float] = {}
        pid = None
        nrec = 0
        for rec in read_jsonl(os.path.join(obs_dir, fname)):
            nrec += 1
            pid = rec.get("tags", {}).get("process_id", pid)
            if rec.get("kind") == "counter":
                finals[rec["name"]] = rec["total"]  # last total wins
            elif rec.get("kind") == "event":
                events[rec["name"]] = events.get(rec["name"], 0) + 1
        n_records += nrec
        per_process[fname] = {"process_id": pid, "records": nrec, "counters": finals}
        for name, total in finals.items():
            counters[name] = counters.get(name, 0.0) + total
    out = {
        "v": SCHEMA_VERSION,
        "records": n_records,
        "counters": counters,
        "events": events,
        "processes": per_process,
    }
    with open(os.path.join(obs_dir, out_name), "w") as fh:
        json.dump(out, fh, indent=2, sort_keys=True)
    return out
