"""Live SPC control-chart export — the paper's Fig. 3 view, reconstructed
at host-sync flush points and reconciled bit-exactly with the engine.

The exporter maintains a **host-side float32 mirror** of the engine's
``LossQueue``, replaying the exact arithmetic of ``control.push`` /
``control.push_at`` (same op order, IEEE-754 single precision) on the
per-step losses fetched at chunk/log boundaries.  Because both sides do
the identical sequence of f32 adds/multiplies, the mirror's ring buffer
(the per-batch ψ table), Σ, Σ², count and ring index match the device
queue **bit for bit** — :meth:`SPCExporter.reconcile` asserts it against
the final ``ISGDState``.

Accelerate decisions are *never* recomputed: ``accelerated``/``sub_iters``
come from the engine's own metrics stream, so the exported accelerate-event
records sum exactly to ``state.accel_count`` / ``state.sub_iters``.  Chart
statistics (ψ̄, limit) are likewise taken from the engine metrics — the
mirror only owns the table.

Two modes mirror the two queue write disciplines:

* ``fifo`` — FCPR engines (`control.push`): window = one epoch, the slot a
  loss lands in is the ring index; batch identity is ``step % n_b``.
* ``table`` — sched policies with ``uses_table`` (`control.push_at`): one
  entry per batch, slot = the ``batch_idx`` the jitted schedule selected.
"""
from __future__ import annotations

import struct
from typing import List, Optional

import numpy as np

_F32 = np.float32


def _f32(x) -> np.float32:
    return _F32(np.asarray(x, dtype=_F32))


def _sq(x: np.float32) -> np.float32:
    """Mirror of ``control._sq``: x² via the exact 12/12-bit split.

    The engine computes Σ²'s squares this way so that every multiply is
    exactly representable — fma contraction in XLA codegen then cannot
    change the result, and this replay (which has no fma) lands on
    identical bits.  hi/lo and all partial products are ≤24-bit values,
    exact in Python's double arithmetic, so only the two adds round —
    through ``np.float32`` in the device's association order.  Kept off
    numpy scalar ops (≈6 µs/call of boxing) because it runs per push on
    the ingestion path that the <3% overhead test budgets."""
    xf = float(_F32(x))
    hi_bits = struct.unpack("<I", struct.pack("<f", xf))[0] & 0xFFFFF000
    hi = struct.unpack("<f", struct.pack("<I", hi_bits))[0]
    lo = xf - hi
    s1 = _F32(hi * hi + 2.0 * (hi * lo))
    return _F32(float(s1) + lo * lo)


class SPCExporter:
    """Replays the SPC queue on host and emits control-chart records."""

    def __init__(self, n_batches: int, k_sigma: float = 3.0, *,
                 mode: str = "fifo", recorder=None, emit_steps: bool = True):
        if mode not in ("fifo", "table"):
            raise ValueError(f"mode must be fifo|table, got {mode!r}")
        self.n_batches = int(n_batches)
        self.k_sigma = float(k_sigma)
        self.mode = mode
        self.recorder = recorder
        self.emit_steps = emit_steps
        # -- exact f32 mirror of control.LossQueue
        self.buf = np.zeros(self.n_batches, dtype=_F32)
        self.buf_sq = np.zeros(self.n_batches, dtype=_F32)  # _sq(buf) cache
        self.total = _F32(0.0)
        self.total_sq = _F32(0.0)
        self.count = 0
        self.idx = 0
        # -- engine-reported accounting
        self.steps = 0
        self.accel_count = 0
        self.sub_iters = 0
        self.events: List[dict] = []

    # ------------------------------------------------ queue replay (exact)

    def _push(self, loss: np.float32) -> int:
        """Mirror of control.push — same op order as the jnp version."""
        slot = self.idx
        old = self.buf[slot]
        full = self.count >= self.n_batches
        dec = old if full else _F32(0.0)
        dec_sq = self.buf_sq[slot] if full else _F32(0.0)
        loss_sq = _sq(loss)
        self.total = _F32(_F32(self.total + loss) - dec)
        self.total_sq = _F32(_F32(self.total_sq + loss_sq) - dec_sq)
        self.buf[slot] = loss
        self.buf_sq[slot] = loss_sq
        self.count = min(self.count + 1, self.n_batches)
        self.idx = (slot + 1) % self.n_batches
        return slot

    def _push_at(self, slot: int, loss: np.float32) -> int:
        """Mirror of control.push_at (per-batch table re-keying)."""
        old = self.buf[slot]
        filled = slot < self.count
        dec = old if filled else _F32(0.0)
        dec_sq = self.buf_sq[slot] if filled else _F32(0.0)
        loss_sq = _sq(loss)
        self.total = _F32(_F32(self.total + loss) - dec)
        self.total_sq = _F32(_F32(self.total_sq + loss_sq) - dec_sq)
        self.buf[slot] = loss
        self.buf_sq[slot] = loss_sq
        self.count = min(max(self.count, slot + 1), self.n_batches)
        self.idx = (slot + 1) % self.n_batches
        return slot

    # --------------------------------------------------------- ingestion

    def ingest(self, step: int, metrics: dict, *, batch: Optional[int] = None) -> None:
        """Feed one step's host-fetched metrics (loss, psi_bar, limit,
        accelerated, sub_iters [, batch_idx via ``batch``])."""
        loss = _f32(metrics["loss"])
        if self.mode == "table":
            if batch is None:
                raise ValueError("table-mode SPC export needs the batch index")
            slot = self._push_at(int(batch), loss)
        else:
            slot = self._push(loss)
        self.steps += 1

        accelerated = bool(np.asarray(metrics["accelerated"]))
        sub = int(np.asarray(metrics["sub_iters"]))
        psi_bar = float(np.asarray(metrics["psi_bar"]))
        limit = float(np.asarray(metrics["limit"]))
        batch_id = int(batch) if batch is not None else slot

        if self.recorder is not None and self.emit_steps:
            self.recorder.event(
                "spc.step", step=int(step), batch=batch_id, psi=float(loss),
                psi_bar=psi_bar, limit=limit, accelerated=accelerated,
                sub_iters=sub)
        if accelerated:
            self.accel_count += 1
            self.sub_iters += sub
            ev = {"step": int(step), "batch": batch_id, "sub_iters": sub,
                  "psi_before": float(loss), "limit": limit,
                  "psi_bar_after": psi_bar}
            self.events.append(ev)
            if self.recorder is not None:
                self.recorder.event("spc.accelerate", **ev)

    # ----------------------------------------------------------- export

    def psi_table(self) -> np.ndarray:
        return self.buf.copy()

    def chart_payload(self) -> dict:
        """The Fig. 3 snapshot: per-batch ψ table + window statistics."""
        count = max(self.count, 1)
        psi_bar = float(_F32(self.total / _F32(count)))
        warm = self.count >= self.n_batches
        valid = self.buf[:self.count].astype(np.float64)
        std = float(np.sqrt(max(((valid - psi_bar) ** 2).sum() / count, 0.0))) \
            if self.count else 0.0
        return {
            "mode": self.mode,
            "n_batches": self.n_batches,
            "k_sigma": self.k_sigma,
            "steps": self.steps,
            "psi_table": [float(x) for x in self.buf],
            "count": self.count,
            "idx": self.idx,
            "total": float(self.total),
            "total_sq": float(self.total_sq),
            "psi_bar": psi_bar,
            "limit": (psi_bar + self.k_sigma * std) if warm else float("inf"),
            "accel_count": self.accel_count,
            "sub_iters": self.sub_iters,
            "accel_events": len(self.events),
        }

    # -------------------------------------------------------- reconcile

    def reconcile(self, state, *, replay_exact: bool = True) -> dict:
        """Check the mirror against the final engine ``ISGDState``.

        Bit-exact contract (``replay_exact=True``, all sync engines): the
        ψ table, Σ, Σ² (f32 bit patterns), count, idx must match the
        device queue; steps/accel_count/sub_iters must match the engine
        counters.  ``replay_exact=False`` (multi-worker async-PS, where
        record order ≠ the server's observe order) checks counters only.

        Returns ``{"reconciled": bool, "mismatches": [...]}``.
        """
        mism: List[str] = []

        def _chk(name, got, want):
            if got != want:
                mism.append(f"{name}: export={got} engine={want}")

        _chk("steps", self.steps, int(np.asarray(state.iter)))
        _chk("accel_count", self.accel_count, int(np.asarray(state.accel_count)))
        _chk("sub_iters", self.sub_iters, int(np.asarray(state.sub_iters)))
        _chk("accel_events", len(self.events), int(np.asarray(state.accel_count)))

        if replay_exact:
            q = state.queue
            buf = np.asarray(q.buf, dtype=_F32)
            _chk("count", self.count, int(np.asarray(q.count)))
            _chk("idx", self.idx, int(np.asarray(q.idx)))
            if self.buf.tobytes() != buf.tobytes():
                bad = int((self.buf.view(np.uint32) != buf.view(np.uint32)).sum())
                mism.append(f"psi_table: {bad}/{self.n_batches} slots differ bitwise")
            for name, mine, theirs in (("total", self.total, q.total),
                                       ("total_sq", self.total_sq, q.total_sq)):
                if _f32(mine).tobytes() != _f32(np.asarray(theirs)).tobytes():
                    mism.append(f"{name}: export={float(mine)!r} "
                                f"engine={float(np.asarray(theirs))!r}")
        return {"reconciled": not mism, "mismatches": mism,
                "replay_exact": replay_exact}
