"""Process-aware console sink: THE one mechanism for run output.

Multi-process runs would otherwise interleave N copies of every progress
line and every warning.  Everything user-facing that is not a metrics
record goes through the module singleton :data:`CONSOLE`:

  * ``CONSOLE.print`` — progress lines, emitted on the coordinator
    (process 0) only.  ``repro.launch.env.p0print`` delegates here, so the
    historical call sites keep working;
  * ``CONSOLE.warn_once`` — keyed warnings (e.g. the
    :func:`~repro.data.device_ring.ring_or_prefetch` demotion warning)
    fired at most once per process *and* only on the coordinator, replacing
    ad-hoc module-global ``_WARNED`` flags.

The module is jax-free at import time (the fig8 sweep parent and the
multihost parity parent never import jax); the coordinator check is
resolved lazily through ``repro.launch.env`` at call time.
"""
from __future__ import annotations

import warnings
from typing import Callable, Optional


class Console:
    """Coordinator-gated stdout + a warn-once registry.

    ``active_fn`` overrides the "am I the coordinator?" predicate — tests
    inject a constant; production resolves ``repro.launch.env
    .is_coordinator()`` lazily so importing this module never touches jax.
    """

    def __init__(self, active_fn: Optional[Callable[[], bool]] = None):
        self._active_fn = active_fn
        self._warned: set = set()

    def _active(self) -> bool:
        if self._active_fn is not None:
            return self._active_fn()
        from repro.launch import env as ENV
        return ENV.is_coordinator()

    def print(self, *args, **kwargs) -> None:
        """Print on the coordinator process only."""
        if self._active():
            print(*args, **kwargs)

    def warn_once(self, key: str, message: str, *,
                  category=UserWarning, stacklevel: int = 3) -> bool:
        """Emit ``message`` as a warning at most once per ``key`` (and only
        on the coordinator).  Returns True the first time the key fires —
        callers can hang extra bookkeeping off it."""
        if key in self._warned:
            return False
        self._warned.add(key)
        if self._active():
            warnings.warn(message, category, stacklevel=stacklevel)
        return True

    def reset(self) -> None:
        """Forget fired warn-once keys (tests)."""
        self._warned.clear()


#: the process-wide console every launcher/library warning routes through
CONSOLE = Console()
