"""Step timing, throughput accounting, and the measured-vs-estimated wall
contract — plus optional ``jax.profiler`` trace capture.

``StepTimer`` replaces the copy-pasted ``t0 = time.perf_counter() ... dt``
runner blocks: named spans accumulate wall seconds, carry the
``TrainLog``-style *estimated* flag (chunk-end stacking, un-synced
dispatch timing, overlapping async pushes), and compute steps/s /
examples/s / dispatch counts in one place.

:func:`require_measured_walls` is the shared refuse-to-fit guard — Eq. 21
timing fits (``fig8_batch_size``, ``fig8_scaling``) must never consume
``wall_est`` entries.

Profiler hooks (all lazy-import jax, so this module stays importable in
the jax-free sweep parents):

* :func:`maybe_profile` — context manager around a run; starts a
  ``jax.profiler`` trace when ``--profile-dir`` is set, else no-op.
* :func:`annotate` — host-side ``TraceAnnotation`` span (PS fold, decode
  step) visible on the trace timeline.
* :func:`named_scope` — ``jax.named_scope`` for *traced* code (chunk scan,
  ψ push, accelerate subproblem): pure metadata on the jaxpr, zero
  runtime cost, so it is safe inside the fused hot path.
"""
from __future__ import annotations

import contextlib
import time
from typing import Dict, Optional, Sequence


class EstimatedWallError(RuntimeError):
    """A timing fit was about to consume estimated (non-measured) walls."""


def require_measured_walls(wall_est: Sequence[bool], context: str = "") -> None:
    """Refuse to proceed when any wall-clock entry is flagged estimated.

    ``wall_est`` is a sequence of flags, True = estimated (``TrainLog``
    semantics: step_sync=False per-step timing, fused-chunk stacking, or
    overlapping async pushes).  Raises :class:`EstimatedWallError` naming
    the offending fraction — estimated walls silently feeding an Eq.21
    C1/C2 fit is exactly the failure mode this guards."""
    flags = [bool(x) for x in wall_est]
    n_bad = sum(flags)
    if n_bad:
        where = context or "timing fit"
        raise EstimatedWallError(
            f"{where}: refusing to fit on estimated walls — {n_bad}/{len(flags)} "
            "entries have wall_est=True (per-step timing without step_sync, "
            "fused-chunk dispatch estimates, or overlapping async pushes). "
            "Re-measure with synced per-step walls.")


class StepTimer:
    """Named accumulating wall-clock spans + throughput derivation.

    >>> timer = StepTimer()
    >>> with timer.span("train"):
    ...     run()
    >>> timer.throughput("train", steps=n)  # {'wall_s': ..., 'steps_per_s': ...}

    Spans re-entered accumulate (the serve drain loop times many small
    spans under one name).  ``estimated=True`` marks a span's wall as
    non-measured; :meth:`throughput` propagates the flag so downstream
    fits can refuse it via :func:`require_measured_walls`."""

    def __init__(self, recorder=None, clock=time.perf_counter):
        self.recorder = recorder
        self._clock = clock
        self._acc: Dict[str, float] = {}
        self._est: set = set()

    @contextlib.contextmanager
    def span(self, name: str, *, estimated: bool = False):
        t0 = self._clock()
        try:
            yield self
        finally:
            self._acc[name] = self._acc.get(name, 0.0) + (self._clock() - t0)
            if estimated:
                self._est.add(name)

    def add(self, name: str, seconds: float, *, estimated: bool = False) -> None:
        """Fold an externally measured duration into a span."""
        self._acc[name] = self._acc.get(name, 0.0) + float(seconds)
        if estimated:
            self._est.add(name)

    def seconds(self, name: str) -> float:
        return self._acc.get(name, 0.0)

    def estimated(self, name: str) -> bool:
        return name in self._est

    def throughput(self, name: str, *, steps: int = 0, examples: int = 0,
                   dispatches: int = 0) -> dict:
        """Derive rates for a span; emits gauges + one event when a
        recorder is attached."""
        dt = self.seconds(name)
        out = {"wall_s": dt, "wall_est": self.estimated(name)}
        if dispatches:
            out["dispatches"] = int(dispatches)
        if dt > 0.0:
            if steps:
                out["steps_per_s"] = steps / dt
            if examples:
                out["examples_per_s"] = examples / dt
            if dispatches:
                out["dispatches_per_s"] = dispatches / dt
        if self.recorder is not None:
            for key in ("steps_per_s", "examples_per_s"):
                if key in out:
                    self.recorder.gauge(f"time/{name}/{key}", out[key])
            self.recorder.event(f"time/{name}", **out)
        return out


# ------------------------------------------------------------- profiler

@contextlib.contextmanager
def maybe_profile(profile_dir: Optional[str]):
    """Capture a ``jax.profiler`` trace into ``profile_dir`` when set
    (``--profile-dir``); no-op (and no jax import) when None."""
    if not profile_dir:
        yield
        return
    import jax
    jax.profiler.start_trace(profile_dir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()


def annotate(name: str):
    """Host-side trace annotation (``jax.profiler.TraceAnnotation``) for
    un-jitted spans: PS fold, decode step, checkpoint IO.  Cheap enough to
    leave on unconditionally."""
    import jax
    return jax.profiler.TraceAnnotation(name)


def named_scope(name: str):
    """``jax.named_scope`` — name traced operations (chunk scan, ψ push,
    accelerate subproblem) on profiles/HLO at zero runtime cost."""
    import jax
    return jax.named_scope(name)
