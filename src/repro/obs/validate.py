"""Schema validator CLI for obs JSONL files.

    python -m repro.obs.validate OBS_DIR_OR_FILE [...]

Exits non-zero if any record fails :func:`repro.obs.recorder
.validate_record` (or any line is not valid JSON) — CI runs this over the
artifact directory so a schema regression fails the build instead of
shipping an unreadable chart.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Iterable, List, Tuple

from repro.obs.recorder import validate_record


def iter_jsonl_files(paths: Iterable[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            out.extend(os.path.join(p, f) for f in sorted(os.listdir(p))
                       if f.endswith(".jsonl"))
        else:
            out.append(p)
    return out


def validate_file(path: str) -> Tuple[int, List[str]]:
    """Returns (n_records, errors)."""
    errors: List[str] = []
    n = 0
    with open(path) as fh:
        for lineno, line in enumerate(fh, 1):
            if not line.strip():
                continue
            n += 1
            try:
                rec = json.loads(line)
            except ValueError as e:
                errors.append(f"{path}:{lineno}: not JSON ({e})")
                continue
            for err in validate_record(rec):
                errors.append(f"{path}:{lineno}: {err}")
    return n, errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="+", help="obs dir(s) or .jsonl file(s)")
    ap.add_argument("--max-errors", type=int, default=20,
                    help="report at most this many violations")
    args = ap.parse_args(argv)

    files = iter_jsonl_files(args.paths)
    if not files:
        print(f"obs.validate: no .jsonl files under {args.paths}", file=sys.stderr)
        return 1
    total = 0
    all_errors: List[str] = []
    for f in files:
        n, errs = validate_file(f)
        total += n
        all_errors.extend(errs)
        status = "OK" if not errs else f"{len(errs)} violations"
        print(f"obs.validate: {f}: {n} records, {status}")
    if all_errors:
        for e in all_errors[:args.max_errors]:
            print(f"  {e}", file=sys.stderr)
        extra = len(all_errors) - args.max_errors
        if extra > 0:
            print(f"  ... and {extra} more", file=sys.stderr)
        return 1
    print(f"obs.validate: {total} records across {len(files)} files, all valid")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
