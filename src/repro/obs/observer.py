"""`TrainObserver` — the one object launch drivers thread through a run.

Bundles a :class:`~repro.obs.recorder.MetricsRecorder`, a
:class:`~repro.obs.spc.SPCExporter` and a
:class:`~repro.obs.timing.StepTimer`, and owns the *boundary discipline*:

* per-step engines ``defer()`` device metric handles and ``flush()`` them
  at the existing log/eval print boundaries (the handles are tiny scalar
  buffers; conversion happens at the boundary, not per step);
* the fused chunk engines call ``chunk()`` with the stacked metrics the
  driver already fetched — the only host transfer the chunk path ever
  does, so obs adds zero dispatches (pinned by ``tests/test_obs.py``);
* ``finalize(state)`` emits the Fig. 3 ``spc.final`` snapshot with the
  bit-exact reconcile verdict against the engine's ``ISGDState`` and
  closes the recorder.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.obs.recorder import MetricsRecorder
from repro.obs.spc import SPCExporter
from repro.obs.timing import StepTimer

_SKIP_KEYS = ("aux",)  # pytree payloads — not chartable scalars


def _host_metrics(metrics: dict) -> dict:
    return {k: np.asarray(v) for k, v in metrics.items() if k not in _SKIP_KEYS}


class TrainObserver:
    def __init__(self, recorder: MetricsRecorder, *, n_batches: int,
                 k_sigma: float = 3.0, table: bool = False,
                 examples_per_step: int = 0, replay_exact: bool = True,
                 emit_steps: bool = True):
        self.recorder = recorder
        self.spc = SPCExporter(n_batches, k_sigma,
                               mode="table" if table else "fifo",
                               recorder=recorder, emit_steps=emit_steps)
        self.timer = StepTimer(recorder)
        self.examples_per_step = int(examples_per_step)
        self.replay_exact = replay_exact
        self._pending: List[Tuple[int, dict]] = []
        self._visits: Optional[np.ndarray] = None
        self._n_batches = int(n_batches)
        self._finalized = None

    # ------------------------------------------------------ per-step path
    def defer(self, step: int, metrics: dict) -> None:
        """Buffer a step's device metrics; no host transfer until flush()."""
        self._pending.append((int(step), metrics))

    def flush(self) -> None:
        """Drain deferred metrics (log/eval boundary — already a host sync)."""
        for step, m in self._pending:
            self._ingest_step(step, _host_metrics(m))
        self._pending.clear()
        self.recorder.flush()

    # ------------------------------------------------------- chunked path
    def chunk(self, first_step: int, stacked_metrics: dict) -> None:
        """Ingest one fused chunk's stacked metrics (already fetched by the
        driver at the chunk boundary — the existing host sync)."""
        host = _host_metrics(stacked_metrics)
        n = int(np.asarray(host["loss"]).shape[0])
        for i in range(n):
            self._ingest_step(first_step + i, {k: v[i] for k, v in host.items()})
        self.recorder.counter("train/dispatches")
        self.recorder.flush()

    # ----------------------------------------------------------- internals
    def _ingest_step(self, step: int, host: dict) -> None:
        batch = host.get("batch_idx")
        batch = None if batch is None else int(batch)
        self.spc.ingest(step, host, batch=batch)
        if batch is not None:
            if self._visits is None:
                self._visits = np.zeros(self._n_batches, dtype=np.int64)
            self._visits[batch] += 1
        self.recorder.counter("train/steps")
        if self.examples_per_step:
            self.recorder.counter("train/examples", self.examples_per_step)

    # ------------------------------------------------------------ wrap-up
    def async_run(self, records, events=()) -> None:
        """Ingest an async-PS run: the server's per-push records (in commit
        order) + coordinator eviction/crash events."""
        for i, r in enumerate(records):
            self._ingest_step(i, {k: np.asarray(v) for k, v in r.items()
                                  if k in ("loss", "psi_bar", "psi_std", "limit",
                                           "accelerated", "sub_iters")})
            self.recorder.observe("async_ps/tau", r["tau"])
            self.recorder.counter("async_ps/pushes")
        for ev in events:
            name = ev.get("event", "event")
            self.recorder.event(f"async_ps.{name}",
                                **{k: v for k, v in ev.items() if k != "event"})
        self.recorder.flush()

    def finalize(self, state=None, *, steps: int = 0, wall: float = 0.0,
                 dispatches: int = 0, close: bool = True) -> dict:
        """Flush everything, emit the ``spc.final`` chart snapshot (with the
        reconcile verdict when the final engine state is given) and the run
        throughput; returns the final payload."""
        if self._finalized is not None:
            return self._finalized
        self.flush()
        if self._visits is not None:
            self.recorder.event("sched.visits", counts=self._visits.tolist())
        payload = self.spc.chart_payload()
        if state is not None:
            verdict = self.spc.reconcile(state, replay_exact=self.replay_exact)
            payload.update(verdict)
            payload["engine_counters"] = {
                "iter": int(np.asarray(state.iter)),
                "accel_count": int(np.asarray(state.accel_count)),
                "sub_iters": int(np.asarray(state.sub_iters)),
            }
        if wall:
            self.timer.add("run", wall)
            payload["throughput"] = self.timer.throughput(
                "run", steps=steps,
                examples=steps * self.examples_per_step,
                dispatches=dispatches or int(self.recorder.total("train/dispatches")))
        self.recorder.event("spc.final", **payload)
        self.recorder.flush()
        if close:
            self.recorder.close()
        self._finalized = payload
        return payload
