"""Coordinator: N worker threads over per-worker FCPR shards + SSP gate.

Drives the async parameter-server engine on one host: the dataset's FCPR
cycle is striped across workers (worker w's k-th batch is global batch
``k·N + w``, so one async "round" covers the same batch set as N
consecutive synchronous steps and the server's ψ window still means one
epoch), worker threads run the split step from ``worker.py`` against the
shared :class:`~repro.distributed.async_ps.server.ParamServer`, and the
:class:`StalenessGate` bounds how far workers may drift apart.

Staleness semantics (the contract the tests pin down):

  * ``max_staleness`` bounds the SSP *step clock*: a worker may start local
    step k only once every worker has finished step ``k − max_staleness``.
    At ``max_staleness=0`` the rounds are lockstep — the synchronous
    data-parallel schedule — and with a single worker the engine is
    **bit-exact** with the synchronous per-step engine (the parity anchor:
    every pull sees τ = 0, so pushes are exact replacements).
  * The *version* staleness τ recorded per push (and fed to ``w(τ)``) is
    the number of pushes that raced this worker between pull and push;
    under the gate it is bounded by ``(2·max_staleness + 1)·(N − 1)``:
    while a worker sits at step k, each of the N−1 peers can push steps
    k−s through k+s (starting k+s+1 would need the sitter's clock to
    advance), i.e. 2s+1 pushes apiece.  At s=0 this is the within-round
    racing bound N−1.

Elasticity (ISSUE 7 — heartbeat, eviction, re-striping):

  * every gate interaction stamps a per-worker heartbeat; a *waiting*
    worker re-stamps on every poll tick, so only a worker that is genuinely
    stuck (hung syscall, dead thread, injected hang) goes stale.  A worker
    that blocks the SSP clock past ``deadline_s`` is detected by whoever it
    blocks;
  * non-elastic gates (the default — the PR-3 contract) fail fast: the
    waiter raises :class:`WorkerStalled` naming the stalled worker and its
    last completed step, and aborts peers, instead of the old silent
    ``cv.wait(timeout=120)`` spin;
  * ``elastic=True`` gates *evict* instead: the stalled worker leaves the
    SSP ``min()`` (survivors advance), the server fences its late pushes
    (:meth:`ParamServer.mark_evicted`), and the coordinator re-stripes the
    evicted worker's FCPR shard across survivors
    (:meth:`ShardedFeed.restripe`).  A worker whose own step raises (a real
    exception or an injected crash) self-evicts via :meth:`StalenessGate
    .leave` as long as a peer survives; the last survivor's failure aborts
    the run.
  * Re-striping and the ψ window: after an eviction the surviving workers'
    stride changes from N to M < N mid-cycle, so for up to one epoch the
    aggregate push stream visits some batches twice and others late — the
    "one ψ window = one epoch" invariant degrades to "one window ≈ one
    epoch's worth of pushes" until the new striding completes a cycle.
    The SSP staleness bound itself is preserved (the clock only ever
    shrinks its membership).

jax compiled computations release the GIL, so worker threads genuinely
overlap device work even on one process; all host-side state transitions
happen under the server lock or the gate condition variable.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp

from repro.core import ISGDConfig, ISGDState
from repro.core.reduce import StalenessReduce
from repro.distributed.async_ps.errors import (WorkerFailure, WorkerStalled,
                                               WorkerEvicted)
from repro.distributed.async_ps.server import ParamServer
from repro.distributed.async_ps.worker import Worker, make_worker_fns
from repro.fault.plan import NO_FAULTS, FaultPlan
from repro.optim.base import UpdateRule
from repro.train.trainer import TrainLog


class StalenessGate:
    """SSP bounded-staleness gate over per-worker step counts, with
    heartbeat-deadline stall detection and (optionally) eviction.

    ``deadline_s`` is the stall contract: a worker that blocks the SSP
    clock without a heartbeat for longer than this is considered dead.  It
    must comfortably exceed the longest healthy step (compile time
    included) — waiting at the gate does NOT age a worker's heartbeat, only
    genuine unresponsiveness does.  ``on_evict(wid, last_step, survivors,
    reason)`` is invoked under the gate lock, so membership changes are
    atomic with respect to workers passing the gate; the callback must not
    call back into the gate.
    """

    def __init__(self, n_workers: int, max_staleness: int, *,
                 deadline_s: float = 120.0, elastic: bool = False,
                 on_evict: Optional[Callable] = None,
                 poll_s: Optional[float] = None):
        assert n_workers >= 1 and max_staleness >= 0
        self.max_staleness = max_staleness
        self.deadline_s = deadline_s
        self.elastic = elastic
        self._on_evict = on_evict
        self._poll = poll_s if poll_s is not None else min(deadline_s / 4, 1.0)
        self._done = [0] * n_workers
        self._active = [True] * n_workers
        self._beat = [time.monotonic()] * n_workers
        self._evicted: Dict[int, str] = {}
        self._cv = threading.Condition()
        self._error = None

    # -- pure predicates ----------------------------------------------------
    def permits(self, k: int, min_done: int) -> bool:
        """Pure predicate: may a worker start step k when the slowest worker
        has completed ``min_done`` steps?"""
        return min_done >= k - self.max_staleness

    def _min_done_locked(self) -> int:
        return min(self._done[w] for w in range(len(self._done))
                   if self._active[w])

    def active_workers(self) -> List[int]:
        with self._cv:
            return [w for w in range(len(self._active)) if self._active[w]]

    def evictions(self) -> Dict[int, str]:
        with self._cv:
            return dict(self._evicted)

    # -- worker protocol ----------------------------------------------------
    def heartbeat(self, wid: int) -> None:
        """Stamp liveness mid-step (workers call this between their server
        round-trips, so long healthy steps never look like stalls).  Doubles
        as the mid-step eviction fence: a worker evicted while computing
        unwinds here, *before* its next ``observe`` would push a loss into
        the canonical ψ queue."""
        with self._cv:
            if not self._active[wid]:
                raise WorkerEvicted(
                    f"worker {wid} evicted: {self._evicted[wid]}")
            self._beat[wid] = time.monotonic()

    def start(self, wid: int, k: int) -> None:
        with self._cv:
            self._beat[wid] = time.monotonic()
            while True:
                if self._error is not None:
                    raise RuntimeError(
                        f"worker {wid} aborted: peer failed") from self._error
                if not self._active[wid]:
                    raise WorkerEvicted(
                        f"worker {wid} evicted: {self._evicted[wid]}")
                if self.permits(k, self._min_done_locked()):
                    return
                self._cv.wait(timeout=self._poll)
                now = time.monotonic()
                self._beat[wid] = now          # a waiting worker is alive
                stalled = [w for w in range(len(self._done))
                           if self._active[w] and w != wid
                           and self._done[w] < k - self.max_staleness
                           and now - self._beat[w] > self.deadline_s]
                for w in stalled:
                    if self.elastic and len([a for a in self._active
                                             if a]) > 1:
                        self._evict_locked(
                            w, f"missed heartbeat deadline "
                               f"({self.deadline_s:.2f}s) blocking the SSP "
                               f"clock at step {self._done[w]}")
                    else:
                        err = WorkerStalled(
                            f"worker {w} stalled: no heartbeat for "
                            f"{now - self._beat[w]:.2f}s (deadline "
                            f"{self.deadline_s:.2f}s); last completed step "
                            f"{self._done[w]} while worker {wid} waits to "
                            f"start step {k}.  A worker that dies without "
                            f"abort() no longer deadlocks its peers.")
                        self._error = err
                        self._cv.notify_all()
                        raise err

    def finish(self, wid: int) -> None:
        with self._cv:
            if not self._active[wid]:
                return                         # late finish from an evictee
            self._done[wid] += 1
            self._beat[wid] = time.monotonic()
            self._cv.notify_all()

    # -- membership ---------------------------------------------------------
    def _evict_locked(self, wid: int, reason: str) -> None:
        self._active[wid] = False
        self._evicted[wid] = reason
        survivors = [w for w in range(len(self._active)) if self._active[w]]
        self._cv.notify_all()
        if self._on_evict is not None:
            self._on_evict(wid, self._done[wid], survivors, reason)

    def evict(self, wid: int, reason: str) -> None:
        with self._cv:
            if self._active[wid]:
                self._evict_locked(wid, reason)

    def leave(self, wid: int, err: BaseException) -> bool:
        """A worker's own step failed.  Elastic + surviving peers ⇒ the
        worker self-evicts (returns True); otherwise the failure aborts the
        whole gate exactly like the pre-elastic engine (returns False)."""
        with self._cv:
            if not self._active[wid]:
                return True                    # already evicted: just unwind
            if self.elastic and sum(self._active) > 1:
                self._evict_locked(wid, f"worker failed: {err!r}")
                return True
            if self._error is None:
                self._error = err
            self._cv.notify_all()
            return False

    def abort(self, err: BaseException) -> None:
        with self._cv:
            if self._error is None:
                self._error = err
            self._cv.notify_all()


class ShardedFeed:
    """Worker w's FCPR shard: local step k ⇒ global batch ``k·N + w``.

    Striding (rather than contiguous blocks) keeps each async round aligned
    with N consecutive synchronous steps of the same global cycle; with
    N == 1 this is the unmodified global sampler, which is what the
    bit-exact parity anchor relies on.

    ``n_batches % n_workers == 0`` is no longer required: the strided
    indices ``k·N + w`` enumerate every global step exactly once across
    workers, so collectively each FCPR cycle is still covered once per
    round-of-rounds — only *fixed per-worker batch ownership* is lost when
    N does not divide the cycle (a worker's shard rotates through the
    cycle instead).  That generality is what re-striping needs: after an
    eviction the coordinator calls :meth:`restripe` and the M survivors
    carry on with stride M over the same global cycle.
    """

    def __init__(self, sampler, wid: int, n_workers: int):
        assert 1 <= n_workers and 0 <= wid < n_workers
        self.sampler = sampler
        self._stripe = (wid, n_workers)        # swapped atomically on restripe

    @property
    def wid(self) -> int:
        return self._stripe[0]

    @property
    def n_workers(self) -> int:
        return self._stripe[1]

    @property
    def n_batches(self) -> int:
        """Batches per local cycle (ceil: the last stripe may be short)."""
        w, n = self._stripe
        return -(-self.sampler.n_batches // n)

    def restripe(self, wid: int, n_workers: int) -> None:
        """Re-assign this feed to stripe ``wid`` of ``n_workers`` (eviction
        re-striping).  A single tuple swap so a racing ``__call__`` sees
        either the old assignment or the new, never a torn pair."""
        self._stripe = (wid, n_workers)

    def __call__(self, k: int) -> dict:
        w, n = self._stripe
        batch = self.sampler(k * n + w)
        return {key: jnp.asarray(v) for key, v in batch.items()}


class AsyncPSCoordinator:
    """Builds the server + workers and runs the async engine end-to-end.

    Mirrors the ``(init, run)`` ergonomics of the other engines: construct
    with the model/rule/config, then ``run(params0, sampler, steps)`` →
    ``(params, state, records)`` where ``state`` is a synchronous-layout
    ``ISGDState`` and ``records`` is the per-push metrics list in server
    apply order (each with ``worker``/``tau``/``version``/``wall``).

    Robustness knobs (all default to the strict PR-3 behavior):

      * ``elastic`` — evict unresponsive/crashed workers and re-stripe
        their FCPR shard across survivors instead of failing the run;
      * ``deadline_s`` — the heartbeat deadline feeding stall detection;
      * ``faults`` — a :class:`repro.fault.FaultPlan` injected into every
        worker (no-op by default);
      * ``verify_pushes`` — workers checksum their deltas and the server
        rejects corrupt arrivals; rejected/transient pushes are retried
        with exponential backoff (``push_retries``).

    After ``run``, ``self.events`` lists evictions/crashes in order.
    """

    def __init__(self, loss_fn: Callable, rule: UpdateRule,
                 isgd_cfg: ISGDConfig, *, workers: int = 1,
                 max_staleness: int = 0, lr_fn: Callable,
                 reduce_ctx: Optional[StalenessReduce] = None,
                 inconsistent: bool = True, micro_batches: int = 1,
                 elastic: bool = False, deadline_s: float = 120.0,
                 faults: FaultPlan = NO_FAULTS, verify_pushes: bool = False,
                 push_retries: int = 3, recorder=None):
        self.recorder = recorder          # obs: push/fold latency + events
        self.rule = rule
        self.isgd_cfg = isgd_cfg
        self.workers = workers
        self.max_staleness = max_staleness
        self.reduce_ctx = (reduce_ctx if reduce_ctx is not None
                           else StalenessReduce())
        self.inconsistent = inconsistent
        self.elastic = elastic
        self.deadline_s = deadline_s
        self.faults = faults
        self.verify_pushes = verify_pushes
        self.push_retries = push_retries
        self.events: List[dict] = []
        self.fns = make_worker_fns(
            loss_fn, rule, isgd_cfg, lr_fn=lr_fn, reduce_ctx=self.reduce_ctx,
            micro_batches=micro_batches)

    def warmup(self, params0, sampler) -> None:
        """Compile every jit a timed run will hit — ``propose``, the
        ``accelerate`` subproblem (which a short warm-up *run* can never
        reach: the ψ queue needs a full epoch before the limit is finite),
        and the server's observe/fold — so benchmarks measure execution,
        not tracing."""
        import jax

        from repro.core import control

        propose, accelerate = self.fns
        batch = ShardedFeed(sampler, 0, 1)(0)
        base = self.rule.init(params0)
        queue = control.init_queue(self.isgd_cfg.n_batches)
        p1, b1, loss, aux, lr = propose(params0, base, queue, batch)
        out = accelerate(p1, batch, jnp.zeros((), jnp.float32), loss, lr)
        srv = ParamServer(params0, base, self.isgd_cfg,
                          reduce_ctx=self.reduce_ctx,
                          inconsistent=self.inconsistent)
        s1, s2 = srv.pull(), srv.pull()
        srv.observe(loss)
        srv.push(s1, p1, b1, worker=0, metrics={})      # τ=0 replacement
        srv.push(s2, p1, b1, worker=0, metrics={})      # τ=1 ⇒ fold path
        jax.block_until_ready((out[0], srv.params))

    def run(self, params0, sampler, steps: int, *,
            resume: Optional[dict] = None,
            checkpoint_fn: Optional[Callable[[dict], None]] = None,
            checkpoint_every: int = 0):
        """Run to ``steps`` total pushes (rounded up to whole rounds).

        ``resume`` is a server snapshot dict (``ParamServer
        .engine_snapshot`` / ``snapshot_from_checkpoint``): the server state
        is restored and each worker continues from its own SSP push clock —
        with one worker this resumption is bit-exact with the uninterrupted
        run (``repro.train.resume_parity``).  ``checkpoint_fn`` is invoked
        with a crash-consistent snapshot every ``checkpoint_every`` applied
        pushes.
        """
        n = self.workers
        if steps % n:
            steps = -(-steps // n) * n        # whole rounds
        self.faults.reset()
        self.events = []
        server = ParamServer(params0, self.rule.init(params0), self.isgd_cfg,
                             reduce_ctx=self.reduce_ctx,
                             inconsistent=self.inconsistent,
                             verify_pushes=self.verify_pushes,
                             checkpoint_fn=checkpoint_fn,
                             checkpoint_every=checkpoint_every,
                             recorder=self.recorder)
        if resume is not None:
            server.load_snapshot(resume)
        clocks = server.pushed_clocks()
        feeds = [ShardedFeed(sampler, w, n) for w in range(n)]

        def on_evict(wid, last_step, survivors, reason):
            server.mark_evicted(wid)
            for rank, w in enumerate(survivors):
                feeds[w].restripe(rank, len(survivors))
            self.events.append(dict(
                event="evict", worker=wid, last_step=last_step,
                reason=reason, survivors=list(survivors),
                at_version=len(server.records)))

        gate = StalenessGate(n, self.max_staleness,
                             deadline_s=self.deadline_s, elastic=self.elastic,
                             on_evict=on_evict if self.elastic else None)
        if resume is not None:
            # push clocks are the SSP resume point: a step whose push never
            # landed is replayed (pushes are the commit point)
            with gate._cv:
                for w in range(n):
                    gate._done[w] = clocks.get(w, 0)
        crew = [Worker(w, server, feeds[w], self.fns, gate, steps // n,
                       start_step=clocks.get(w, 0), faults=self.faults,
                       push_retries=self.push_retries,
                       verify_pushes=self.verify_pushes)
                for w in range(n)]
        if n == 1:
            crew[0].run()                     # in-thread: easier to debug
        else:
            threads = [threading.Thread(target=w.run, name=f"async-ps-{w.wid}")
                       for w in crew]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        for w in crew:
            if w.evicted and w.error is not None:
                self.events.append(dict(
                    event="crash", worker=w.wid, error=repr(w.error),
                    traceback=w.error_tb))
        failures = [w for w in crew if w.error is not None and not w.evicted]
        if failures:
            # surface the root cause, not a bystander's gate-abort error
            def secondary(w):
                return (isinstance(w.error, RuntimeError)
                        and "peer failed" in str(w.error))
            prim = next((w for w in failures if not secondary(w)), failures[0])
            raise WorkerFailure(prim.wid, prim.error,
                                prim.error_tb or "<no traceback captured>") \
                from prim.error
        return server.params, server.isgd_state(), server.records


# -- engine-checkpoint plumbing (launch/train.py, resume_parity) -------------
def snapshot_engine_kwargs(snap: dict) -> dict:
    """Server snapshot → ``checkpoints.save_engine`` kwargs: the canonical
    state in the synchronous ``ISGDState`` layout plus the async extras
    (version counter, per-worker SSP push clocks)."""
    state = ISGDState(
        base=snap["base"], queue=snap["queue"],
        iter=jnp.asarray(snap["iter"], jnp.int32),
        accel_count=jnp.asarray(snap["accel_count"], jnp.int32),
        sub_iters=jnp.asarray(snap["sub_iters"], jnp.int32))
    return dict(params=snap["params"], state=state, step=int(snap["version"]),
                server={"version": int(snap["version"]),
                        "pushed": dict(snap["pushed"])})


def snapshot_from_checkpoint(ck) -> dict:
    """``checkpoints.EngineCheckpoint`` → ``ParamServer.load_snapshot``
    input (inverse of :func:`snapshot_engine_kwargs`)."""
    if ck.server is None:
        raise ValueError("checkpoint has no async-PS server metadata; was "
                         "it written by a synchronous engine?")
    return dict(params=ck.params, base=ck.state.base, queue=ck.state.queue,
                version=int(ck.server["version"]), iter=int(ck.state.iter),
                accel_count=int(ck.state.accel_count),
                sub_iters=int(ck.state.sub_iters),
                pushed=dict(ck.server["pushed"]))


def records_to_trainlog(records) -> TrainLog:
    """Server push records → the host ``TrainLog`` schema.

    Walls are real per-push host timestamps, but with more than one worker
    the pushes *overlap*: consecutive-push deltas are ~cost/N, not the cost
    of an update, so multi-worker walls are marked ``wall_est=True`` and
    timing fits must refuse them (single-worker runs are sequential and
    keep true walls)."""
    overlapping = len({r["worker"] for r in records}) > 1
    log = TrainLog()
    for r in records:
        log.append(r, r["wall"], wall_estimated=overlapping)
    return log
