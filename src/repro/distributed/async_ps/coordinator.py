"""Coordinator: N worker threads over per-worker FCPR shards + SSP gate.

Drives the async parameter-server engine on one host: the dataset's FCPR
cycle is striped across workers (worker w's k-th batch is global batch
``k·N + w``, so one async "round" covers the same batch set as N
consecutive synchronous steps and the server's ψ window still means one
epoch), worker threads run the split step from ``worker.py`` against the
shared :class:`~repro.distributed.async_ps.server.ParamServer`, and the
:class:`StalenessGate` bounds how far workers may drift apart.

Staleness semantics (the contract the tests pin down):

  * ``max_staleness`` bounds the SSP *step clock*: a worker may start local
    step k only once every worker has finished step ``k − max_staleness``.
    At ``max_staleness=0`` the rounds are lockstep — the synchronous
    data-parallel schedule — and with a single worker the engine is
    **bit-exact** with the synchronous per-step engine (the parity anchor:
    every pull sees τ = 0, so pushes are exact replacements).
  * The *version* staleness τ recorded per push (and fed to ``w(τ)``) is
    the number of pushes that raced this worker between pull and push;
    under the gate it is bounded by ``(2·max_staleness + 1)·(N − 1)``:
    while a worker sits at step k, each of the N−1 peers can push steps
    k−s through k+s (starting k+s+1 would need the sitter's clock to
    advance), i.e. 2s+1 pushes apiece.  At s=0 this is the within-round
    racing bound N−1.

jax compiled computations release the GIL, so worker threads genuinely
overlap device work even on one process; all host-side state transitions
happen under the server lock or the gate condition variable.
"""
from __future__ import annotations

import threading
from typing import Callable, Optional

import jax.numpy as jnp

from repro.core import ISGDConfig
from repro.core.reduce import StalenessReduce
from repro.distributed.async_ps.server import ParamServer
from repro.distributed.async_ps.worker import Worker, make_worker_fns
from repro.optim.base import UpdateRule
from repro.train.trainer import TrainLog


class StalenessGate:
    """SSP bounded-staleness gate over per-worker step counts."""

    def __init__(self, n_workers: int, max_staleness: int):
        assert n_workers >= 1 and max_staleness >= 0
        self.max_staleness = max_staleness
        self._done = [0] * n_workers
        self._cv = threading.Condition()
        self._error = None

    def permits(self, k: int, min_done: int) -> bool:
        """Pure predicate: may a worker start step k when the slowest worker
        has completed ``min_done`` steps?"""
        return min_done >= k - self.max_staleness

    def start(self, wid: int, k: int) -> None:
        with self._cv:
            while self._error is None and not self.permits(k, min(self._done)):
                self._cv.wait(timeout=120.0)
            if self._error is not None:
                raise RuntimeError(
                    f"worker {wid} aborted: peer failed") from self._error

    def finish(self, wid: int) -> None:
        with self._cv:
            self._done[wid] += 1
            self._cv.notify_all()

    def abort(self, err: BaseException) -> None:
        with self._cv:
            if self._error is None:
                self._error = err
            self._cv.notify_all()


class ShardedFeed:
    """Worker w's FCPR shard: local step k ⇒ global batch ``k·N + w``.

    Striding (rather than contiguous blocks) keeps each async round aligned
    with N consecutive synchronous steps of the same global cycle; with
    N == 1 this is the unmodified global sampler, which is what the
    bit-exact parity anchor relies on.
    """

    def __init__(self, sampler, wid: int, n_workers: int):
        assert sampler.n_batches % n_workers == 0, (
            f"n_batches={sampler.n_batches} must divide by "
            f"workers={n_workers} so every worker owns a whole FCPR shard")
        self.sampler = sampler
        self.wid = wid
        self.n_workers = n_workers
        self.n_batches = sampler.n_batches // n_workers

    def __call__(self, k: int) -> dict:
        batch = self.sampler(k * self.n_workers + self.wid)
        return {key: jnp.asarray(v) for key, v in batch.items()}


class AsyncPSCoordinator:
    """Builds the server + workers and runs the async engine end-to-end.

    Mirrors the ``(init, run)`` ergonomics of the other engines: construct
    with the model/rule/config, then ``run(params0, sampler, steps)`` →
    ``(params, state, records)`` where ``state`` is a synchronous-layout
    ``ISGDState`` and ``records`` is the per-push metrics list in server
    apply order (each with ``worker``/``tau``/``version``/``wall``).
    """

    def __init__(self, loss_fn: Callable, rule: UpdateRule,
                 isgd_cfg: ISGDConfig, *, workers: int = 1,
                 max_staleness: int = 0, lr_fn: Callable,
                 reduce_ctx: Optional[StalenessReduce] = None,
                 inconsistent: bool = True, micro_batches: int = 1):
        self.rule = rule
        self.isgd_cfg = isgd_cfg
        self.workers = workers
        self.max_staleness = max_staleness
        self.reduce_ctx = (reduce_ctx if reduce_ctx is not None
                           else StalenessReduce())
        self.inconsistent = inconsistent
        self.fns = make_worker_fns(
            loss_fn, rule, isgd_cfg, lr_fn=lr_fn, reduce_ctx=self.reduce_ctx,
            micro_batches=micro_batches)

    def warmup(self, params0, sampler) -> None:
        """Compile every jit a timed run will hit — ``propose``, the
        ``accelerate`` subproblem (which a short warm-up *run* can never
        reach: the ψ queue needs a full epoch before the limit is finite),
        and the server's observe/fold — so benchmarks measure execution,
        not tracing."""
        import jax

        from repro.core import control

        propose, accelerate = self.fns
        batch = ShardedFeed(sampler, 0, 1)(0)
        base = self.rule.init(params0)
        queue = control.init_queue(self.isgd_cfg.n_batches)
        p1, b1, loss, aux, lr = propose(params0, base, queue, batch)
        out = accelerate(p1, batch, jnp.zeros((), jnp.float32), loss, lr)
        srv = ParamServer(params0, base, self.isgd_cfg,
                          reduce_ctx=self.reduce_ctx,
                          inconsistent=self.inconsistent)
        s1, s2 = srv.pull(), srv.pull()
        srv.observe(loss)
        srv.push(s1, p1, b1, worker=0, metrics={})      # τ=0 replacement
        srv.push(s2, p1, b1, worker=0, metrics={})      # τ=1 ⇒ fold path
        jax.block_until_ready((out[0], srv.params))

    def run(self, params0, sampler, steps: int):
        n = self.workers
        if steps % n:
            steps = -(-steps // n) * n        # whole rounds
        server = ParamServer(params0, self.rule.init(params0), self.isgd_cfg,
                             reduce_ctx=self.reduce_ctx,
                             inconsistent=self.inconsistent)
        gate = StalenessGate(n, self.max_staleness)
        crew = [Worker(w, server, ShardedFeed(sampler, w, n), self.fns, gate,
                       steps // n)
                for w in range(n)]
        if n == 1:
            crew[0].run()                     # in-thread: easier to debug
        else:
            threads = [threading.Thread(target=w.run, name=f"async-ps-{w.wid}")
                       for w in crew]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        errors = [w.error for w in crew if w.error is not None]
        if errors:
            # surface the root cause, not a bystander's gate-abort RuntimeError
            def secondary(e):
                return isinstance(e, RuntimeError) and "peer failed" in str(e)
            raise next((e for e in errors if not secondary(e)), errors[0])
        return server.params, server.isgd_state(), server.records


def records_to_trainlog(records) -> TrainLog:
    """Server push records → the host ``TrainLog`` schema.

    Walls are real per-push host timestamps, but with more than one worker
    the pushes *overlap*: consecutive-push deltas are ~cost/N, not the cost
    of an update, so multi-worker walls are marked ``wall_est=True`` and
    timing fits must refuse them (single-worker runs are sequential and
    keep true walls)."""
    overlapping = len({r["worker"] for r in records}) > 1
    log = TrainLog()
    for r in records:
        log.append(r, r["wall"], wall_estimated=overlapping)
    return log
