"""Worker: the synchronous step body, split at its two server round-trips.

``make_worker_fns`` compiles the SAME primitives ``core.isgd.isgd_step``
composes — ``make_loss_and_grad``, the base ``rule.apply``, Alg.2's
``solve_subproblem`` — into two jitted pieces:

  * ``propose(params, base, queue, batch)`` — loss/gradients on the pulled
    (possibly stale) snapshot plus the vanilla base update (Alg.1 line 21).
    The loss-driven LR is read from the snapshot queue *before* this step's
    loss reaches the server, preserving the one-step lag the per-step and
    fused engines guarantee (ROADMAP design rule / Alg.1 line 19);
  * ``accelerate(params1, batch, limit, loss, lr)`` — the conservative
    subproblem (Eq. 17) from the post-update weights, driven by the
    *server's* control limit.

The split is exactly where the synchronous step's control state lives: the
queue push + limit (``ParamServer.observe``) and the commit
(``ParamServer.push``).  Everything between is per-worker-deterministic —
the :class:`~repro.core.reduce.StalenessReduce` context wraps every
``loss_and_grad`` as the identity, so the subproblem ``while_loop`` trips on
the worker's own values with no collectives inside it.
"""
from __future__ import annotations

from typing import Callable

import jax

from repro.core import ISGDConfig, control, solve_subproblem
from repro.core.reduce import ReduceCtx, StalenessReduce
from repro.optim.base import UpdateRule
from repro.train.trainer import make_loss_and_grad


def make_worker_fns(loss_fn: Callable, rule: UpdateRule,
                    isgd_cfg: ISGDConfig, *, lr_fn: Callable,
                    reduce_ctx: ReduceCtx = StalenessReduce(),
                    micro_batches: int = 1):
    """Returns the jitted ``(propose, accelerate)`` pair shared by every
    worker thread (the jit cache is thread-safe and the computation is
    identical across workers)."""
    lg = reduce_ctx.wrap_loss_and_grad(
        make_loss_and_grad(loss_fn, micro_batches))

    @jax.jit
    def propose(params, base, queue, batch):
        lr = lr_fn(control.mean(queue))      # pre-push queue: one-step lag
        (loss, aux), grads = lg(params, batch)
        base1, params1 = rule.apply(base, params, grads, lr)
        return params1, base1, loss, aux, lr

    @jax.jit
    def accelerate(params1, batch, limit, loss, lr):
        def lg1(w):
            (l, _), g = lg(w, batch)
            return l, g
        return solve_subproblem(lg1, params1, limit, loss, lr, isgd_cfg)

    return propose, accelerate


class Worker:
    """One worker thread's loop over its FCPR shard.

    Per local step k: wait at the bounded-staleness gate, pull a snapshot,
    ``propose``, ``observe`` (server-side SPC verdict), optionally solve the
    subproblem against the server's limit, ``push``.  Exceptions abort the
    gate so sibling workers unblock instead of deadlocking.
    """

    def __init__(self, wid: int, server, feed: Callable, fns, gate,
                 steps: int):
        self.wid = wid
        self.server = server
        self.feed = feed                      # k -> device batch dict
        self.propose, self.accelerate = fns
        self.gate = gate
        self.steps = steps
        self.error = None

    def run(self) -> None:
        try:
            for k in range(self.steps):
                self.gate.start(self.wid, k)
                self._step(k)
                self.gate.finish(self.wid)
        except BaseException as e:            # noqa: BLE001 — must unblock peers
            self.error = e
            self.gate.abort(e)

    def _step(self, k: int) -> None:
        batch = self.feed(k)
        snap = self.server.pull()
        params1, base1, loss, aux, lr = self.propose(
            snap.params, snap.base, snap.queue, batch)
        d = self.server.observe(loss)
        if d.accelerated:
            params2, used = self.accelerate(params1, batch, d.limit, loss, lr)
            used = int(used)
        else:
            params2, used = params1, 0
        try:
            aux_val = float(aux)              # scalar aux by repo convention
        except (TypeError, ValueError):
            aux_val = None
        self.server.push(
            snap, params2, base1, worker=self.wid,
            metrics={
                "loss": float(loss),
                "aux": aux_val,
                "psi_bar": float(d.psi_bar),
                "psi_std": float(d.psi_std),
                "limit": float(d.limit),
                "accelerated": bool(d.accelerated),
                "sub_iters": used,
                "lr": float(lr),
            })
