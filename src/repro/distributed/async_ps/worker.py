"""Worker: the synchronous step body, split at its two server round-trips.

``make_worker_fns`` compiles the SAME primitives ``core.isgd.isgd_step``
composes — ``make_loss_and_grad``, the base ``rule.apply``, Alg.2's
``solve_subproblem`` — into two jitted pieces:

  * ``propose(params, base, queue, batch)`` — loss/gradients on the pulled
    (possibly stale) snapshot plus the vanilla base update (Alg.1 line 21).
    The loss-driven LR is read from the snapshot queue *before* this step's
    loss reaches the server, preserving the one-step lag the per-step and
    fused engines guarantee (ROADMAP design rule / Alg.1 line 19);
  * ``accelerate(params1, batch, limit, loss, lr)`` — the conservative
    subproblem (Eq. 17) from the post-update weights, driven by the
    *server's* control limit.

The split is exactly where the synchronous step's control state lives: the
queue push + limit (``ParamServer.observe``) and the commit
(``ParamServer.push``).  Everything between is per-worker-deterministic —
the :class:`~repro.core.reduce.StalenessReduce` context wraps every
``loss_and_grad`` as the identity, so the subproblem ``while_loop`` trips on
the worker's own values with no collectives inside it.

Robustness (ISSUE 7): the loop carries the fault-injection hooks
(``FaultPlan.before_step`` / ``slow_factor`` / ``on_transit``, all no-ops
by default), heartbeats the gate between its server round-trips so long
healthy steps never trip the stall deadline, retries rejected/transient
pushes with exponential backoff, and — on a real failure — captures the
formatted traceback before the thread dies so the coordinator can re-raise
it with the original frames attached.
"""
from __future__ import annotations

import time
import traceback
from typing import Callable

import jax

from repro.core import ISGDConfig, control, solve_subproblem
from repro.core.reduce import ReduceCtx, StalenessReduce
from repro.distributed.async_ps.errors import (PushRejected, WorkerEvicted)
from repro.fault.plan import NO_FAULTS, FaultPlan, TransientPushError
from repro.optim.base import UpdateRule
from repro.train.trainer import make_loss_and_grad


def make_worker_fns(loss_fn: Callable, rule: UpdateRule,
                    isgd_cfg: ISGDConfig, *, lr_fn: Callable,
                    reduce_ctx: ReduceCtx = StalenessReduce(),
                    micro_batches: int = 1):
    """Returns the jitted ``(propose, accelerate)`` pair shared by every
    worker thread (the jit cache is thread-safe and the computation is
    identical across workers)."""
    lg = reduce_ctx.wrap_loss_and_grad(
        make_loss_and_grad(loss_fn, micro_batches))

    @jax.jit
    def propose(params, base, queue, batch):
        lr = lr_fn(control.mean(queue))      # pre-push queue: one-step lag
        (loss, aux), grads = lg(params, batch)
        base1, params1 = rule.apply(base, params, grads, lr)
        return params1, base1, loss, aux, lr

    @jax.jit
    def accelerate(params1, batch, limit, loss, lr):
        def lg1(w):
            (l, _), g = lg(w, batch)
            return l, g
        return solve_subproblem(lg1, params1, limit, loss, lr, isgd_cfg)

    return propose, accelerate


class Worker:
    """One worker thread's loop over its FCPR shard.

    Per local step k: wait at the bounded-staleness gate, pull a snapshot,
    ``propose``, ``observe`` (server-side SPC verdict), optionally solve the
    subproblem against the server's limit, ``push`` (with bounded retry when
    the server verifies checksums).  A failing step captures its traceback
    and either self-evicts (elastic gate, peers survive) or aborts the gate
    so sibling workers unblock instead of deadlocking.

    ``start_step`` is the resume point: a worker restored from a checkpoint
    continues at its own SSP push clock (pushes are the commit point — a
    step whose push never landed is replayed in full).
    """

    def __init__(self, wid: int, server, feed: Callable, fns, gate,
                 steps: int, *, start_step: int = 0,
                 faults: FaultPlan = NO_FAULTS, push_retries: int = 3,
                 backoff_s: float = 0.05, verify_pushes: bool = False):
        self.wid = wid
        self.server = server
        self.feed = feed                      # k -> device batch dict
        self.propose, self.accelerate = fns
        self.gate = gate
        self.steps = steps
        self.start_step = start_step
        self.faults = faults
        self.push_retries = push_retries
        self.backoff_s = backoff_s
        self.verify_pushes = verify_pushes
        self.error = None
        self.error_tb = None                  # formatted worker-thread frames
        self.evicted = False

    def run(self) -> None:
        try:
            for k in range(self.start_step, self.steps):
                self.gate.start(self.wid, k)
                self.faults.before_step(self.wid, k)
                t0 = time.perf_counter()
                self._step(k)
                slow = self.faults.slow_factor(self.wid, k)
                if slow > 1.0:
                    time.sleep((time.perf_counter() - t0) * (slow - 1.0))
                self.gate.finish(self.wid)
        except WorkerEvicted:
            # benign unwind: the coordinator already recorded the eviction,
            # re-striped the shard, and fenced this worker's pushes
            self.evicted = True
        except BaseException as e:            # noqa: BLE001 — must unblock peers
            self.error = e
            self.error_tb = traceback.format_exc()
            self.evicted = self.gate.leave(self.wid, e)

    def _step(self, k: int) -> None:
        batch = self.feed(k)
        snap = self.server.pull()
        params1, base1, loss, aux, lr = self.propose(
            snap.params, snap.base, snap.queue, batch)
        self.gate.heartbeat(self.wid)         # device work done; still alive
        d = self.server.observe(loss)
        if d.accelerated:
            params2, used = self.accelerate(params1, batch, d.limit, loss, lr)
            used = int(used)
            self.gate.heartbeat(self.wid)
        else:
            params2, used = params1, 0
        try:
            aux_val = float(aux)              # scalar aux by repo convention
        except (TypeError, ValueError):
            aux_val = None
        self._push(k, snap, params2, base1, metrics={
            "loss": float(loss),
            "aux": aux_val,
            "psi_bar": float(d.psi_bar),
            "psi_std": float(d.psi_std),
            "limit": float(d.limit),
            "accelerated": bool(d.accelerated),
            "sub_iters": used,
            "lr": float(lr),
        })

    def _push(self, k: int, snap, params2, base1, *, metrics: dict) -> None:
        """Push with integrity checksum + bounded retry.

        The checksum is computed over the worker's *pristine* trees;
        ``faults.on_transit`` may then corrupt/fail the payload (simulating
        the transport).  A verifying server rejects a corrupted arrival
        (:class:`PushRejected`) and the retry resends the clean original, so
        a transient corruption costs one round-trip, never model quality.
        """
        checksum = None
        if self.verify_pushes:
            from repro.train.checkpoints import tree_checksum
            checksum = tree_checksum((params2, base1))
        last = None
        for attempt in range(self.push_retries + 1):
            if attempt:
                time.sleep(self.backoff_s * 2 ** (attempt - 1))
            try:
                send_p, send_b = self.faults.on_transit(
                    self.wid, k, (params2, base1))
                self.server.push(snap, send_p, send_b, worker=self.wid,
                                 metrics=metrics, checksum=checksum)
                return
            except (PushRejected, TransientPushError) as e:
                last = e
        raise RuntimeError(
            f"worker {self.wid}: push for local step {k} failed after "
            f"{self.push_retries + 1} attempts") from last
