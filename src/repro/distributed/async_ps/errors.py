"""Exceptions shared by the async-PS server, gate and workers.

Kept in their own module so ``server.py`` and ``coordinator.py`` can both
raise them without importing each other.
"""
from __future__ import annotations


class WorkerStalled(RuntimeError):
    """A worker missed its heartbeat deadline and the gate is not elastic:
    the run fails fast with a diagnostic naming the stalled worker and its
    last completed step, instead of peers spinning forever."""


class WorkerEvicted(RuntimeError):
    """Raised inside an *evicted* worker's gate/server calls so its thread
    unwinds cleanly without touching canonical state (its pushes are
    rejected, its ``finish`` is ignored)."""


class PushRejected(RuntimeError):
    """The server rejected a delta whose content checksum failed — the
    payload was corrupted between the worker computing it and the push
    landing.  Retryable: the worker resends the uncorrupted original."""


class WorkerFailure(RuntimeError):
    """A worker thread died and the run cannot continue.  Carries the
    worker's formatted traceback (the live frames died with the thread) and
    chains the original exception as ``__cause__``."""

    def __init__(self, wid: int, err: BaseException, tb: str):
        self.wid = wid
        self.original = err
        super().__init__(
            f"async-PS worker {wid} failed: {err!r}\n"
            f"--- worker thread traceback ---\n{tb}")
