"""Async parameter-server ISGD engine (paper §6.2) — staleness-bounded
workers against a server-side SPC controller.

The paper's second scaling mode runs ISGD on a heterogeneous system:
workers compute gradients/ψ on their own batches and push to a parameter
server asynchronously.  This package maps that onto a single jax host:

  * :class:`~repro.distributed.async_ps.server.ParamServer` — canonical
    ``(params, base-rule state)`` plus the ψ control queue.  The SPC
    limit/accelerate logic runs **server-side** (``observe``), so
    undertrained-batch detection uses globally consistent, globally ordered
    loss statistics even when workers race; pushed deltas are folded in
    staleness-weighted: ``new = old + w(τ)·(final − snapshot)``.
  * :class:`~repro.distributed.async_ps.worker.Worker` /
    ``make_worker_fns`` — the synchronous step body split at its two server
    round-trips, reusing ``make_loss_and_grad``, the base ``rule.apply``
    and ``solve_subproblem`` under a
    :class:`~repro.core.reduce.StalenessReduce` context (loss/grads stay
    local ⇒ the subproblem ``while_loop`` is per-worker-deterministic).
  * :class:`~repro.distributed.async_ps.coordinator.AsyncPSCoordinator` —
    N threads over per-worker FCPR shards behind a bounded-staleness
    (SSP) gate.

Staleness semantics (pinned by tests/test_async_ps.py):

  * ``w(τ)`` is configurable via ``StalenessReduce``: ``1/(1+ατ)``
    (default), ``exp(-ατ)``, or ``1`` — always ``w(0) = 1``;
  * τ is the number of pushes applied between a worker's pull and its own
    push; the gate bounds it by ``(2·max_staleness + 1)·(workers − 1)``
    (each peer can push steps k−s…k+s while a worker sits at step k);
  * ``max_staleness=0`` forces lockstep rounds — the synchronous schedule.
    With one worker every τ is 0, pushes are exact replacements, and the
    engine is **bit-exact** with the synchronous per-step engine (losses,
    limits, accelerate decisions, final params), including under a
    ψ̄-dependent loss-driven LR: workers read ψ̄ from the pulled queue
    *before* their loss reaches the server — the same one-step lag the
    per-step and fused engines carry (Alg.1 line 19).

Elasticity contract (ISSUE 7 — eviction, re-striping, durability):

  * **Eviction vs the SSP bound.** With ``elastic=True`` a worker that
    misses the heartbeat deadline while blocking the SSP clock — or whose
    own step raises — is *evicted*: removed from the gate's ``min()`` (so
    survivors advance), fenced at the server (late pushes rejected via
    :class:`~repro.distributed.async_ps.errors.WorkerEvicted`).  The
    staleness bound is preserved through membership change: the clock's
    ``min()`` ranges over a *shrinking* set, so no surviving worker ever
    observes more staleness than the pre-eviction bound
    ``(2·max_staleness + 1)·(workers − 1)`` allowed.
  * **Re-striping vs "one ψ window = one epoch".** The evicted worker's
    FCPR shard is re-striped across the M survivors
    (:meth:`~repro.distributed.async_ps.coordinator.ShardedFeed.restripe`,
    which drops the old ``n_batches % n_workers == 0`` requirement).  For
    up to one epoch after the membership change the aggregate push stream
    visits some batches twice and others late, so the ψ window temporarily
    means "≈ one epoch's worth of pushes" rather than exactly one pass;
    the window re-aligns once the new striding completes a cycle.  The
    control chart tolerates this the same way it tolerates staleness — ψ̄
    and σ are running statistics, not per-batch bookkeeping.
  * **Checkpoints commit at pushes.**  ``ParamServer.engine_snapshot`` /
    ``load_snapshot`` (and the ``checkpoint_fn`` hook, invoked under the
    server lock) capture params, base, ψ queue, version and the per-worker
    push clocks together, so a resumed run replays exactly the steps whose
    pushes never landed — with one worker this resume is **bit-exact**
    (``repro.train.resume_parity``).
  * Failures that cannot be absorbed (non-elastic stall, last survivor
    crashing, retry exhaustion) surface as
    :class:`~repro.distributed.async_ps.errors.WorkerFailure` carrying the
    worker thread's formatted traceback, with the original exception
    chained as ``__cause__``.
"""
from __future__ import annotations

import importlib

# Lazy exports, like the parent package: ``python -m …async_ps.parity`` must
# be runnable without this __init__ eagerly importing the submodule first.
_EXPORTS = {
    "StalenessReduce": "repro.core.reduce",
    "staleness_reduce_from_spec": "repro.core.reduce",
    "AsyncPSCoordinator": "repro.distributed.async_ps.coordinator",
    "StalenessGate": "repro.distributed.async_ps.coordinator",
    "ShardedFeed": "repro.distributed.async_ps.coordinator",
    "records_to_trainlog": "repro.distributed.async_ps.coordinator",
    "snapshot_engine_kwargs": "repro.distributed.async_ps.coordinator",
    "snapshot_from_checkpoint": "repro.distributed.async_ps.coordinator",
    "run_async_parity": "repro.distributed.async_ps.parity",
    "ParamServer": "repro.distributed.async_ps.server",
    "Snapshot": "repro.distributed.async_ps.server",
    "Decision": "repro.distributed.async_ps.server",
    "Worker": "repro.distributed.async_ps.worker",
    "make_worker_fns": "repro.distributed.async_ps.worker",
    "WorkerStalled": "repro.distributed.async_ps.errors",
    "WorkerEvicted": "repro.distributed.async_ps.errors",
    "PushRejected": "repro.distributed.async_ps.errors",
    "WorkerFailure": "repro.distributed.async_ps.errors",
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)


def __dir__():
    return sorted(_EXPORTS)
