"""Async parameter-server ISGD engine (paper §6.2) — staleness-bounded
workers against a server-side SPC controller.

The paper's second scaling mode runs ISGD on a heterogeneous system:
workers compute gradients/ψ on their own batches and push to a parameter
server asynchronously.  This package maps that onto a single jax host:

  * :class:`~repro.distributed.async_ps.server.ParamServer` — canonical
    ``(params, base-rule state)`` plus the ψ control queue.  The SPC
    limit/accelerate logic runs **server-side** (``observe``), so
    undertrained-batch detection uses globally consistent, globally ordered
    loss statistics even when workers race; pushed deltas are folded in
    staleness-weighted: ``new = old + w(τ)·(final − snapshot)``.
  * :class:`~repro.distributed.async_ps.worker.Worker` /
    ``make_worker_fns`` — the synchronous step body split at its two server
    round-trips, reusing ``make_loss_and_grad``, the base ``rule.apply``
    and ``solve_subproblem`` under a
    :class:`~repro.core.reduce.StalenessReduce` context (loss/grads stay
    local ⇒ the subproblem ``while_loop`` is per-worker-deterministic).
  * :class:`~repro.distributed.async_ps.coordinator.AsyncPSCoordinator` —
    N threads over per-worker FCPR shards behind a bounded-staleness
    (SSP) gate.

Staleness semantics (pinned by tests/test_async_ps.py):

  * ``w(τ)`` is configurable via ``StalenessReduce``: ``1/(1+ατ)``
    (default), ``exp(-ατ)``, or ``1`` — always ``w(0) = 1``;
  * τ is the number of pushes applied between a worker's pull and its own
    push; the gate bounds it by ``(2·max_staleness + 1)·(workers − 1)``
    (each peer can push steps k−s…k+s while a worker sits at step k);
  * ``max_staleness=0`` forces lockstep rounds — the synchronous schedule.
    With one worker every τ is 0, pushes are exact replacements, and the
    engine is **bit-exact** with the synchronous per-step engine (losses,
    limits, accelerate decisions, final params), including under a
    ψ̄-dependent loss-driven LR: workers read ψ̄ from the pulled queue
    *before* their loss reaches the server — the same one-step lag the
    per-step and fused engines carry (Alg.1 line 19).
"""
from __future__ import annotations

import importlib

# Lazy exports, like the parent package: ``python -m …async_ps.parity`` must
# be runnable without this __init__ eagerly importing the submodule first.
_EXPORTS = {
    "StalenessReduce": "repro.core.reduce",
    "staleness_reduce_from_spec": "repro.core.reduce",
    "AsyncPSCoordinator": "repro.distributed.async_ps.coordinator",
    "StalenessGate": "repro.distributed.async_ps.coordinator",
    "ShardedFeed": "repro.distributed.async_ps.coordinator",
    "records_to_trainlog": "repro.distributed.async_ps.coordinator",
    "run_async_parity": "repro.distributed.async_ps.parity",
    "ParamServer": "repro.distributed.async_ps.server",
    "Snapshot": "repro.distributed.async_ps.server",
    "Decision": "repro.distributed.async_ps.server",
    "Worker": "repro.distributed.async_ps.worker",
    "make_worker_fns": "repro.distributed.async_ps.worker",
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)


def __dir__():
    return sorted(_EXPORTS)
