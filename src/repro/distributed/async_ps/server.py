"""Parameter server: canonical ``(params, state)`` + server-side SPC.

The server owns three things the async engine must keep globally
consistent no matter how workers race (paper §6.2, ROADMAP "async
parameter server" item):

  1. **the canonical weights and base-rule state** — updated only under the
     server lock, one version per applied push;
  2. **the ψ control queue** — every worker loss is pushed into THIS queue
     (``observe``), so the control limit ψ̄ + kσ and the accelerate decision
     are computed from the same globally ordered statistics a synchronous
     run would see, not from any worker's stale snapshot;
  3. **the staleness weighting** — a push that raced ``τ`` other pushes is
     folded in as ``new = old + w(τ)·(final − snapshot)`` with ``w`` from
     the :class:`~repro.core.reduce.StalenessReduce` context.

τ == 0 (no intervening push — always the case for the single-worker
``max_staleness=0`` configuration) is applied as an exact replacement with
the worker's final tree: mathematically identical to ``old + 1·delta``
(``old`` *is* the snapshot when τ == 0) but free of the f32 round-trip
``snap + (final − snap)``, which is what makes the async engine **bit-exact**
with the synchronous per-step engine at the parity anchor.

The two worker round-trips per step (``observe`` then ``push``) mirror the
two places the synchronous ``isgd_step`` touches control state: the queue
push + limit *before* the conservative subproblem, and the counter/param
commit after it.

Robustness (ISSUE 7): the server is also the engine's durability and
integrity point —

  * ``engine_snapshot``/``load_snapshot`` capture/restore the full server
    state (params, base, ψ queue, version/iteration counters AND the
    per-worker push clocks) under the lock, so a checkpoint taken between
    pushes is *crash-consistent*: pushes are the commit point, and a resumed
    run replays exactly the steps whose pushes never landed.  A
    ``checkpoint_fn`` wired at construction is invoked (still under the
    lock) every ``checkpoint_every`` versions;
  * ``verify_pushes=True`` makes ``push`` recompute the worker-supplied
    content checksum over the received trees and reject mismatches with
    :class:`~repro.distributed.async_ps.errors.PushRejected` — a delta
    corrupted in transit never reaches canonical state (the worker's
    bounded retry resends it clean);
  * ``mark_evicted(wid)`` fences a worker the coordinator evicted: its
    late pushes raise
    :class:`~repro.distributed.async_ps.errors.WorkerEvicted` instead of
    folding stale state into the model.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import ISGDConfig, ISGDState, control
from repro.core.reduce import StalenessReduce
from repro.distributed.async_ps.errors import PushRejected, WorkerEvicted


# Module-level jits (shared cache): per-instance closures would re-trace for
# every fresh server, putting compilation inside benchmark timed regions
# even after a warm-up run.  k_sigma/w ride in as traced scalars so every
# config/τ shares one compilation; the ops are the same the synchronous
# step runs inside its jit, so bit-exactness is unaffected.
@jax.jit
def _observe_fn(queue, loss, k_sigma):
    q2 = control.push(queue, loss)
    return (q2, control.control_limit(q2, k_sigma),
            control.mean(q2), control.std(q2))


@jax.jit
def _fold_fn(old, final, snap, w):
    """Staleness-weighted fold for τ > 0: old + w(τ)·(final − snap)."""
    return jax.tree.map(
        lambda o, f, s: (o + w * (f - s)).astype(o.dtype), old, final, snap)


class Snapshot(NamedTuple):
    """What a worker pulls: possibly-stale canonical state + its version."""
    params: object            # weight pytree
    base: object              # base-rule state (e.g. momentum velocity)
    queue: control.LossQueue  # ψ queue — drives the loss-driven LR (lagged)
    version: int              # server version at pull time


class Decision(NamedTuple):
    """What ``observe`` returns: the server-side SPC verdict for one loss."""
    limit: jnp.ndarray        # ψ̄ + kσ from the canonical post-push queue
    psi_bar: jnp.ndarray
    psi_std: jnp.ndarray
    accelerated: bool         # loss > limit (False during warm-up / SGD mode)


class ParamServer:
    """Thread-safe canonical state holder with server-side SPC control."""

    def __init__(self, params, base, isgd_cfg: ISGDConfig, *,
                 reduce_ctx: Optional[StalenessReduce] = None,
                 inconsistent: bool = True, verify_pushes: bool = False,
                 checkpoint_fn: Optional[Callable[[dict], None]] = None,
                 checkpoint_every: int = 0, recorder=None):
        self._lock = threading.Lock()
        # obs ingestion rides the push commit — already a host sync point
        # (worker threads round-trip the host every step by design)
        self._recorder = recorder
        self._params = params
        self._base = base
        self._queue = control.init_queue(isgd_cfg.n_batches)
        self._cfg = isgd_cfg
        self._ctx = reduce_ctx if reduce_ctx is not None else StalenessReduce()
        self._inconsistent = inconsistent
        self._verify = verify_pushes
        self._ckpt_fn = checkpoint_fn
        self._ckpt_every = checkpoint_every
        self._version = 0
        self._iter = 0
        self._accel_count = 0
        self._sub_iters = 0
        self._pushed: Dict[int, int] = {}      # per-worker SSP push clocks
        self._evicted: set[int] = set()
        self._k_sigma = jnp.asarray(isgd_cfg.k_sigma, jnp.float32)
        self._t0 = time.perf_counter()
        self.records: List[dict] = []

    # -- worker protocol ----------------------------------------------------
    @property
    def version(self) -> int:
        return self._version

    def pull(self) -> Snapshot:
        """Current canonical state (jax arrays are immutable, so handing out
        references under the lock is race-free)."""
        with self._lock:
            return Snapshot(self._params, self._base, self._queue,
                            self._version)

    def observe(self, loss) -> Decision:
        """Push one batch loss into the canonical ψ queue and return the
        SPC verdict computed from the *post-push* queue — exactly the
        ordering of Alg.1 lines 13–22 in the synchronous step, but on
        globally consistent statistics."""
        with self._lock:
            q2, limit, psi_bar, psi_std = _observe_fn(self._queue, loss,
                                                      self._k_sigma)
            self._queue = q2
        # host-level compare of exact f32 values — identical verdict to the
        # synchronous step's traced ``loss > limit`` (warm-up ⇒ limit=inf)
        accelerated = self._inconsistent and float(loss) > float(limit)
        return Decision(limit, psi_bar, psi_std, accelerated)

    def push(self, snap: Snapshot, final_params, final_base, *,
             worker: int, metrics: dict, checksum: Optional[str] = None) -> int:
        """Fold a worker's finished step into the canonical state.

        Returns the staleness τ = versions applied between the worker's pull
        and this push.  τ == 0 applies the worker's trees verbatim (exact —
        see module docstring); τ > 0 applies ``old + w(τ)·(final − snap)``
        to params and base state alike.

        ``checksum`` (when the server verifies pushes) is the worker's
        content checksum of ``(final_params, final_base)`` computed *before*
        transit; a mismatch on arrival raises :class:`PushRejected` and
        nothing is applied.  Pushes from evicted workers raise
        :class:`WorkerEvicted` (also applying nothing).
        """
        if self._verify and checksum is not None:
            # recompute OUTSIDE the lock: checksumming the whole delta is
            # the expensive part and must not serialize healthy pushes
            from repro.train.checkpoints import tree_checksum
            got = tree_checksum((final_params, final_base))
            if got != checksum:
                raise PushRejected(
                    f"worker {worker}: delta checksum mismatch on arrival "
                    f"(sent {checksum}, received {got}) — payload corrupted "
                    f"in transit; rejecting the push")
        t_enter = time.perf_counter()
        with self._lock:
            if worker in self._evicted:
                raise WorkerEvicted(
                    f"worker {worker} push rejected: worker was evicted")
            tau = self._version - snap.version
            assert tau >= 0, (tau, self._version, snap.version)
            t_fold = time.perf_counter()
            if tau == 0:
                self._params = final_params
                self._base = final_base
            else:
                from repro.obs.timing import annotate
                with annotate("obs/ps_fold"):
                    w = self._ctx.weight(tau)
                    self._params = _fold_fn(self._params, final_params,
                                            snap.params, w)
                    self._base = _fold_fn(self._base, final_base,
                                          snap.base, w)
            fold_s = time.perf_counter() - t_fold
            self._version += 1
            self._iter += 1
            self._accel_count += int(metrics.get("accelerated", False))
            self._sub_iters += int(metrics.get("sub_iters", 0))
            self._pushed[worker] = self._pushed.get(worker, 0) + 1
            self.records.append(dict(
                metrics, worker=worker, tau=tau, version=self._version,
                wall=time.perf_counter() - self._t0))
            if (self._ckpt_fn is not None and self._ckpt_every
                    and self._version % self._ckpt_every == 0):
                # under the lock on purpose: the snapshot must pair the
                # just-applied push with its clock (crash consistency)
                self._ckpt_fn(self._snapshot_locked())
        if self._recorder is not None:
            # outside the lock: recording must not serialize healthy pushes
            self._recorder.observe("async_ps/push_commit_s",
                                   time.perf_counter() - t_enter)
            if tau > 0:
                self._recorder.observe("async_ps/fold_s", fold_s)
        return tau

    # -- elasticity / durability -------------------------------------------
    def mark_evicted(self, worker: int) -> None:
        """Fence an evicted worker: its in-flight push (pulled before the
        eviction) must not fold stale state into the canonical params."""
        with self._lock:
            self._evicted.add(worker)

    def _snapshot_locked(self) -> dict:
        return dict(params=self._params, base=self._base, queue=self._queue,
                    version=self._version, iter=self._iter,
                    accel_count=self._accel_count, sub_iters=self._sub_iters,
                    pushed=dict(self._pushed))

    def engine_snapshot(self) -> dict:
        """Crash-consistent copy of everything a resumed run needs: params,
        base, ψ queue, counters, and the per-worker push clocks (jax arrays
        are immutable, so sharing references under the lock is race-free)."""
        with self._lock:
            return self._snapshot_locked()

    def load_snapshot(self, snap: dict) -> None:
        """Restore a checkpointed server (inverse of ``engine_snapshot``).
        Worker clocks resume from ``snap['pushed']``: a step whose push
        never landed is replayed in full — pushes are the commit point."""
        with self._lock:
            self._params = snap["params"]
            self._base = snap["base"]
            self._queue = snap["queue"]
            self._version = int(snap["version"])
            self._iter = int(snap["iter"])
            self._accel_count = int(snap["accel_count"])
            self._sub_iters = int(snap["sub_iters"])
            self._pushed = {int(w): int(n)
                            for w, n in snap.get("pushed", {}).items()}

    def pushed_clocks(self) -> Dict[int, int]:
        with self._lock:
            return dict(self._pushed)

    # -- results ------------------------------------------------------------
    @property
    def params(self):
        with self._lock:
            return self._params

    def isgd_state(self) -> ISGDState:
        """Canonical state in the synchronous engine's ``ISGDState`` layout
        (counters as i32 scalars), so callers compare/checkpoint uniformly."""
        with self._lock:
            return ISGDState(
                base=self._base,
                queue=self._queue,
                iter=jnp.asarray(self._iter, jnp.int32),
                accel_count=jnp.asarray(self._accel_count, jnp.int32),
                sub_iters=jnp.asarray(self._sub_iters, jnp.int32),
            )
