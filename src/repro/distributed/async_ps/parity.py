"""Async-PS parity / convergence check (runnable, mirrors
``repro.distributed.parity``).

Two modes over the same rigged problem (least squares with one outlier
batch per FCPR cycle so the conservative subproblem actually fires, driven
by a ψ̄-dependent loss-driven LR so the one-step queue lag is exercised):

  * ``--workers 1`` (default, ``max_staleness`` forced 0): the acceptance
    anchor — the async engine must be **bit-exact** with the synchronous
    per-step engine: losses, control limits, accelerate decisions,
    sub-iteration counts, ψ̄/σ, final params and final counters, over
    ``--steps`` covering ≥ 4 FCPR epochs.
  * ``--workers N`` (N > 1): convergence — async final-epoch mean ψ̄ within
    ``--tol`` of the synchronous engine's on the same global cycle, with
    the recorded version staleness τ within the gate's bound.

  PYTHONPATH=src python -m repro.distributed.async_ps.parity --steps 32
  PYTHONPATH=src python -m repro.distributed.async_ps.parity \
      --workers 2 --max-staleness 2 --steps 64 --tol 0.25
"""
from __future__ import annotations

import argparse


def _problem(batch_size: int, n_batches: int, dim: int = 6, seed: int = 0):
    import jax.numpy as jnp
    import numpy as np

    from repro.core import ISGDConfig
    from repro.data import FCPRSampler

    rng = np.random.RandomState(seed)
    xs = rng.randn(batch_size * n_batches, dim).astype(np.float32)
    ys = ((xs @ rng.randn(dim, 1).astype(np.float32)).ravel()
          / np.sqrt(dim)).astype(np.float32)
    ys[:batch_size] += 3.0                    # the under-trained batch

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, loss

    params = {"w": jnp.zeros((dim,), jnp.float32),
              "b": jnp.zeros((), jnp.float32)}
    sampler = FCPRSampler({"x": xs, "y": ys}, batch_size=batch_size, seed=1)
    # zeta=None on purpose: the subproblem's ζ then tracks the ψ̄-driven LR
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=1.0, stop=3)
    return loss_fn, params, sampler, icfg


def _lr_fn(psi_bar):
    import jax.numpy as jnp
    # ψ̄-dependent: any queue-lag regression shifts the whole trajectory
    return jnp.asarray(0.01) + 0.001 * jnp.minimum(psi_bar, 1.0)


def run_async_parity(steps: int = 32, *, workers: int = 1,
                     max_staleness: int = 0, tol: float = 0.25,
                     batch_size: int = 8, n_batches: int = 4,
                     decay: str = "inverse", verbose: bool = False) -> dict:
    """Returns {"ok": bool, "mode": "bitexact"|"convergence", ...}."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.reduce import StalenessReduce
    from repro.distributed.async_ps import AsyncPSCoordinator
    from repro.optim import momentum
    from repro.train import make_train_step

    if n_batches % workers:
        n_batches = 4 * workers       # every worker owns a whole FCPR shard
    loss_fn, params0, sampler, icfg = _problem(batch_size, n_batches)
    rule = momentum(0.9)
    bitexact = workers == 1 and max_staleness == 0

    # synchronous per-step reference over the same global FCPR cycle
    init_fn, step = make_train_step(loss_fn, rule, icfg, lr_fn=_lr_fn,
                                    donate=False)
    ref_p = jax.tree.map(jnp.copy, params0)
    ref_s = init_fn(ref_p)
    ref = []
    for j in range(steps):
        batch = {k: jnp.asarray(v) for k, v in sampler(j).items()}
        ref_s, ref_p, m = step(ref_s, ref_p, batch)
        ref.append({k: np.asarray(v) for k, v in m.items() if k != "aux"})

    coord = AsyncPSCoordinator(
        loss_fn, rule, icfg, workers=workers, max_staleness=max_staleness,
        lr_fn=_lr_fn, reduce_ctx=StalenessReduce(decay=decay))
    got_p, got_s, records = coord.run(params0, sampler, steps)

    n_accel = sum(r["accelerated"] for r in records)
    taus = [r["tau"] for r in records]
    out = {"workers": workers, "max_staleness": max_staleness, "steps": steps,
           "accelerations": n_accel, "max_tau": max(taus),
           "tau_bound": (2 * max_staleness + 1) * (workers - 1)}

    if bitexact:
        mism = 0
        for j, (r, g) in enumerate(zip(ref, records)):
            for key in ("loss", "psi_bar", "psi_std", "limit",
                        "accelerated", "sub_iters"):
                if float(r[key]) != float(g[key]):
                    mism += 1
                    if verbose:
                        print(f"step {j} {key}: sync={float(r[key])!r} "
                              f"async={float(g[key])!r}")
        dparam = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                     zip(jax.tree.leaves(ref_p), jax.tree.leaves(got_p)))
        counters_ok = (int(ref_s.accel_count) == int(got_s.accel_count)
                       and int(ref_s.sub_iters) == int(got_s.sub_iters)
                       and int(ref_s.iter) == int(got_s.iter))
        out.update(mode="bitexact", metric_mismatches=mism,
                   max_param_dev=dparam, counters_ok=counters_ok,
                   ok=(mism == 0 and dparam == 0.0 and counters_ok
                       and max(taus) == 0 and n_accel > 0))
    else:
        n_b = sampler.n_batches
        sync_final = float(np.mean([r["psi_bar"] for r in ref[-n_b:]]))
        async_final = float(np.mean([r["psi_bar"] for r in records[-n_b:]]))
        out.update(mode="convergence", sync_final_psi_bar=sync_final,
                   async_final_psi_bar=async_final,
                   ok=(abs(sync_final - async_final) <= tol
                       and max(taus) <= out["tau_bound"] and n_accel > 0))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--max-staleness", type=int, default=0)
    ap.add_argument("--n-batches", type=int, default=4,
                    help="global FCPR batches per epoch (auto-bumped to "
                         "4*workers when not divisible by --workers)")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="final-epoch mean ψ̄ tolerance (multi-worker mode)")
    ap.add_argument("--decay", default="inverse")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    r = run_async_parity(args.steps, workers=args.workers,
                         max_staleness=args.max_staleness, tol=args.tol,
                         n_batches=args.n_batches,
                         decay=args.decay, verbose=args.verbose)
    items = " ".join(f"{k}={v}" for k, v in r.items() if k != "ok")
    print(f"async-ps parity {items} -> {'OK' if r['ok'] else 'FAIL'}")
    if r["accelerations"] == 0:
        print("parity WARNING: subproblem never fired; cond path untested")
        return 2
    return 0 if r["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
