"""Multi-process parity harness: N cooperating jax processes vs one.

The acceptance check of the multi-host scale-out (ROADMAP: multi-host 3-D
mesh): a **2-process run on a (pod=2, data=2, model=1) mesh must be
bit-exact with the 1-process (data=4, model=1) run** — same final params,
same per-step loss/ψ̄/limit series, same ψ control queue, same
accelerate/subproblem counters — for the per-step engine, the fused
chunked engine (K=32), and the sched-fcpr scheduler path, all driving a
ψ̄-dependent ``lr_fn`` on the measured path (the one-step-lagged ψ̄ of
Alg.1 line 19).

Why bit-exactness is achievable at all: the manual-strategy engines reduce
ψ/grads with ``AxisReduce(axes, deterministic=True)`` — all_gather to flat
pod-major shard order, then a local mean — so the f32 association is a
pure function of the shard *values*, not of which backend ring carried
them (``core/reduce.py``).  The FCPR data layer holds the other half: each
process's :class:`~repro.data.device_ring.DeviceRing` uploads only its
stripe of the globally permuted epoch, and this harness proves the stripes
are the *same rows* the single-process ring holds (union of per-process
stripes == single-host relaid-out epoch, bit-for-bit), plus the SPC queue
after exactly one epoch is identical — "one ψ window = one epoch" survives
scale-out.

Topology (same-machine, real cross-process collectives via gloo):

    parent (jax-free orchestrator)
      ├─ ref child:    XLA_FLAGS=..device_count=4, no coordinator
      ├─ worker 0:     XLA_FLAGS=..device_count=2, --process-id 0 ─┐ gloo
      └─ worker 1:     XLA_FLAGS=..device_count=2, --process-id 1 ─┘

Every worker writes its results npz (outputs are replicated, so worker 1's
file double-checks replication itself); the parent compares everything
bit-exactly.  Run it:

    PYTHONPATH=src python -m repro.distributed.multihost_parity \
        --procs 2 --devices-per-proc 2 --steps 32 --chunk-steps 32
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys

LEGS = ("perstep", "chunked", "sched")

# problem constants — mirror repro.distributed.hybrid_parity's canonical
# dim=6 linear problem (see the comment there for why dim stays small)
DIM = 6
N_BATCHES = 4
PER_DEVICE_BATCH = 8


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


# ---------------------------------------------------------------------------
# child: one jax process (reference or worker)
# ---------------------------------------------------------------------------
def _child(args) -> int:
    from repro.launch import env as ENV
    if args.coordinator:
        ENV.initialize_distributed(args.coordinator, args.num_processes,
                                   args.process_id)
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import ISGDConfig
    from repro.data import DeviceRing, FCPRSampler
    from repro.distributed.data_parallel import (make_chunked_hybrid_step,
                                                 make_hybrid_step,
                                                 replicate_to_mesh)
    from repro.launch.mesh import local_data_block, make_training_mesh
    from repro.optim import momentum
    from repro.sched import FCPRSchedule

    steps, K = args.steps, args.chunk_steps
    mesh = make_training_mesh()      # (4,1) ref / (pod,2,1) workers
    n_data = int(np.prod([mesh.shape[a] for a in mesh.shape
                          if a != "model"]))
    batch_size = PER_DEVICE_BATCH * n_data
    assert steps % K == 0 and steps >= 2 * N_BATCHES

    rng = np.random.RandomState(0)
    xs = rng.randn(batch_size * N_BATCHES, DIM).astype(np.float32)
    ys = ((xs @ rng.randn(DIM, 1).astype(np.float32)).ravel()
          / np.sqrt(DIM)).astype(np.float32)
    ys[:batch_size] += 3.0                      # the under-trained batch
    sampler = FCPRSampler({"x": xs, "y": ys}, batch_size=batch_size, seed=1)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, loss

    params0 = {"w": jnp.zeros((DIM,), jnp.float32),
               "b": jnp.zeros((), jnp.float32)}
    rule = momentum(0.9)
    icfg = ISGDConfig(n_batches=N_BATCHES, k_sigma=1.0, stop=3, zeta=0.01)

    def lr_fn(psi_bar):
        # ψ̄-dependent on purpose: a frozen/diverged ψ̄ shifts the params
        return jnp.asarray(0.01) + 0.001 * jnp.minimum(psi_bar, 1.0)

    ring = DeviceRing(sampler.epoch_arrays(), batch_size, mesh=mesh,
                      axis=None, relayout=True)
    out = {"n_dev": np.int64(n_data),
           "proc": np.int64(jax.process_index()),
           "nprocs": np.int64(jax.process_count())}

    # -- FCPR striping evidence: this process's actual device-resident
    # rows, tagged with their global row offsets ---------------------------
    lo, hi, total = local_data_block(mesh)
    out["block"] = np.asarray([lo, hi, total], np.int64)
    xa = ring.arrays["x"]
    rows_per_shard = xa.shape[0] // total
    shards = sorted(xa.addressable_shards,
                    key=lambda s: s.index[0].start or 0)
    out["stripe_starts"] = np.asarray(
        [s.index[0].start or 0 for s in shards], np.int64)
    out["stripe_data"] = np.concatenate(
        [np.asarray(s.data) for s in shards], axis=0)
    assert out["stripe_data"].shape[0] == (hi - lo) * rows_per_shard
    out["epoch_x"] = sampler.epoch_arrays()["x"]   # permuted global epoch

    def fetch(tree):
        return jax.tree.map(np.asarray, tree)      # replicated -> host

    def record(leg, s, p, stacked, queue_epoch1=None):
        out[f"{leg}_w"] = np.asarray(p["w"])
        out[f"{leg}_b"] = np.asarray(p["b"])
        for k in ("loss", "limit", "psi_bar", "accelerated", "sub_iters"):
            out[f"{leg}_{k}"] = stacked[k]
        out[f"{leg}_queue_buf"] = np.asarray(s.queue.buf)
        out[f"{leg}_queue_total"] = np.asarray(s.queue.total)
        out[f"{leg}_queue_count"] = np.asarray(s.queue.count)
        out[f"{leg}_accel_count"] = np.asarray(s.accel_count)
        out[f"{leg}_sub_iters_total"] = np.asarray(s.sub_iters)
        if queue_epoch1 is not None:
            out[f"{leg}_queue_epoch1"] = queue_epoch1

    def fresh():
        p = replicate_to_mesh(jax.tree.map(np.asarray, params0), mesh)
        return p

    # ---- per-step engine, ψ̄-lagged lr computed on the measured path ----
    init_fn, step_fn = make_hybrid_step(loss_fn, rule, icfg, mesh,
                                        axis=None, lr_fn=lr_fn,
                                        donate=False)
    p = fresh()
    s = replicate_to_mesh(fetch(init_fn(params0)), mesh)
    ms, queue_epoch1 = [], None
    for j in range(steps):
        # lr is NOT passed: the engine reads ψ̄ from the incoming state's
        # queue inside the jitted step — the one-step lag of Alg.1 line 19
        # on the measured path, identical program on both topologies
        s, p, m = step_fn(s, p, ring(j))
        ms.append(fetch(m))
        if j + 1 == N_BATCHES:                 # "one ψ window = one epoch"
            queue_epoch1 = np.concatenate([
                np.asarray(s.queue.buf).ravel(),
                np.asarray(s.queue.total).ravel().astype(np.float32),
                np.asarray(s.queue.count).ravel().astype(np.float32)])
    stacked = {k: np.stack([m[k] for m in ms]) for k in ms[0]}
    record("perstep", s, p, stacked, queue_epoch1)

    # ---- chunked engine, one fused dispatch per K steps ------------------
    cinit, chunk = make_chunked_hybrid_step(loss_fn, rule, icfg, mesh,
                                            chunk_steps=K, axis=None,
                                            lr_fn=lr_fn, donate=False)
    p = fresh()
    s = replicate_to_mesh(fetch(cinit(params0)), mesh)
    outs = []
    for c in range(steps // K):
        s, p, msk = chunk(s, p, ring.arrays, c * K)
        outs.append(fetch(msk))
    stacked = {k: np.concatenate([o[k] for o in outs]) for k in outs[0]}
    record("chunked", s, p, stacked)

    # ---- scheduler path: FCPR policy drawn on device inside the scan ----
    fcpr = FCPRSchedule()
    sinit, schunk = make_chunked_hybrid_step(loss_fn, rule, icfg, mesh,
                                             chunk_steps=K, axis=None,
                                             lr_fn=lr_fn, donate=False,
                                             schedule=fcpr)
    p = fresh()
    s = replicate_to_mesh(fetch(sinit(params0)), mesh)
    ss = replicate_to_mesh(fetch(fcpr.init(N_BATCHES)), mesh)
    outs = []
    for c in range(steps // K):
        s, p, ss, msk = schunk(s, p, ss, ring.arrays, c * K)
        outs.append(fetch(msk))
    stacked = {k: np.concatenate([o[k] for o in outs]) for k in outs[0]}
    record("sched", s, p, stacked)

    np.savez(args.out, **out)
    print(f"child proc={int(out['proc'])}/{int(out['nprocs'])} "
          f"mesh={dict(mesh.shape)} wrote {args.out}")
    return 0


# ---------------------------------------------------------------------------
# parent: orchestrate + compare
# ---------------------------------------------------------------------------
def _spawn(extra_args, devices, out, workdir, timeout):
    from repro.launch import env as ENV
    env = dict(os.environ)
    src_root = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    ENV.force_host_device_count(devices, env=env)
    env.setdefault("JAX_PLATFORMS", "cpu")
    cmd = [sys.executable, "-m", "repro.distributed.multihost_parity",
           "--child", "--out", out] + extra_args
    return subprocess.Popen(cmd, env=env, cwd=workdir,
                            stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
                            text=True)


def run_multihost_parity(procs: int = 2, devices_per_proc: int = 2,
                         steps: int = 32, chunk_steps: int = 32,
                         workdir: str = ".", timeout: float = 420.0,
                         verbose: bool = False) -> dict:
    """Spawn the reference and the N-process group, compare bit-exactly.
    Returns {"ok": bool, "legs": {...}, "striping": {...}, ...}."""
    import numpy as np
    import tempfile

    total = procs * devices_per_proc
    tmp = tempfile.mkdtemp(prefix="mhp_")
    ref_out = os.path.join(tmp, "ref.npz")
    w_out = [os.path.join(tmp, f"w{i}.npz") for i in range(procs)]
    sargs = ["--steps", str(steps), "--chunk-steps", str(chunk_steps)]

    ref = _spawn(sargs, total, ref_out, workdir, timeout)
    port = _free_port()
    workers = [
        _spawn(sargs + ["--coordinator", f"127.0.0.1:{port}",
                        "--num-processes", str(procs),
                        "--process-id", str(i)],
               devices_per_proc, w_out[i], workdir, timeout)
        for i in range(procs)]

    logs = {}
    failed = []
    for name, proc in [("ref", ref)] + [(f"w{i}", w)
                                        for i, w in enumerate(workers)]:
        try:
            out, _ = proc.communicate(timeout=timeout)
        except subprocess.TimeoutExpired:
            proc.kill()
            out = proc.communicate()[0] + "\n<TIMEOUT>"
        logs[name] = out
        if proc.returncode != 0:
            failed.append(name)
    if failed:
        for name in failed:
            print(f"--- {name} (rc != 0) ---\n{logs[name]}")
        return {"ok": False, "failed_children": failed, "legs": {}}

    R = dict(np.load(ref_out, allow_pickle=False))
    W = [dict(np.load(p, allow_pickle=False)) for p in w_out]

    legs = {}
    keys = ["w", "b", "loss", "limit", "psi_bar", "accelerated",
            "sub_iters", "queue_buf", "queue_total", "queue_count",
            "accel_count", "sub_iters_total"]
    for leg in LEGS:
        bad = []
        for key in keys + (["queue_epoch1"] if leg == "perstep" else []):
            k = f"{leg}_{key}"
            if not np.array_equal(R[k], W[0][k]):
                bad.append(f"{key}: ref!=workers "
                           f"(maxdiff {np.max(np.abs(R[k] - W[0][k]))})")
            if not np.array_equal(W[0][k], W[-1][k]):
                bad.append(f"{key}: worker replicas differ")
        legs[leg] = {"ok": not bad, "bad": bad,
                     "accelerations": int(R[f"{leg}_accel_count"])}

    # ---- FCPR striping: union of per-process stripes == the single-host
    # relaid-out permuted epoch, and the SPC window covers exactly it -----
    n_rows = R["epoch_x"].shape[0]
    assembled = np.full_like(R["epoch_x"], np.nan)
    for w in W:
        row = 0
        for start in w["stripe_starts"]:
            shard_rows = w["stripe_data"].shape[0] // len(w["stripe_starts"])
            assembled[start:start + shard_rows] = \
                w["stripe_data"][row:row + shard_rows]
            row += shard_rows
    # expected: the reference ring's own device rows, assembled identically
    ref_assembled = np.full_like(R["epoch_x"], np.nan)
    row = 0
    for start in R["stripe_starts"]:
        shard_rows = R["stripe_data"].shape[0] // len(R["stripe_starts"])
        ref_assembled[start:start + shard_rows] = \
            R["stripe_data"][row:row + shard_rows]
        row += shard_rows
    # and the analytic relayout of the permuted epoch (independent of any
    # DeviceRing code): batch-major -> shard-major regrouping
    bs = n_rows // N_BATCHES
    bsl = bs // int(R["n_dev"])
    expect = (R["epoch_x"].reshape(N_BATCHES, int(R["n_dev"]), bsl, DIM)
              .swapaxes(0, 1).reshape(n_rows, DIM))
    striping = {
        "union_covers_epoch": bool(np.isfinite(assembled).all()),
        "union_equals_singlehost": bool(np.array_equal(assembled,
                                                       ref_assembled)),
        "matches_analytic_relayout": bool(np.array_equal(assembled, expect)),
        "epoch_equal_across_processes": bool(
            np.array_equal(W[0]["epoch_x"], R["epoch_x"])
            and np.array_equal(W[-1]["epoch_x"], R["epoch_x"])),
    }
    striping["ok"] = all(striping.values())

    ok = all(leg["ok"] for leg in legs.values()) and striping["ok"]
    accel = legs["perstep"]["accelerations"]
    result = {"ok": ok, "procs": procs,
              "devices_per_proc": devices_per_proc, "steps": steps,
              "K": chunk_steps, "accelerations": accel, "legs": legs,
              "striping": striping}
    if verbose or not ok:
        for leg, r in legs.items():
            print(f"  {leg:8s} ok={r['ok']} "
                  f"accel={r['accelerations']} {r['bad'] or ''}")
        print(f"  striping {striping}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--child", action="store_true",
                    help="internal: run as one jax process of the harness")
    ap.add_argument("--out", default=None, help="child: npz output path")
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    ap.add_argument("--procs", type=int, default=2)
    ap.add_argument("--devices-per-proc", type=int, default=2)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--chunk-steps", type=int, default=32)
    ap.add_argument("--timeout", type=float, default=420.0)
    ap.add_argument("--json-out", default=None,
                    help="parent: write the result dict as JSON here")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    if args.child:
        return _child(args)
    r = run_multihost_parity(procs=args.procs,
                             devices_per_proc=args.devices_per_proc,
                             steps=args.steps, chunk_steps=args.chunk_steps,
                             timeout=args.timeout, verbose=args.verbose)
    if args.json_out:
        def clean(x):
            if isinstance(x, dict):
                return {k: clean(v) for k, v in x.items()}
            if isinstance(x, (list, tuple)):
                return [clean(v) for v in x]
            return x if isinstance(x, (bool, int, float, str,
                                       type(None))) else str(x)
        with open(args.json_out, "w") as f:
            json.dump(clean(r), f, indent=2)
    print(f"multihost-parity procs={r.get('procs')}x"
          f"{r.get('devices_per_proc')}dev steps={r.get('steps')} "
          f"K={r.get('K')} accelerations={r.get('accelerations')} -> "
          f"{'OK' if r['ok'] else 'FAIL'}")
    if r["ok"] and not r.get("accelerations"):
        print("multihost-parity WARNING: subproblem never fired")
        return 2
    return 0 if r["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
