"""shard_map/GSPMD ISGD engine: pure data parallelism (paper §6, Fig. 8)
and the hybrid DP × TP regime on ``(data, model)`` / ``(pod, data, model)``
meshes — single-host or multi-process (see ``README.md`` in this package
for the full process-aware contract).

One engine, one step path.  ``make_hybrid_step`` runs the *same* step body
every other synchronous engine uses — ``train.trainer.make_step_core`` —
so the loss-driven LR (ψ̄ read from the queue with its one-step lag, Alg.1
line 19) is identical everywhere.  (Historical note: the old pjit runner
hand-rolled its own step closure and froze the schedule at ``lr_fn(0.0)``;
that closure is gone and tests/test_hybrid.py pins the fix.)  The engine
picks its execution strategy from the mesh through ONE dispatch point,
:func:`mesh_strategy`:

  * **manual shard_map over the data axes** — when every non-data axis is
    trivial (a 1-D ``('data',)`` mesh, ``(data, model=1)``, or
    ``(pod, data, model=1)``).  The batch is sharded over the data axes
    (leading dim); each device computes loss/gradients on its shard and
    ``AxisReduce`` reduces both, so the ``lax.cond`` accelerate predicate
    and every trip of the subproblem ``while_loop`` see replicated values —
    the invariant ``core/isgd.py`` documents.  Params and ISGD state are
    replicated.  The strategy always constructs
    ``AxisReduce(axes, deterministic=True)``: the gather-then-reduce mode
    whose f32 association is a pure function of the flat shard order, so a
    ``(pod=2, data=2)`` two-process mesh reproduces a single-process
    ``(data=4)`` mesh *bit-exactly* (``core/reduce.py``; pinned by
    ``repro.distributed.multihost_parity``).  This is the pure
    data-parallel regime the paper scales (its multi-GPU experiments
    replicate the model); ``make_data_parallel_step`` remains as the alias.

  * **GSPMD (pjit-with-constraints)** — when a model/tensor axis has size
    > 1.  The identical ``make_step_core`` body is jitted as a *global*
    program: params/velocity sharded over ``model`` by their placement
    (``launch/shardings.py``) plus any activation-sharding constraints,
    batch pinned to ``P(data)`` by an in-step ``with_sharding_constraint``.
    The reduction context stays ``LOCAL`` because the traced program
    already computes the *global*-batch loss/gradients — GSPMD partitions
    the batch dim over ``data`` and inserts the cross-device reductions
    itself, so ψ and the grads are the same real numbers the manual
    strategy reduces together (associated differently in f32; the hybrid
    parity suite bounds the difference and pins bit-exactness on the legs
    where the layouts coincide).

  Why two strategies instead of ``shard_map(..., auto={'model'})``: XLA's
  SPMD partitioner (jax 0.4.37) cannot partition ``lax.scan`` inside a
  manual subgroup (``Check failed: sharding.IsManualSubgroup()``), and
  scan is load-bearing everywhere here — the transformer block stack, the
  fused chunk engine, micro-batch accumulation.  The shardy partitioner
  lifts the limitation; :func:`mesh_strategy` is the ONLY place that knows
  the split exists, so deleting it when shardy becomes the default is a
  one-function change.

``make_hybrid_step`` mirrors ``train.trainer.make_train_step`` — same
``(init_fn, step_fn)`` contract, same metrics surface — so the host loop,
examples, and benchmarks can swap engines with one line.

Both factories accept ``schedule=`` (a ``repro.sched`` policy): batch
identity is then drawn on device inside the step/scan (selection key and
table updates replicated by construction, exactly like the accelerate
cond), the signatures gain a ``sched_state`` pytree, and batches come from
``DeviceRing`` epoch arrays instead of host transfers.  ``FCPRSchedule``
through this path is bit-exact with ``schedule=None``.

**Multi-process notes** — the factories are topology-agnostic; what makes
a multi-process run work is how the *inputs* are placed:

  * build the mesh with ``repro.launch.mesh.make_training_mesh`` (global
    devices, process-contiguous pod rows);
  * pass ``axis=None`` (or an explicit tuple like ``("pod", "data")``) so
    the strategy reduces over every data sub-axis;
  * feed batches from a :class:`~repro.data.device_ring.DeviceRing` (each
    process uploads only its epoch stripe) and replicate params/state with
    :func:`replicate_to_mesh` — a plain ``device_put`` cannot address
    other processes' devices.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import ISGDConfig
from repro.core.reduce import LOCAL, AxisReduce
from repro.optim.base import UpdateRule
from repro.train.chunked import chunk_over_ring
from repro.train.trainer import make_step_core


def _data_axes(axis) -> tuple:
    return (axis,) if isinstance(axis, str) else tuple(axis)


def data_axis_size(mesh: Mesh, axis=None) -> int:
    """Total data-parallel degree: the product of the data axes' sizes
    (``axis=None`` = every pod/data axis of the mesh)."""
    if axis is None:
        from repro.launch.mesh import data_axes
        axis = data_axes(mesh)
    return int(np.prod([mesh.shape[a] for a in _data_axes(axis)]))


def batch_sharding(mesh: Mesh, axis=None) -> NamedSharding:
    """NamedSharding for host->device batch transfer (leading dim over the
    data axes — jointly, pod-major, when ``axis`` is a tuple or ``None``).

    Matches the step's data layout so the prefetcher's ``device_put`` lands
    shards exactly where the engine consumes them — no resharding copy.
    The batch is replicated over any model axis.
    """
    if axis is None:
        from repro.launch.mesh import data_axes
        axis = data_axes(mesh)
    axes = _data_axes(axis)
    return NamedSharding(mesh, P(axes[0] if len(axes) == 1 else axes))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def replicate_to_mesh(tree, mesh: Mesh):
    """Place a host-local pytree fully replicated on ``mesh`` — the
    multi-process-safe ``device_put``.

    On a single-process mesh this IS ``jax.device_put(x, P())``.  On a
    multi-process mesh ``device_put`` cannot address other processes'
    devices, so each leaf goes through
    ``jax.make_array_from_process_local_data`` instead: every process
    supplies its (identical — same seed, same init) host value and jax
    assembles the global replicated array.  Use this for params/ISGD
    state/sched state before handing them to the engines."""
    sh = replicated(mesh)
    procs = {d.process_index for d in mesh.devices.flat}
    if len(procs) <= 1:
        return jax.tree.map(lambda x: jax.device_put(x, sh), tree)

    def put(x):
        x = np.asarray(x)
        return jax.make_array_from_process_local_data(sh, x, x.shape)

    return jax.tree.map(put, tree)


def tensor_axes(mesh: Mesh, axis=None) -> tuple:
    """Non-data mesh axes with size > 1 — the tensor/model-parallel part.

    Empty ⇒ the mesh is pure data parallel and the engine uses the manual
    shard_map strategy; non-empty ⇒ the GSPMD strategy (see module doc).
    """
    if axis is None:
        from repro.launch.mesh import data_axes
        axis = data_axes(mesh)
    data = set(_data_axes(axis))
    return tuple(a for a in mesh.axis_names
                 if a not in data and mesh.shape[a] > 1)


class MeshStrategy:
    """THE strategy dispatch point: everything the engines need to know
    about *how* a mesh executes, resolved once.

    ``reduce_ctx`` — what ``make_step_core`` reduces ψ/grads with;
    ``wrap_step``/``wrap_sched`` — how a traced body becomes a mesh
    program; ``constrain_batch`` — the GSPMD-side equivalent of the manual
    in_specs.  The manual/GSPMD split (see module doc: scan-in-manual-
    subgroup is its only reason to exist) lives entirely in this class —
    when shardy lands, collapse it here and no engine factory changes.
    """

    def __init__(self, mesh: Mesh, axis=None):
        if axis is None:
            from repro.launch.mesh import data_axes
            axes = data_axes(mesh)
            assert axes, f"mesh {tuple(mesh.shape)} has no data axes"
        else:
            axes = _data_axes(axis)
        self.mesh = mesh
        #: normalized data axis spec (str when single — preserves the
        #: historical P("data") spec objects and cache keys)
        self.axis = axes[0] if len(axes) == 1 else axes
        self.tensor_axes = tensor_axes(mesh, axes)
        #: True ⇒ GSPMD strategy (global program); False ⇒ manual shard_map
        self.gspmd = bool(self.tensor_axes)
        #: reduction context for ``make_step_core`` — LOCAL under GSPMD
        #: (the traced program spans the global batch); deterministic
        #: AxisReduce under manual, so the f32 association is pinned to
        #: the flat shard order and any process topology that preserves
        #: the data order reproduces the same bits.
        self.reduce_ctx = (LOCAL if self.gspmd
                           else AxisReduce(self.axis, deterministic=True))

    def wrap_step(self, fn: Callable) -> Callable:
        """4-ary step/chunk body (state, params, batch_or_ring, lr_or_j) ->
        mesh program.  Manual: shard_map with arg 2 sharded over the data
        axes.  GSPMD: the body already IS the global program."""
        if self.gspmd:
            return fn
        return shard_map(fn, mesh=self.mesh,
                         in_specs=(P(), P(), P(self.axis), P()),
                         out_specs=(P(), P(), P()),
                         check_rep=False)

    def wrap_sched(self, fn: Callable) -> Callable:
        """Scheduled twin of ``wrap_step`` for the 5-ary bodies from
        ``repro.sched.engine``: (state, params, sched_state, ring, j) with
        only the ring sharded.  The schedule state (loss table, visit
        counters) is replicated — its updates are driven by the reduced ψ
        and the step-index-derived key, so every shard writes the same
        values (the same replication-by-construction argument as the
        accelerate cond)."""
        if self.gspmd:
            return fn
        return shard_map(fn, mesh=self.mesh,
                         in_specs=(P(), P(), P(), P(self.axis), P()),
                         out_specs=(P(), P(), P(), P()),
                         check_rep=False)

    def constrain_batch(self, batch):
        """Pin every divisible batch leaf's leading dim to the data axes —
        the GSPMD strategy's equivalent of the manual in_specs; identity on
        the manual strategy (the shard_map specs already did it)."""
        if not self.gspmd:
            return batch
        size = data_axis_size(self.mesh, self.axis)
        sh = NamedSharding(self.mesh, P(self.axis))

        def leaf(x):
            if getattr(x, "ndim", 0) and x.shape[0] % size == 0:
                return jax.lax.with_sharding_constraint(x, sh)
            return x

        return jax.tree.map(leaf, batch)


def mesh_strategy(mesh: Mesh, axis=None) -> MeshStrategy:
    """Resolve the execution strategy for ``mesh`` (see
    :class:`MeshStrategy`).  ``axis=None`` spans every data sub-axis the
    mesh has (``("pod", "data")`` on a 3-D mesh)."""
    return MeshStrategy(mesh, axis)


def make_hybrid_step(loss_fn: Callable, rule: UpdateRule,
                     isgd_cfg: ISGDConfig, mesh: Mesh, *,
                     axis=None, inconsistent: bool = True,
                     lr_fn: Optional[Callable] = None,
                     micro_batches: int = 1, donate: bool = True,
                     schedule=None, sched_seed: int = 0):
    """Returns ``(init_fn, step_fn)`` with the ``make_train_step`` contract.

    ``step_fn(state, params, batch, lr=None) -> (state, params, metrics)``
    where ``batch`` leaves carry the *global* batch on their leading dim
    (divisible by the total data-axis size).  Params/state are replicated
    over the data axes; over any tensor-parallel axis their layout follows
    the caller's placement (``launch/shardings.py``).  All outputs are
    replicated over data: grads are globally reduced before the base
    update and ψ before the queue push, so every data shard computes the
    same new params.  When ``lr`` is not passed, ``lr_fn`` reads ψ̄ from
    the queue of the *incoming* state — the one-step lag of Alg.1 line 19,
    identical on both strategies because both run ``make_step_core``.

    ``axis=None`` resolves to the mesh's data sub-axes — ``("pod", "data")``
    on a process-aware 3-D mesh, ``"data"`` otherwise (the historical
    default).

    ``schedule`` (a ``repro.sched`` policy; requires ``lr_fn``) switches to
    on-device batch selection with the scheduled contract — ``step_fn(state,
    params, sched_state, ring_arrays, j) -> (state, params, sched_state,
    metrics)`` — where ``ring_arrays`` is a :class:`DeviceRing`'s
    ``.arrays`` (relaid-out on the manual strategy, ``relayout=False`` on
    GSPMD, exactly like the chunked engine).  Selection is replicated-
    deterministic across data shards *and processes*: the draw key is a
    pure function of the replicated step index, and the loss-table update
    consumes the ``AxisReduce``-reduced ψ.
    """
    if schedule is not None:
        return _make_scheduled_hybrid(
            loss_fn, rule, isgd_cfg, mesh, axis=axis,
            inconsistent=inconsistent, lr_fn=lr_fn,
            micro_batches=micro_batches, donate=donate, schedule=schedule,
            sched_seed=sched_seed, chunk_steps=None)
    strat = mesh_strategy(mesh, axis)
    jit_kwargs = dict(donate_argnums=(0, 1)) if donate else {}
    init_fn, core_step = make_step_core(
        loss_fn, rule, isgd_cfg, inconsistent=inconsistent, lr_fn=lr_fn,
        reduce_ctx=strat.reduce_ctx, micro_batches=micro_batches)

    if strat.gspmd:
        def step_fn(state, params, batch, lr=None):
            return core_step(state, params, strat.constrain_batch(batch), lr)

        return init_fn, jax.jit(step_fn, **jit_kwargs)

    sharded = strat.wrap_step(core_step)

    def step_fn(state, params, batch, lr=None):
        if lr is None:
            from repro.core import control as C
            lr = lr_fn(C.mean(state.queue))
        return sharded(state, params, batch, jnp.asarray(lr, jnp.float32))

    return init_fn, jax.jit(step_fn, **jit_kwargs)


def _make_scheduled_hybrid(loss_fn, rule, isgd_cfg, mesh, *, axis,
                           inconsistent, lr_fn, micro_batches, donate,
                           schedule, sched_seed, chunk_steps):
    """Shared scheduled-engine builder: per-step (``chunk_steps=None``) or
    fused chunk, on either mesh strategy.  Both return ``(init_fn, fn)``
    with ``fn(state, params, sched_state, ring_arrays, j_or_j0)`` and
    ``(state, params, sched_state)`` donated."""
    from repro.sched.engine import chunk_over_schedule, make_scheduled_body

    assert lr_fn is not None, "scheduled engine needs lr_fn (device-side LR)"
    strat = mesh_strategy(mesh, axis)
    init_fn, step_fn = make_step_core(
        loss_fn, rule, isgd_cfg, inconsistent=inconsistent, lr_fn=lr_fn,
        reduce_ctx=strat.reduce_ctx, micro_batches=micro_batches)
    if chunk_steps is None:
        body = make_scheduled_body(step_fn, schedule, isgd_cfg.n_batches,
                                   sched_seed)
    else:
        body = chunk_over_schedule(step_fn, schedule, isgd_cfg.n_batches,
                                   chunk_steps, sched_seed)
    inner = strat.wrap_sched(body)

    def fn(state, params, sched_state, ring_arrays, j):
        return inner(state, params, sched_state, ring_arrays,
                     jnp.asarray(j, jnp.int32))

    jit_kwargs = dict(donate_argnums=(0, 1, 2)) if donate else {}
    return init_fn, jax.jit(fn, **jit_kwargs)


def make_chunked_hybrid_step(loss_fn: Callable, rule: UpdateRule,
                             isgd_cfg: ISGDConfig, mesh: Mesh, *,
                             chunk_steps: int, axis=None,
                             inconsistent: bool = True,
                             lr_fn: Optional[Callable] = None,
                             micro_batches: int = 1, donate: bool = True,
                             schedule=None, sched_seed: int = 0):
    """Fused K-steps-per-dispatch twin of ``make_hybrid_step``.

    The ``lax.scan`` over ``repro.train.chunked.chunk_over_ring`` runs K
    full ISGD steps without the host in the loop; metrics come back stacked
    (chunk_steps,).  Strategy follows the mesh exactly as in the per-step
    engine:

      * manual shard_map — the scan runs per device; each data shard slices
        its own rows out of its local block of a *relaid-out* sharded
        :class:`DeviceRing` (``ring_arrays`` sharded over the data axes,
        layout documented in ``repro.data.device_ring``);
      * GSPMD — the scan is one global program; ``ring_arrays`` keep the
        *global* row order (``DeviceRing(relayout=False)``) and the in-scan
        ``dynamic_slice`` picks the global batch, which the partitioner
        re-lays-out per the step's constraints.

    Returns ``(init_fn, chunk_fn)``; ``chunk_fn(state, params, ring_arrays,
    j0) -> (state, params, stacked_metrics)`` with ``(state, params)``
    donated.

    ``schedule`` switches to the scheduled contract (``chunk_fn(state,
    params, sched_state, ring_arrays, j0)``) with on-device selection in
    the scan body — see ``make_hybrid_step``; still ONE host dispatch per
    K-step chunk, on both strategies.
    """
    assert lr_fn is not None, "chunked engine needs lr_fn (no per-step host)"
    if schedule is not None:
        return _make_scheduled_hybrid(
            loss_fn, rule, isgd_cfg, mesh, axis=axis,
            inconsistent=inconsistent, lr_fn=lr_fn,
            micro_batches=micro_batches, donate=donate, schedule=schedule,
            sched_seed=sched_seed, chunk_steps=chunk_steps)
    strat = mesh_strategy(mesh, axis)
    jit_kwargs = dict(donate_argnums=(0, 1)) if donate else {}
    init_fn, step_fn = make_step_core(
        loss_fn, rule, isgd_cfg, inconsistent=inconsistent, lr_fn=lr_fn,
        reduce_ctx=strat.reduce_ctx, micro_batches=micro_batches)
    chunk = chunk_over_ring(step_fn, isgd_cfg.n_batches, chunk_steps)
    wrapped = strat.wrap_step(chunk)

    def chunk_fn(state, params, ring_arrays, j0):
        return wrapped(state, params, ring_arrays,
                       jnp.asarray(j0, jnp.int32))

    return init_fn, jax.jit(chunk_fn, **jit_kwargs)


# The pure data-parallel engine IS the hybrid engine on a pure-data mesh
# (manual shard_map strategy); the historical names stay as aliases so
# callers that never go tensor-parallel keep reading naturally.
make_data_parallel_step = make_hybrid_step
make_chunked_data_parallel_step = make_chunked_hybrid_step
