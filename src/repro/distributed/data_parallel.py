"""shard_map/GSPMD ISGD engine: pure data parallelism (paper §6, Fig. 8)
and the hybrid DP × TP regime on a 2-D ``(data, model)`` mesh.

One engine, one step path.  ``make_hybrid_step`` runs the *same* step body
every other synchronous engine uses — ``train.trainer.make_step_core`` —
so the loss-driven LR (ψ̄ read from the queue with its one-step lag, Alg.1
line 19) is identical everywhere.  (Historical note: the old pjit runner
hand-rolled its own step closure and froze the schedule at ``lr_fn(0.0)``;
that closure is gone and tests/test_hybrid.py pins the fix.)  The engine
picks its execution strategy from the mesh:

  * **manual shard_map over the data axis** — when every non-data axis is
    trivial (a 1-D ``('data',)`` mesh, or ``(data, model=1)``).  The batch
    is sharded over ``data`` (leading dim); each device computes
    loss/gradients on its shard and ``AxisReduce`` pmeans both, so the
    ``lax.cond`` accelerate predicate and every trip of the subproblem
    ``while_loop`` see replicated values — the invariant ``core/isgd.py``
    documents.  Params and ISGD state are replicated.  This is the pure
    data-parallel regime the paper scales (its multi-GPU experiments
    replicate the model); ``make_data_parallel_step`` remains as the alias.

  * **GSPMD (pjit-with-constraints)** — when a model/tensor axis has size
    > 1.  The identical ``make_step_core`` body is jitted as a *global*
    program: params/velocity sharded over ``model`` by their placement
    (``launch/shardings.py``) plus any activation-sharding constraints,
    batch pinned to ``P(data)`` by an in-step ``with_sharding_constraint``.
    The reduction context stays ``LOCAL`` because the traced program
    already computes the *global*-batch loss/gradients — GSPMD partitions
    the batch dim over ``data`` and inserts the cross-device reductions
    itself, so ψ and the grads are the same real numbers the manual
    strategy pmeans together (associated differently in f32; the hybrid
    parity suite bounds the difference and pins bit-exactness on the legs
    where the layouts coincide).

  Why two strategies instead of ``shard_map(..., auto={'model'})``: XLA's
  SPMD partitioner (jax 0.4.37) cannot partition ``lax.scan`` inside a
  manual subgroup (``Check failed: sharding.IsManualSubgroup()``), and
  scan is load-bearing everywhere here — the transformer block stack, the
  fused chunk engine, micro-batch accumulation.  The shardy partitioner
  lifts the limitation; fold the strategies together when it becomes the
  default.

``make_hybrid_step`` mirrors ``train.trainer.make_train_step`` — same
``(init_fn, step_fn)`` contract, same metrics surface — so the host loop,
examples, and benchmarks can swap engines with one line.

Both factories accept ``schedule=`` (a ``repro.sched`` policy): batch
identity is then drawn on device inside the step/scan (selection key and
table updates replicated by construction, exactly like the accelerate
cond), the signatures gain a ``sched_state`` pytree, and batches come from
``DeviceRing`` epoch arrays instead of host transfers.  ``FCPRSchedule``
through this path is bit-exact with ``schedule=None``.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import ISGDConfig
from repro.core.reduce import LOCAL, AxisReduce
from repro.optim.base import UpdateRule
from repro.train.chunked import chunk_over_ring
from repro.train.trainer import make_step_core


def data_axis_size(mesh: Mesh, axis: str = "data") -> int:
    return mesh.shape[axis]


def batch_sharding(mesh: Mesh, axis: str = "data") -> NamedSharding:
    """NamedSharding for host->device batch transfer (leading dim over data).

    Matches the step's data layout so the prefetcher's ``device_put`` lands
    shards exactly where the engine consumes them — no resharding copy.
    On a 2-D mesh the batch is replicated over the model axis.
    """
    return NamedSharding(mesh, P(axis))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def _data_axes(axis) -> tuple:
    return (axis,) if isinstance(axis, str) else tuple(axis)


def tensor_axes(mesh: Mesh, axis: str = "data") -> tuple:
    """Non-data mesh axes with size > 1 — the tensor/model-parallel part.

    Empty ⇒ the mesh is pure data parallel and the engine uses the manual
    shard_map strategy; non-empty ⇒ the GSPMD strategy (see module doc).
    """
    data = set(_data_axes(axis))
    return tuple(a for a in mesh.axis_names
                 if a not in data and mesh.shape[a] > 1)


def _sharded_over_data(fn: Callable, mesh: Mesh, axis):
    """``shard_map`` a 4-ary step/chunk body manually over the data axis:
    args 0/1/3 (state, params, lr-or-j0) replicated, arg 2 (batch or ring)
    sharded on its leading dim.  Only valid when ``tensor_axes`` is empty —
    any trivial (size-1) non-data axis is bound manually too, which is a
    no-op.

    check_rep=False: replication of the outputs follows from the pmean'd
    grads/ψ, but the rep checker can't see through cond/while_loop bodies.
    """
    return shard_map(fn, mesh=mesh,
                     in_specs=(P(), P(), P(axis), P()),
                     out_specs=(P(), P(), P()),
                     check_rep=False)


def _sharded_over_data_sched(fn: Callable, mesh: Mesh, axis):
    """Scheduled twin of ``_sharded_over_data`` for the 5-ary bodies from
    ``repro.sched.engine``: (state, params, sched_state, ring, j) with only
    the ring sharded.  The schedule state (loss table, visit counters) is
    replicated — its updates are driven by the pmean'd ψ and the
    step-index-derived key, so every shard writes the same values (the same
    replication-by-construction argument as the accelerate cond)."""
    return shard_map(fn, mesh=mesh,
                     in_specs=(P(), P(), P(), P(axis), P()),
                     out_specs=(P(), P(), P(), P()),
                     check_rep=False)


def _constrain_batch(mesh: Mesh, axis, batch):
    """Pin every divisible batch leaf's leading dim to the data axis — the
    GSPMD strategy's equivalent of the manual in_specs ``P(axis)``."""
    size = 1
    for a in _data_axes(axis):
        size *= mesh.shape[a]
    sh = NamedSharding(mesh, P(axis))

    def leaf(x):
        if getattr(x, "ndim", 0) and x.shape[0] % size == 0:
            return jax.lax.with_sharding_constraint(x, sh)
        return x

    return jax.tree.map(leaf, batch)


def make_hybrid_step(loss_fn: Callable, rule: UpdateRule,
                     isgd_cfg: ISGDConfig, mesh: Mesh, *,
                     axis: str = "data", inconsistent: bool = True,
                     lr_fn: Optional[Callable] = None,
                     micro_batches: int = 1, donate: bool = True,
                     schedule=None, sched_seed: int = 0):
    """Returns ``(init_fn, step_fn)`` with the ``make_train_step`` contract.

    ``step_fn(state, params, batch, lr=None) -> (state, params, metrics)``
    where ``batch`` leaves carry the *global* batch on their leading dim
    (divisible by the ``data`` axis size).  Params/state are replicated
    over ``data``; over any tensor-parallel axis their layout follows the
    caller's placement (``launch/shardings.py``).  All outputs are
    replicated over ``data``: grads are globally reduced before the base
    update and ψ before the queue push, so every data shard computes the
    same new params.  When ``lr`` is not passed, ``lr_fn`` reads ψ̄ from
    the queue of the *incoming* state — the one-step lag of Alg.1 line 19,
    identical on both strategies because both run ``make_step_core``.

    ``schedule`` (a ``repro.sched`` policy; requires ``lr_fn``) switches to
    on-device batch selection with the scheduled contract — ``step_fn(state,
    params, sched_state, ring_arrays, j) -> (state, params, sched_state,
    metrics)`` — where ``ring_arrays`` is a :class:`DeviceRing`'s
    ``.arrays`` (relaid-out on the manual strategy, ``relayout=False`` on
    GSPMD, exactly like the chunked engine).  Selection is replicated-
    deterministic across data shards: the draw key is a pure function of
    the replicated step index, and the loss-table update consumes the
    ``AxisReduce``-reduced ψ.
    """
    if schedule is not None:
        return _make_scheduled_hybrid(
            loss_fn, rule, isgd_cfg, mesh, axis=axis,
            inconsistent=inconsistent, lr_fn=lr_fn,
            micro_batches=micro_batches, donate=donate, schedule=schedule,
            sched_seed=sched_seed, chunk_steps=None)
    jit_kwargs = dict(donate_argnums=(0, 1)) if donate else {}

    if tensor_axes(mesh, axis):
        # GSPMD strategy: the global program, partitioned by placement +
        # constraints.  LOCAL reduction — the traced loss/grads already
        # span the global batch.
        init_fn, core_step = make_step_core(
            loss_fn, rule, isgd_cfg, inconsistent=inconsistent, lr_fn=lr_fn,
            reduce_ctx=LOCAL, micro_batches=micro_batches)

        def step_fn(state, params, batch, lr=None):
            return core_step(state, params,
                             _constrain_batch(mesh, axis, batch), lr)

        return init_fn, jax.jit(step_fn, **jit_kwargs)

    # manual shard_map strategy: per-shard body + explicit AxisReduce
    init_fn, core_step = make_step_core(
        loss_fn, rule, isgd_cfg, inconsistent=inconsistent, lr_fn=lr_fn,
        reduce_ctx=AxisReduce(axis), micro_batches=micro_batches)
    sharded = _sharded_over_data(core_step, mesh, axis)

    def step_fn(state, params, batch, lr=None):
        if lr is None:
            from repro.core import control as C
            lr = lr_fn(C.mean(state.queue))
        return sharded(state, params, batch, jnp.asarray(lr, jnp.float32))

    return init_fn, jax.jit(step_fn, **jit_kwargs)


def _make_scheduled_hybrid(loss_fn, rule, isgd_cfg, mesh, *, axis,
                           inconsistent, lr_fn, micro_batches, donate,
                           schedule, sched_seed, chunk_steps):
    """Shared scheduled-engine builder: per-step (``chunk_steps=None``) or
    fused chunk, on either mesh strategy.  Both return ``(init_fn, fn)``
    with ``fn(state, params, sched_state, ring_arrays, j_or_j0)`` and
    ``(state, params, sched_state)`` donated."""
    from repro.sched.engine import chunk_over_schedule, make_scheduled_body

    assert lr_fn is not None, "scheduled engine needs lr_fn (device-side LR)"
    gspmd = bool(tensor_axes(mesh, axis))
    init_fn, step_fn = make_step_core(
        loss_fn, rule, isgd_cfg, inconsistent=inconsistent, lr_fn=lr_fn,
        reduce_ctx=LOCAL if gspmd else AxisReduce(axis),
        micro_batches=micro_batches)
    if chunk_steps is None:
        body = make_scheduled_body(step_fn, schedule, isgd_cfg.n_batches,
                                   sched_seed)
    else:
        body = chunk_over_schedule(step_fn, schedule, isgd_cfg.n_batches,
                                   chunk_steps, sched_seed)
    if not gspmd:
        body = _sharded_over_data_sched(body, mesh, axis)
    inner = body

    def fn(state, params, sched_state, ring_arrays, j):
        return inner(state, params, sched_state, ring_arrays,
                     jnp.asarray(j, jnp.int32))

    jit_kwargs = dict(donate_argnums=(0, 1, 2)) if donate else {}
    return init_fn, jax.jit(fn, **jit_kwargs)


def make_chunked_hybrid_step(loss_fn: Callable, rule: UpdateRule,
                             isgd_cfg: ISGDConfig, mesh: Mesh, *,
                             chunk_steps: int, axis: str = "data",
                             inconsistent: bool = True,
                             lr_fn: Optional[Callable] = None,
                             micro_batches: int = 1, donate: bool = True,
                             schedule=None, sched_seed: int = 0):
    """Fused K-steps-per-dispatch twin of ``make_hybrid_step``.

    The ``lax.scan`` over ``repro.train.chunked.chunk_over_ring`` runs K
    full ISGD steps without the host in the loop; metrics come back stacked
    (chunk_steps,).  Strategy follows the mesh exactly as in the per-step
    engine:

      * manual shard_map — the scan runs per device; each data shard slices
        its own rows out of its local block of a *relaid-out* sharded
        :class:`DeviceRing` (``ring_arrays`` sharded ``P(axis)``, layout
        documented in ``repro.data.device_ring``);
      * GSPMD — the scan is one global program; ``ring_arrays`` keep the
        *global* row order (``DeviceRing(relayout=False)``) and the in-scan
        ``dynamic_slice`` picks the global batch, which the partitioner
        re-lays-out per the step's constraints.

    Returns ``(init_fn, chunk_fn)``; ``chunk_fn(state, params, ring_arrays,
    j0) -> (state, params, stacked_metrics)`` with ``(state, params)``
    donated.

    ``schedule`` switches to the scheduled contract (``chunk_fn(state,
    params, sched_state, ring_arrays, j0)``) with on-device selection in
    the scan body — see ``make_hybrid_step``; still ONE host dispatch per
    K-step chunk, on both strategies.
    """
    assert lr_fn is not None, "chunked engine needs lr_fn (no per-step host)"
    if schedule is not None:
        return _make_scheduled_hybrid(
            loss_fn, rule, isgd_cfg, mesh, axis=axis,
            inconsistent=inconsistent, lr_fn=lr_fn,
            micro_batches=micro_batches, donate=donate, schedule=schedule,
            sched_seed=sched_seed, chunk_steps=chunk_steps)
    jit_kwargs = dict(donate_argnums=(0, 1)) if donate else {}

    if tensor_axes(mesh, axis):
        init_fn, step_fn = make_step_core(
            loss_fn, rule, isgd_cfg, inconsistent=inconsistent, lr_fn=lr_fn,
            reduce_ctx=LOCAL, micro_batches=micro_batches)
        chunk = chunk_over_ring(step_fn, isgd_cfg.n_batches, chunk_steps)

        def chunk_fn(state, params, ring_arrays, j0):
            return chunk(state, params, ring_arrays,
                         jnp.asarray(j0, jnp.int32))

        return init_fn, jax.jit(chunk_fn, **jit_kwargs)

    init_fn, step_fn = make_step_core(
        loss_fn, rule, isgd_cfg, inconsistent=inconsistent, lr_fn=lr_fn,
        reduce_ctx=AxisReduce(axis), micro_batches=micro_batches)
    device_chunk = chunk_over_ring(step_fn, isgd_cfg.n_batches, chunk_steps)
    sharded = _sharded_over_data(device_chunk, mesh, axis)

    def chunk_fn(state, params, ring_arrays, j0):
        return sharded(state, params, ring_arrays,
                       jnp.asarray(j0, jnp.int32))

    return init_fn, jax.jit(chunk_fn, **jit_kwargs)


# The pure data-parallel engine IS the hybrid engine on a pure-data mesh
# (manual shard_map strategy); the historical names stay as aliases so
# callers that never go tensor-parallel keep reading naturally.
make_data_parallel_step = make_hybrid_step
make_chunked_data_parallel_step = make_chunked_hybrid_step
