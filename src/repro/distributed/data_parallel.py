"""shard_map data-parallel ISGD engine (paper §6, Fig. 8).

Each device computes loss/gradients on its shard of the global batch; the
gradients are all-reduced (``pmean`` over the ``data`` axis) and the control
statistic ψ is the globally reduced batch-mean loss.  Because *both* go
through ``AxisReduce`` inside the per-device function, the ``lax.cond``
accelerate predicate and every trip of the subproblem ``while_loop`` are
computed from replicated values — every device takes the identical branch,
which is the invariant ``core/isgd.py`` documents and this module enforces.

Layout: params and ISGD state (queue, counters, velocity) are replicated
(``P()``); only the batch is sharded (leading dim over ``data``).  This is
the pure data-parallel regime the paper scales (its multi-GPU experiments
replicate the model); the tensor/FSDP-parallel pjit path in ``launch/`` is
complementary and untouched.

``make_data_parallel_step`` mirrors ``train.trainer.make_train_step`` —
same ``(init_fn, step_fn)`` contract, same metrics surface — so the host
loop, examples, and benchmarks can swap engines with one line.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import ISGDConfig, consistent_step, isgd_init, isgd_step
from repro.core.reduce import AxisReduce
from repro.optim.base import UpdateRule
from repro.train.chunked import chunk_over_ring
from repro.train.trainer import make_loss_and_grad, make_step_core


def data_axis_size(mesh: Mesh, axis: str = "data") -> int:
    return mesh.shape[axis]


def batch_sharding(mesh: Mesh, axis: str = "data") -> NamedSharding:
    """NamedSharding for host->device batch transfer (leading dim over data).

    Matches the step's ``in_specs`` so the prefetcher's ``device_put`` lands
    shards exactly where ``shard_map`` consumes them — no resharding copy.
    """
    return NamedSharding(mesh, P(axis))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def make_data_parallel_step(loss_fn: Callable, rule: UpdateRule,
                            isgd_cfg: ISGDConfig, mesh: Mesh, *,
                            axis: str = "data", inconsistent: bool = True,
                            lr_fn: Optional[Callable] = None,
                            micro_batches: int = 1, donate: bool = True):
    """Returns ``(init_fn, step_fn)`` with the ``make_train_step`` contract.

    ``step_fn(state, params, batch, lr=None) -> (state, params, metrics)``
    where ``batch`` leaves carry the *global* batch on their leading dim
    (divisible by the ``data`` axis size) and params/state are replicated.
    All outputs are replicated: grads are pmean'd before the base update and
    ψ before the queue push, so every device computes the same new params.
    """
    lg = make_loss_and_grad(loss_fn, micro_batches)
    rctx = AxisReduce(axis)

    def init_fn(params):
        return isgd_init(rule, isgd_cfg, params)

    def device_step(state, params, batch, lr):
        if inconsistent:
            return isgd_step(rule, isgd_cfg, lg, state, params, batch, lr,
                             reduce_ctx=rctx)
        return consistent_step(rule, lg, state, params, batch, lr,
                               reduce_ctx=rctx)

    # check_rep=False: replication of the outputs follows from the pmean'd
    # grads/ψ, but the rep checker can't see through cond/while_loop bodies.
    sharded = shard_map(device_step, mesh=mesh,
                        in_specs=(P(), P(), P(axis), P()),
                        out_specs=(P(), P(), P()),
                        check_rep=False)

    def step_fn(state, params, batch, lr=None):
        if lr is None:
            from repro.core import control as C
            lr = lr_fn(C.mean(state.queue))
        lr = jnp.asarray(lr, jnp.float32)
        return sharded(state, params, batch, lr)

    jit_kwargs = dict(donate_argnums=(0, 1)) if donate else {}
    return init_fn, jax.jit(step_fn, **jit_kwargs)


def make_chunked_data_parallel_step(loss_fn: Callable, rule: UpdateRule,
                                    isgd_cfg: ISGDConfig, mesh: Mesh, *,
                                    chunk_steps: int, axis: str = "data",
                                    inconsistent: bool = True,
                                    lr_fn: Optional[Callable] = None,
                                    micro_batches: int = 1,
                                    donate: bool = True):
    """Fused K-steps-per-dispatch twin of ``make_data_parallel_step``.

    The ``lax.scan`` over ``repro.train.chunked.chunk_over_ring`` runs
    *inside* the ``shard_map``: each device slices its own batch shard out
    of its local block of the sharded :class:`DeviceRing` (layout documented
    in ``repro.data.device_ring``) and runs K full ISGD steps without the
    host in the loop.  ψ/grads pmean through ``AxisReduce`` exactly as in
    the per-step engine, so cond/while control flow — and therefore the
    scan carry — stays replicated across devices.

    Returns ``(init_fn, chunk_fn)``; ``chunk_fn(state, params, ring_arrays,
    j0) -> (state, params, stacked_metrics)`` with ``ring_arrays`` sharded
    ``P(axis)`` (a sharded ``DeviceRing``'s ``.arrays``), metrics stacked
    (chunk_steps,) and replicated, and ``(state, params)`` donated.
    """
    assert lr_fn is not None, "chunked engine needs lr_fn (no per-step host)"
    init_fn, step_fn = make_step_core(
        loss_fn, rule, isgd_cfg, inconsistent=inconsistent, lr_fn=lr_fn,
        reduce_ctx=AxisReduce(axis), micro_batches=micro_batches)
    device_chunk = chunk_over_ring(step_fn, isgd_cfg.n_batches, chunk_steps)

    # check_rep=False for the same reason as the per-step engine: the rep
    # checker can't see through the cond/while bodies inside the scan.
    sharded = shard_map(device_chunk, mesh=mesh,
                        in_specs=(P(), P(), P(axis), P()),
                        out_specs=(P(), P(), P()),
                        check_rep=False)

    def chunk_fn(state, params, ring_arrays, j0):
        return sharded(state, params, ring_arrays,
                       jnp.asarray(j0, jnp.int32))

    jit_kwargs = dict(donate_argnums=(0, 1)) if donate else {}
    return init_fn, jax.jit(chunk_fn, **jit_kwargs)
