"""Data-parallel ISGD (paper §6): shard_map engine, reduction contexts,
host->device prefetching, and the N-device parity check.

The reduction contexts themselves live in ``repro.core.reduce`` (so ``core``
never imports this package); they are re-exported here because callers that
go distributed pick them together with the engine.

Exports resolve lazily: ``python -m repro.distributed.parity --devices N``
must be able to set ``--xla_force_host_platform_device_count`` before
anything imports jax, and this package runs before the submodule does.
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    "ReduceCtx": "repro.core.reduce",
    "LocalReduce": "repro.core.reduce",
    "AxisReduce": "repro.core.reduce",
    "LOCAL": "repro.core.reduce",
    "make_data_parallel_step": "repro.distributed.data_parallel",
    "make_chunked_data_parallel_step": "repro.distributed.data_parallel",
    "batch_sharding": "repro.distributed.data_parallel",
    "replicated": "repro.distributed.data_parallel",
    "data_axis_size": "repro.distributed.data_parallel",
    "PrefetchSampler": "repro.distributed.prefetch",
    "prefetched": "repro.distributed.prefetch",
    "run_parity": "repro.distributed.parity",
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)


def __dir__():
    return sorted(_EXPORTS)
