"""Distributed ISGD (paper §6): the unified synchronous engine — pure
data parallelism and hybrid DP × TP on a 2-D ``(data, model)`` mesh
(``make_hybrid_step``; ``make_data_parallel_step`` is its pure-data alias)
— reduction contexts, host->device prefetching, the N-device parity checks
(``parity`` and the ψ̄-schedule ``hybrid_parity`` matrix) — and the
asynchronous parameter-server engine (§6.2) in
``repro.distributed.async_ps`` (staleness-bounded workers, server-side SPC
controller, ``w(τ)``-weighted delta folding).

The same synchronous engines run multi-process: ``make_training_mesh``
builds a 3-D ``(pod, data, model)`` mesh over the global device set,
``MeshStrategy`` folds the execution-strategy choice behind one dispatch
point, and ``multihost_parity`` pins N-process × M-device bit-exactness
against the single-host reference — see ``README.md`` in this package for
the mesh contract and the FCPR striping invariant.

The reduction contexts themselves live in ``repro.core.reduce`` (so ``core``
never imports this package); they are re-exported here because callers that
go distributed pick them together with the engine.

Exports resolve lazily: ``python -m repro.distributed.parity --devices N``
must be able to set ``--xla_force_host_platform_device_count`` before
anything imports jax, and this package runs before the submodule does.
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    "ReduceCtx": "repro.core.reduce",
    "LocalReduce": "repro.core.reduce",
    "AxisReduce": "repro.core.reduce",
    "StalenessReduce": "repro.core.reduce",
    "staleness_reduce_from_spec": "repro.core.reduce",
    "LOCAL": "repro.core.reduce",
    "AsyncPSCoordinator": "repro.distributed.async_ps",
    "ParamServer": "repro.distributed.async_ps",
    "records_to_trainlog": "repro.distributed.async_ps",
    "run_async_parity": "repro.distributed.async_ps",
    "make_hybrid_step": "repro.distributed.data_parallel",
    "make_chunked_hybrid_step": "repro.distributed.data_parallel",
    "make_data_parallel_step": "repro.distributed.data_parallel",
    "make_chunked_data_parallel_step": "repro.distributed.data_parallel",
    "run_hybrid_parity": "repro.distributed.hybrid_parity",
    "run_multihost_parity": "repro.distributed.multihost_parity",
    "batch_sharding": "repro.distributed.data_parallel",
    "replicated": "repro.distributed.data_parallel",
    "replicate_to_mesh": "repro.distributed.data_parallel",
    "MeshStrategy": "repro.distributed.data_parallel",
    "mesh_strategy": "repro.distributed.data_parallel",
    "data_axis_size": "repro.distributed.data_parallel",
    "tensor_axes": "repro.distributed.data_parallel",
    "PrefetchSampler": "repro.distributed.prefetch",
    "prefetched": "repro.distributed.prefetch",
    "run_parity": "repro.distributed.parity",
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)


def __dir__():
    return sorted(_EXPORTS)
