"""Hybrid DP × TP parity matrix: the unified engine vs its references.

The headline regression under test (ISSUE 4): the old pjit runner evaluated
``lr_fn(0.0)`` instead of ``lr_fn(ψ̄)``, silently freezing the paper's
loss-driven LR schedule (Alg.1 line 19) on the tensor-parallel path.  Every
leg here drives a **ψ̄-dependent** ``lr_fn`` — if any engine drops the
running loss average from the schedule, its parameter trajectory diverges
from the reference within an epoch and the bit-exact comparison fails
loudly.  A control leg re-runs the reference with the LR frozen at
``lr_fn(0.0)`` and asserts it *differs*, proving the matrix can actually
catch the bug.

Legs (``n`` = available devices; all over ≥ 2 FCPR epochs with the
subproblem firing):

  * ``hybrid(1,1)``   vs per-step ``make_train_step``      — bit-exact
  * ``hybrid(n,1)``   vs data-parallel engine (1-D mesh)   — bit-exact
  * ``hybrid(1,n)``   vs per-step ``make_train_step``      — bit-exact
    (GSPMD strategy; the tiny test params stay replicated, so the global
    program is the reference program)
  * ``chunked(n,1)``  K=4 fused scan vs ``hybrid(n,1)``    — bit-exact
  * ``chunked(1,n)``  K=4 GSPMD scan vs the reference      — bit-exact
  * ``sched-fcpr(n,1)``/``sched-fcpr(1,n)`` — the SAME chunked legs run
    through the ``repro.sched`` scheduler path (on-device FCPR policy
    selection instead of the hard-wired ring walk)        — bit-exact
  * ``sharded-tp``    a (128, 8) weight actually sharded over model=2 vs
    the reference — allclose(tol): cross-shard reductions reassociate f32
  * ``data-parallel`` vs the reference                      — allclose(tol)

Usable two ways (same pattern as ``repro.distributed.parity``):

  * in-process: ``run_hybrid_parity()`` on whatever devices exist;
  * subprocess with a forced device count (the CI acceptance check):

      PYTHONPATH=src python -m repro.distributed.hybrid_parity --devices 8
"""
from __future__ import annotations

import argparse
import os
import sys


def _force_host_devices(n: int) -> None:
    assert "jax" not in sys.modules, "--devices must be set before jax init"
    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip())


def run_hybrid_parity(steps: int = 32, K: int = 4, tol: float = 1e-5,
                      verbose: bool = False) -> dict:
    """Returns {"ok": bool, "devices": int, "legs": {name: report}, ...}."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.core import ISGDConfig
    from repro.data import DeviceRing, FCPRSampler
    from repro.distributed.data_parallel import (make_chunked_hybrid_step,
                                                 make_data_parallel_step,
                                                 make_hybrid_step)
    from repro.launch.mesh import make_data_mesh, make_host_mesh
    from repro.optim import momentum
    from repro.train import make_train_step

    n_dev = len(jax.devices())
    n_batches = 4
    batch_size = 8 * n_dev
    assert steps % K == 0 and steps >= 2 * n_batches, (steps, K, n_batches)

    # dim=6 matches tests/test_chunked.py's canonical problem: XLA:CPU
    # compiles its straight-line and in-scan step bodies to identical
    # float programs there (wider dims pick up 1-ulp fusion differences,
    # which would blur what this matrix pins — schedule drift, not ulps)
    dim = 6
    rng = np.random.RandomState(0)
    xs = rng.randn(batch_size * n_batches, dim).astype(np.float32)
    ys = ((xs @ rng.randn(dim, 1).astype(np.float32)).ravel()
          / np.sqrt(dim)).astype(np.float32)
    ys[:batch_size] += 3.0                      # the under-trained batch
    sampler = FCPRSampler({"x": xs, "y": ys}, batch_size=batch_size, seed=1)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, loss

    params0 = {"w": jnp.zeros((dim,), jnp.float32),
               "b": jnp.zeros((), jnp.float32)}
    rule = momentum(0.9)
    icfg = ISGDConfig(n_batches=n_batches, k_sigma=1.0, stop=3, zeta=0.01)

    def lr_fn(psi_bar):
        # ψ̄-dependent on purpose: freezing ψ̄=0 shifts the whole trajectory
        return jnp.asarray(0.01) + 0.001 * jnp.minimum(psi_bar, 1.0)

    host = [{k: jnp.asarray(v) for k, v in sampler(j).items()}
            for j in range(steps)]

    def drive(step_fn, init_fn, feed):
        p = jax.tree.map(jnp.copy, params0)
        s = init_fn(p)
        ms = []
        for j in range(steps):
            s, p, m = step_fn(s, p, feed(j))
            ms.append(jax.tree.map(np.asarray, m))
        stacked = {k: np.stack([m[k] for m in ms]) for k in ms[0]}
        return s, p, stacked

    def drive_chunked(chunk_fn, init_fn, ring):
        p = jax.tree.map(jnp.copy, params0)
        s = init_fn(p)
        outs = []
        for c in range(steps // K):
            s, p, ms = chunk_fn(s, p, ring.arrays, c * K)
            outs.append(jax.tree.map(np.asarray, ms))
        stacked = {k: np.concatenate([o[k] for o in outs]) for k in outs[0]}
        return s, p, stacked

    def compare(ref, got, exact):
        """(ok, max_param_dev) for (state, params, metrics) triples."""
        r_s, r_p, r_m = ref
        g_s, g_p, g_m = got
        dev = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
                  for a, b in zip(jax.tree.leaves(r_p), jax.tree.leaves(g_p)))
        ok = True
        for key in ("loss", "limit", "psi_bar", "accelerated", "sub_iters"):
            a, b = r_m[key], g_m[key]
            finite = np.isfinite(a) & np.isfinite(b)
            if exact:
                ok &= bool(np.array_equal(a, b))
            else:
                ok &= bool(np.array_equal(a[~finite], b[~finite])
                           if (~finite).any() else True)
                ok &= bool(np.allclose(a[finite], b[finite],
                                       atol=tol, rtol=tol))
        ok &= (dev == 0.0) if exact else (dev <= tol)
        ok &= int(r_s.accel_count) == int(g_s.accel_count) if exact else True
        return ok, dev

    legs = {}

    # reference: the single-device per-step engine
    init_fn, step = make_train_step(loss_fn, rule, icfg, lr_fn=lr_fn,
                                    donate=False)
    ref = drive(step, init_fn, lambda j: host[j])
    assert ref[2]["accelerated"].sum() > 0, "subproblem never fired"

    # control: the bug being tested for — LR frozen at lr_fn(0.0) — must
    # produce a DIFFERENT trajectory, or this matrix couldn't catch it
    finit, fstep = make_train_step(loss_fn, rule, icfg,
                                   lr_fn=lambda _: lr_fn(0.0), donate=False)
    frozen = drive(fstep, finit, lambda j: host[j])
    froze_differs = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(ref[1]), jax.tree.leaves(frozen[1])))
    legs["frozen-lr-differs"] = {"ok": froze_differs, "max_param": None}

    # hybrid (1, 1): bit-exact vs the reference
    mesh11 = make_host_mesh(model=1, devices=[jax.devices()[0]])
    hinit, hstep = make_hybrid_step(loss_fn, rule, icfg, mesh11,
                                    lr_fn=lr_fn, donate=False)
    got = drive(hstep, hinit, lambda j: host[j])
    ok, dev = compare(ref, got, exact=True)
    legs["hybrid(1,1)"] = {"ok": ok, "max_param": dev}

    # data-parallel engine (1-D mesh): allclose vs the reference
    mesh_d = make_data_mesh()
    dinit, dstep = make_data_parallel_step(loss_fn, rule, icfg, mesh_d,
                                           lr_fn=lr_fn, donate=False)
    dp = drive(dstep, dinit, lambda j: host[j])
    ok, dev = compare(ref, dp, exact=n_dev == 1)
    legs["data-parallel"] = {"ok": ok, "max_param": dev}

    # hybrid (n, 1): manual strategy — bit-exact vs data-parallel
    mesh_n1 = make_host_mesh(model=1)
    hinit, hstep = make_hybrid_step(loss_fn, rule, icfg, mesh_n1,
                                    lr_fn=lr_fn, donate=False)
    hy_n1 = drive(hstep, hinit, lambda j: host[j])
    ok, dev = compare(dp, hy_n1, exact=True)
    legs["hybrid(n,1)=dp"] = {"ok": ok, "max_param": dev}

    # hybrid (1, n): GSPMD strategy — bit-exact vs the reference
    mesh_1n = make_host_mesh(model=n_dev)
    hinit, hstep = make_hybrid_step(loss_fn, rule, icfg, mesh_1n,
                                    lr_fn=lr_fn, donate=False)
    got = drive(hstep, hinit, lambda j: host[j])
    ok, dev = compare(ref, got, exact=True)
    legs["hybrid(1,n)"] = {"ok": ok, "max_param": dev}

    # chunked K on (n, 1): fused manual scan — bit-exact vs hybrid(n,1)
    ring = DeviceRing(sampler.epoch_arrays(), batch_size, mesh=mesh_n1)
    cinit, chunk = make_chunked_hybrid_step(loss_fn, rule, icfg, mesh_n1,
                                            chunk_steps=K, lr_fn=lr_fn,
                                            donate=False)
    got = drive_chunked(chunk, cinit, ring)
    ok, dev = compare(hy_n1, got, exact=True)
    legs[f"chunked(n,1)K{K}"] = {"ok": ok, "max_param": dev}

    # chunked K on (1, n): fused GSPMD scan — bit-exact vs the reference
    ring_g = DeviceRing(sampler.epoch_arrays(), batch_size, mesh=mesh_1n,
                        relayout=False)
    cinit, chunk = make_chunked_hybrid_step(loss_fn, rule, icfg, mesh_1n,
                                            chunk_steps=K, lr_fn=lr_fn,
                                            donate=False)
    got = drive_chunked(chunk, cinit, ring_g)
    ok, dev = compare(ref, got, exact=True)
    legs[f"chunked(1,n)K{K}"] = {"ok": ok, "max_param": dev}

    # scheduler path (ISSUE 5): the same chunked legs with batch identity
    # drawn by the FCPR *policy* inside the scan — must stay bit-exact
    from repro.sched import FCPRSchedule
    fcpr = FCPRSchedule()

    def drive_sched_chunked(chunk_fn, init_fn, ring):
        p = jax.tree.map(jnp.copy, params0)
        s = init_fn(p)
        ss = fcpr.init(n_batches)
        outs = []
        for c in range(steps // K):
            s, p, ss, ms = chunk_fn(s, p, ss, ring.arrays, c * K)
            outs.append(jax.tree.map(np.asarray, ms))
        stacked = {k: np.concatenate([o[k] for o in outs]) for k in outs[0]}
        return s, p, stacked

    cinit, chunk = make_chunked_hybrid_step(loss_fn, rule, icfg, mesh_n1,
                                            chunk_steps=K, lr_fn=lr_fn,
                                            donate=False, schedule=fcpr)
    got = drive_sched_chunked(chunk, cinit, ring)
    ok, dev = compare(hy_n1, got, exact=True)
    legs[f"sched-fcpr(n,1)K{K}"] = {"ok": ok, "max_param": dev}

    cinit, chunk = make_chunked_hybrid_step(loss_fn, rule, icfg, mesh_1n,
                                            chunk_steps=K, lr_fn=lr_fn,
                                            donate=False, schedule=fcpr)
    got = drive_sched_chunked(chunk, cinit, ring_g)
    ok, dev = compare(ref, got, exact=True)
    legs[f"sched-fcpr(1,n)K{K}"] = {"ok": ok, "max_param": dev}

    # sharded-tp: a weight genuinely split over model=2 (allclose — the
    # cross-shard loss/grad reductions reassociate f32)
    if n_dev % 2 == 0:
        wdim, out = 128, 8
        xs2 = rng.randn(batch_size * n_batches, wdim).astype(np.float32)
        W = rng.randn(wdim, out).astype(np.float32)
        ys2 = (xs2 @ W / np.sqrt(wdim)).astype(np.float32)
        ys2[:batch_size] += 3.0
        smp2 = FCPRSampler({"x": xs2, "y": ys2}, batch_size=batch_size,
                           seed=1)
        host2 = [{k: jnp.asarray(v) for k, v in smp2(j).items()}
                 for j in range(steps)]

        def loss2(params, batch):
            loss = jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)
            return loss, loss

        p2 = {"w": jnp.zeros((wdim, out), jnp.float32)}
        r_init, r_step = make_train_step(loss2, rule, icfg, lr_fn=lr_fn,
                                         donate=False)

        def drive2(step_fn, init_fn, p0):
            p = jax.tree.map(jnp.copy, p0)
            s = init_fn(p)
            accel = 0
            for j in range(steps):
                s, p, m = step_fn(s, p, host2[j])
                accel += int(np.asarray(m["accelerated"]))
            return s, p, accel

        _, rp, raccel = drive2(r_step, r_init, p2)
        mesh_tp = make_host_mesh(model=2)
        h_init, h_step = make_hybrid_step(loss2, rule, icfg, mesh_tp,
                                          lr_fn=lr_fn, donate=False)
        p2s = jax.device_put(p2, {"w": NamedSharding(mesh_tp,
                                                     P(None, "model"))})
        _, hp, haccel = drive2(h_step, h_init, p2s)
        dev = float(np.max(np.abs(np.asarray(rp["w"]) - np.asarray(hp["w"]))))
        legs["sharded-tp(model=2)"] = {
            "ok": dev <= tol and raccel == haccel and raccel > 0,
            "max_param": dev}

    ok = all(leg["ok"] for leg in legs.values())
    if verbose:
        for name, leg in legs.items():
            print(f"  {name:22s} ok={leg['ok']} max_param={leg['max_param']}")
    return {"ok": ok, "devices": n_dev, "steps": steps, "K": K,
            "accelerations": int(ref[2]["accelerated"].sum()), "legs": legs}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=0,
                    help="force this many XLA host-platform devices "
                         "(0 = use whatever XLA_FLAGS already provides)")
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--chunk-steps", type=int, default=4)
    ap.add_argument("--tol", type=float, default=1e-5)
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    if args.devices:
        _force_host_devices(args.devices)
    r = run_hybrid_parity(steps=args.steps, K=args.chunk_steps, tol=args.tol,
                          verbose=args.verbose)
    bad = [n for n, leg in r["legs"].items() if not leg["ok"]]
    print(f"hybrid-parity devices={r['devices']} steps={r['steps']} "
          f"K={r['K']} accelerations={r['accelerations']} "
          f"legs={len(r['legs'])} failed={bad or 'none'} -> "
          f"{'OK' if r['ok'] else 'FAIL'}")
    if r["accelerations"] == 0:
        print("hybrid-parity WARNING: subproblem never fired")
        return 2
    return 0 if r["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
