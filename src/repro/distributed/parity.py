"""Distributed-parity check: N-device shard_map ISGD vs single-device.

Runs the same FCPR batch sequence through (a) the single-device reference
``isgd_step`` on the full global batch and (b) the ``shard_map``
data-parallel step over every available device, then compares params, ψ̄,
the control limit and the accelerate decision step by step.  The problem is
rigged so the subproblem actually fires (one outlier batch per cycle), so
the comparison covers the cond/while control flow, not just the base update.

Usable two ways:

  * in-process (the tier-1 test calls ``run_parity`` on whatever devices
    exist — 1 on a bare CPU run, 8 under the CI matrix's XLA_FLAGS);
  * as a module that forces a device count before first jax init:

      PYTHONPATH=src python -m repro.distributed.parity --devices 8

    (``--xla_force_host_platform_device_count`` splits the host CPU into
    that many XLA devices; it must be set before jax initializes, which is
    why the flag is handled here rather than by the caller.)

Exit status 0 iff every deviation is within ``--tol`` (default 1e-5).
"""
from __future__ import annotations

import argparse
import os
import sys


def _force_host_devices(n: int) -> None:
    assert "jax" not in sys.modules, "--devices must be set before jax init"
    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip())


def run_parity(steps: int = 20, tol: float = 1e-5, *, batch_size: int = 32,
               n_batches: int = 4, verbose: bool = False) -> dict:
    """Returns {"ok": bool, "devices": int, "max_param": float, ...}."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import ISGDConfig, isgd_init, isgd_step
    from repro.data import FCPRSampler
    from repro.distributed.data_parallel import (batch_sharding,
                                                 make_data_parallel_step)
    from repro.distributed.prefetch import PrefetchSampler
    from repro.launch.mesh import make_data_mesh
    from repro.optim import momentum
    from repro.train.trainer import make_loss_and_grad

    n_dev = len(jax.devices())
    assert batch_size % n_dev == 0, (batch_size, n_dev)

    # Tiny least-squares model with a MEAN loss (per-shard means pmean to the
    # global mean, matching the reference).  One target cluster is an outlier
    # so its batch loss breaches ψ̄ + kσ every cycle after warm-up.
    dim = 8
    rng = np.random.RandomState(0)
    xs = rng.randn(batch_size * n_batches, dim).astype(np.float32)
    ys = ((xs @ rng.randn(dim, 1).astype(np.float32)).ravel()
          / np.sqrt(dim)).astype(np.float32)
    ys[:batch_size] += 3.0                        # the under-trained batch
    sampler = FCPRSampler({"x": xs, "y": ys}, batch_size=batch_size, seed=1)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, loss

    params0 = {"w": jnp.zeros((dim,), jnp.float32),
               "b": jnp.zeros((), jnp.float32)}
    rule = momentum(0.9)
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=1.0, stop=3,
                      zeta=0.01)
    lr = 0.01

    # reference: single-device, full batch, local reduction
    lg = make_loss_and_grad(loss_fn)
    ref_step = jax.jit(
        lambda s, p, b: isgd_step(rule, icfg, lg, s, p, b, lr))
    ref_params = jax.tree.map(jnp.copy, params0)
    ref_state = isgd_init(rule, icfg, ref_params)

    # data-parallel engine over every device, prefetched input pipeline
    mesh = make_data_mesh()
    init_fn, dp_step = make_data_parallel_step(
        loss_fn, rule, icfg, mesh, lr_fn=lambda _: jnp.asarray(lr))
    dp_params = jax.device_put(params0, jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec()))
    dp_state = init_fn(dp_params)
    prefetch = PrefetchSampler(sampler, sharding=batch_sharding(mesh))

    dev = {"param": 0.0, "psi_bar": 0.0, "limit": 0.0}
    accel_mismatch = 0
    n_accel = 0
    for j in range(steps):
        host = {k: jnp.asarray(v) for k, v in sampler(j).items()}
        ref_state, ref_params, mr = ref_step(ref_state, ref_params, host)
        dp_state, dp_params, md = dp_step(dp_state, dp_params, prefetch(j))
        diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                             ref_params, dp_params)
        dev["param"] = max(dev["param"], max(jax.tree.leaves(diffs)))
        dev["psi_bar"] = max(dev["psi_bar"],
                             abs(float(mr["psi_bar"]) - float(md["psi_bar"])))
        lim_r, lim_d = float(mr["limit"]), float(md["limit"])
        if not (lim_r == lim_d == float("inf")):
            dev["limit"] = max(dev["limit"], abs(lim_r - lim_d))
        accel_mismatch += int(bool(mr["accelerated"]) != bool(md["accelerated"]))
        n_accel += int(bool(mr["accelerated"]))
        if verbose:
            print(f"step {j:3d} loss={float(mr['loss']):8.4f} "
                  f"accel={bool(mr['accelerated'])} dparam={dev['param']:.2e}")

    ok = (accel_mismatch == 0 and all(v <= tol for v in dev.values()))
    return {"ok": ok, "devices": n_dev, "steps": steps,
            "accelerations": n_accel, "accel_mismatch": accel_mismatch,
            "max_param": dev["param"], "max_psi_bar": dev["psi_bar"],
            "max_limit": dev["limit"]}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=0,
                    help="force this many XLA host-platform devices "
                         "(0 = use whatever XLA_FLAGS already provides)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--tol", type=float, default=1e-5)
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    if args.devices:
        _force_host_devices(args.devices)
    r = run_parity(steps=args.steps, tol=args.tol, verbose=args.verbose)
    print(f"parity devices={r['devices']} steps={r['steps']} "
          f"accelerations={r['accelerations']} "
          f"accel_mismatch={r['accel_mismatch']} "
          f"max_param={r['max_param']:.3e} "
          f"max_psi_bar={r['max_psi_bar']:.3e} "
          f"max_limit={r['max_limit']:.3e} -> "
          f"{'OK' if r['ok'] else 'FAIL'}")
    if r["accelerations"] == 0:
        print("parity WARNING: subproblem never fired; cond path untested")
        return 2
    return 0 if r["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
