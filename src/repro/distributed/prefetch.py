"""Double-buffered host->device prefetching sampler.

``jax.device_put`` is asynchronous: it returns immediately with arrays whose
H2D copies complete in the background.  Wrapping an FCPR-style sampler in
``PrefetchSampler`` therefore overlaps the *next* batch's transfer (and the
numpy slicing that feeds it) with the *current* step's compute — the classic
double-buffer that hides H2D latency on the data-parallel engine, where the
batch is the only per-step transfer (params/state live on device).

The wrapper preserves the sampler protocol (``__call__(j)``, ``n_batches``,
``batch_size``, ``batch_index``) and FCPR's fixed-cycle determinism: batch j
is bit-identical to ``sampler(j)``, merely staged early.  Random access is
still supported (a miss falls back to a synchronous put), but sequential
iteration is the fast path.
"""
from __future__ import annotations

from typing import Optional

import jax


class PrefetchSampler:
    def __init__(self, sampler, sharding=None, depth: int = 2):
        """``sharding``: optional ``jax.sharding.Sharding`` — or a dict of
        them keyed like the batch (``launch.shardings
        .data_parallel_shardings``) — for the staged batches, so shards
        land on their consuming devices.  ``depth`` >= 1 is how many
        batches may be in flight; 2 = classic double buffering."""
        assert depth >= 1
        self.sampler = sampler
        self.n_batches = sampler.n_batches
        self.batch_size = sampler.batch_size
        self._sharding = sharding
        self._depth = depth
        self._staged: dict[int, dict] = {}

    def batch_index(self, j: int) -> int:
        return self.sampler.batch_index(j)

    def _put(self, j: int) -> None:
        host = self.sampler(j)
        sh = self._sharding
        dev = {k: jax.device_put(v, sh[k] if isinstance(sh, dict) else sh)
               for k, v in host.items()}
        self._staged[j] = dev

    def __call__(self, j: int) -> dict:
        if j not in self._staged:          # cold start or random access
            self._put(j)
        # enqueue the lookahead window before handing back batch j, so its
        # transfers overlap the step that consumes j
        for ahead in range(j + 1, j + self._depth):
            if ahead not in self._staged:
                self._put(ahead)
        batch = self._staged.pop(j)
        # drop anything stale (random access moved the cursor backwards)
        for k in [k for k in self._staged if k <= j]:
            del self._staged[k]
        return batch


def prefetched(sampler, mesh=None, *, axis: str = "data", depth: int = 2,
               sharding: Optional[object] = None) -> PrefetchSampler:
    """Convenience: wrap ``sampler`` with the data-parallel batch sharding
    for ``mesh`` (or an explicit ``sharding``)."""
    if sharding is None and mesh is not None:
        from repro.distributed.data_parallel import batch_sharding
        sharding = batch_sharding(mesh, axis)
    return PrefetchSampler(sampler, sharding=sharding, depth=depth)
