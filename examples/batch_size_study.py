"""Batch-size vs time-domain convergence (paper §4.5, Fig.5/Fig.8).

Plots (as text) the Eq.24 predicted training-time curve for two system
configurations and reports the measured optimum on this machine.

  PYTHONPATH=src python examples/batch_size_study.py
"""
import numpy as np

from repro.core import batch_model as bm


def ascii_curve(xs, ys, width=60, label=""):
    ys = np.asarray(ys, float)
    finite = np.isfinite(ys)
    lo, hi = ys[finite].min(), ys[finite].max()
    print(f"\n{label}  (min={lo:.1f}s at n_b={int(xs[np.nanargmin(ys)])})")
    for x, y in zip(xs, ys):
        if not np.isfinite(y):
            bar = "∞"
        else:
            bar = "#" * max(1, int((y - lo) / max(hi - lo, 1e-9) * width))
        print(f"  n_b={int(x):5d} |{bar}")


def main():
    cand = np.arange(100, 3100, 200)
    # System 1: 4x TITAN X-class (paper's rig): ~3000 img/s, 0.1 s sync
    t1 = bm.predicted_time_to_loss(cand, psi=0.02, c1=3000.0, c2=0.1)
    # System 2: faster interconnect-bound system: 6000 img/s, 0.25 s sync
    t2 = bm.predicted_time_to_loss(cand, psi=0.02, c1=6000.0, c2=0.25)
    ascii_curve(cand, t1, label="System 1 (C1=3000 img/s, C2=0.1s)")
    ascii_curve(cand, t2, label="System 2 (C1=6000 img/s, C2=0.25s)")
    b1 = bm.optimal_batch_size(0.02, 3000.0, 0.1)
    b2 = bm.optimal_batch_size(0.02, 6000.0, 0.25)
    print(f"\noptimal batch: system1={b1}, system2={b2} "
          f"(faster system ⇒ larger batch: {b2 >= b1})")


if __name__ == "__main__":
    main()
