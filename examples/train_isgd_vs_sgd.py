"""End-to-end driver (deliverable b): train a transformer LM with ISGD vs
SGD on synthetic token data — the full production path (model zoo config,
FCPR pipeline, ISGD controller, checkpointing) at a CPU-feasible scale.

Default is a ~10M-param internlm2-family model for speed; pass --params 100
to train a ~100M-param variant for a few hundred steps (the deliverable's
"train ~100M model" configuration — expect a few hours on this 1-core CPU
container; on a real TPU slice this is minutes).

``--devices N`` (N > 1) runs both legs on the shard_map data-parallel ISGD
engine (repro.distributed): the host CPU is split into N XLA devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the flag is
injected here BEFORE jax initializes, which is why it is parsed ahead of
the normal argparse pass.  The global --batch must be a multiple of the
data-axis size (each data shard takes batch/data samples); inputs ride the
double-buffered prefetcher.  (Setting XLA_FLAGS yourself works too and
takes precedence; --devices is a convenience for single-host smoke runs.)

``--model-parallel M`` (with ``--devices N``, M dividing N) switches both
legs to the hybrid DP × TP engine on a 2-D ``(data=N/M, model=M)`` mesh:
params sharded over 'model' (launch/shardings.py), batch over 'data', the
same ``make_step_core`` body — the loss-driven LR keeps its ψ̄ read.

``--chunk-steps K`` switches both legs to the fused engine (ISSUE 2): the
permuted epoch lives on device in a ``DeviceRing`` and each host dispatch
runs K full ISGD steps inside a ``lax.scan``, bit-exact with the per-step
engine; ``--device-ring`` keeps the per-step engine but serves batches from
the ring (one upload instead of one transfer per step).

``--async-ps`` switches both legs to the asynchronous parameter-server
engine (paper §6.2): ``--workers`` threads over per-worker FCPR shards push
staleness-weighted deltas to a server running the SPC controller on
globally consistent statistics; ``--max-staleness`` bounds worker drift
(0 = lockstep; with 1 worker, bit-exact with the per-step engine) and
``--staleness-decay`` picks w(τ).

``--schedule fcpr|loss-prop|rank`` (ISSUE 5, ``repro.sched``) swaps the
fixed FCPR cycle for a batch-*selection* policy on both legs: selection
runs inside the jitted step over the device ring (``fcpr`` is bit-exact
with the default path; ``loss-prop`` demos loss-aware selection — compare
its visit counts and ψ̄ trace against a plain run).  Composes with
``--chunk-steps``/``--devices``/``--model-parallel``, not ``--async-ps``.

  PYTHONPATH=src python examples/train_isgd_vs_sgd.py --steps 200
  PYTHONPATH=src python examples/train_isgd_vs_sgd.py --params 100 --steps 300
  PYTHONPATH=src python examples/train_isgd_vs_sgd.py --devices 8 --batch 16
  PYTHONPATH=src python examples/train_isgd_vs_sgd.py --chunk-steps 20
  PYTHONPATH=src python examples/train_isgd_vs_sgd.py --schedule loss-prop
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time


def _inject_device_count(argv=None) -> None:
    """Handle --devices before first jax import (XLA reads the flag at
    backend init; too late once jax device state exists)."""
    argv = sys.argv if argv is None else argv
    assert "jax" not in sys.modules
    for i, a in enumerate(argv):
        n = 0
        if a.startswith("--devices="):
            n = int(a.split("=", 1)[1])
        elif a == "--devices" and i + 1 < len(argv):
            n = int(argv[i + 1])
        if n > 1 and "--xla_force_host_platform_device_count" not in \
                os.environ.get("XLA_FLAGS", ""):
            os.environ["XLA_FLAGS"] = (
                os.environ.get("XLA_FLAGS", "") +
                f" --xla_force_host_platform_device_count={n}").strip()


_inject_device_count()

import jax                    # noqa: E402
import jax.numpy as jnp       # noqa: E402
import numpy as np            # noqa: E402

from repro.configs import get_config                       # noqa: E402
from repro.core import ISGDConfig                          # noqa: E402
from repro.data import (DeviceRing, FCPRSampler,           # noqa: E402
                        make_lm_tokens, ring_or_prefetch)
from repro.distributed import (                            # noqa: E402
    make_chunked_hybrid_step, make_hybrid_step, prefetched, tensor_axes)
from repro.launch.mesh import make_data_mesh, make_host_mesh  # noqa: E402
from repro.models import build_model                       # noqa: E402
from repro.optim import momentum                           # noqa: E402
from repro.train import (checkpoints,                      # noqa: E402
                         make_chunked_train_step, make_train_step)
from repro.train.trainer import TrainLog                   # noqa: E402


def model_for(params_m: int):
    base = get_config("internlm2_1_8b")
    if params_m >= 100:
        # ~100M: 12 layers, d=512, vocab 8k
        return dataclasses.replace(base, num_layers=12, d_model=512,
                                   num_heads=8, num_kv_heads=4, head_dim=64,
                                   d_ff=2048, vocab_size=8192)
    return dataclasses.replace(base, num_layers=4, d_model=256, num_heads=4,
                               num_kv_heads=2, head_dim=64, d_ff=1024,
                               vocab_size=4096)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--params", type=int, default=10, help="target M params")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--devices", type=int, default=1,
                    help="split the host into N XLA devices and use the "
                         "data-parallel engine (see module docstring)")
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="with --devices N: hybrid DP x TP engine, M "
                         "devices on the 'model' axis (M must divide N)")
    ap.add_argument("--chunk-steps", type=int, default=1,
                    help="K>1 = fused engine: K steps per dispatch over the "
                         "device-resident FCPR ring (steps rounded up to "
                         "whole chunks); bit-exact with per-step")
    ap.add_argument("--device-ring", action="store_true",
                    help="feed the per-step engine from the device ring "
                         "(implied by --chunk-steps > 1)")
    ap.add_argument("--async-ps", action="store_true",
                    help="run both legs on the async parameter-server "
                         "engine (repro.distributed.async_ps)")
    ap.add_argument("--workers", type=int, default=2,
                    help="async-ps: worker threads")
    ap.add_argument("--max-staleness", type=int, default=0,
                    help="async-ps: SSP staleness bound (0 = lockstep)")
    ap.add_argument("--staleness-decay", default="inverse",
                    help="async-ps: w(tau) family[:alpha]")
    ap.add_argument("--schedule", default=None,
                    help="batch-selection policy (repro.sched): fcpr | "
                         "loss-prop | rank (family:k=v,... for options); "
                         "selection runs on device over the ring")
    ap.add_argument("--ckpt", default="experiments/e2e_lm.npz")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    if args.model_parallel > 1:
        if args.async_ps:
            raise SystemExit("--model-parallel does not compose with "
                             "--async-ps (workers are host threads)")
        mesh = make_host_mesh(model=args.model_parallel)
    elif args.devices > 1:
        mesh = make_data_mesh()
    else:
        mesh = None
    if mesh is not None and args.batch % mesh.shape["data"]:
        raise SystemExit(f"--batch {args.batch} must be a multiple of the "
                         f"{mesh.shape['data']} 'data'-axis devices (it is "
                         f"split across them)")

    cfg = model_for(args.params)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params0 = model.init(key, max_seq=args.seq)
    n = sum(x.size for x in jax.tree.leaves(params0))
    print(f"model: {cfg.name}-derived, {n/1e6:.1f}M params, "
          f"{n_dev} device(s)")

    data = make_lm_tokens(0, n_seqs=64, seq_len=args.seq, vocab=cfg.vocab_size)
    sampler = FCPRSampler(data, batch_size=args.batch, seed=1)
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=2.0, stop=3)
    tp = mesh is not None and bool(tensor_axes(mesh))
    if tp:
        from repro.launch import shardings as SH
        params0, _ = SH.hybrid_params_placement(mesh, params0)

    schedule = None
    if args.schedule is not None:
        if args.async_ps:
            raise SystemExit("--schedule does not compose with --async-ps "
                             "(workers own fixed FCPR stripes)")
        from repro.sched import schedule_from_spec
        schedule = schedule_from_spec(args.schedule)
        print(f"schedule: {schedule}")

    K = args.chunk_steps
    ring = None
    if K > 1:
        args.steps = -(-args.steps // K) * K         # whole chunks
    if K > 1 or schedule is not None:
        # one epoch upload serves both legs (identical permuted data);
        # scheduled engines select on device, so the ring is mandatory
        ring = DeviceRing(sampler.epoch_arrays(), args.batch, mesh=mesh,
                          relayout=not tp)
    results = {}
    for name, inconsistent in (("sgd", False), ("isgd", True)):
        lr_fn = lambda _: jnp.asarray(args.lr)       # noqa: E731
        params = jax.tree.map(jnp.copy, params0)
        log = TrainLog()
        if args.async_ps:
            from repro.distributed import (AsyncPSCoordinator,
                                           records_to_trainlog,
                                           staleness_reduce_from_spec)
            if sampler.n_batches % args.workers:
                raise SystemExit(
                    f"n_batches={sampler.n_batches} must be a multiple of "
                    f"--workers {args.workers} (per-worker FCPR shards)")
            coord = AsyncPSCoordinator(
                model.loss_fn, momentum(0.9), icfg, workers=args.workers,
                max_staleness=args.max_staleness, lr_fn=lr_fn,
                reduce_ctx=staleness_reduce_from_spec(args.staleness_decay),
                inconsistent=inconsistent)
            params, state, records = coord.run(params, sampler, args.steps)
            args.steps = len(records)        # run() rounds up to whole rounds
            log = records_to_trainlog(records)
            taus = [r["tau"] for r in records]
            print(f"[{name}] async-ps workers={args.workers} "
                  f"max_staleness={args.max_staleness} "
                  f"mean_tau={sum(taus)/len(taus):.2f} max_tau={max(taus)} "
                  f"final loss={log.losses[-1]:.4f}")
        elif schedule is not None:
            # scheduled engines (repro.sched): selection inside the jit
            if K > 1:
                if mesh is not None:
                    init_fn, sfn = make_chunked_hybrid_step(
                        model.loss_fn, momentum(0.9), icfg, mesh,
                        chunk_steps=K, inconsistent=inconsistent,
                        lr_fn=lr_fn, schedule=schedule)
                else:
                    init_fn, sfn = make_chunked_train_step(
                        model.loss_fn, momentum(0.9), icfg, chunk_steps=K,
                        inconsistent=inconsistent, lr_fn=lr_fn,
                        schedule=schedule)
            elif mesh is not None:
                init_fn, sfn = make_hybrid_step(
                    model.loss_fn, momentum(0.9), icfg, mesh,
                    inconsistent=inconsistent, lr_fn=lr_fn,
                    schedule=schedule)
            else:
                from repro.train import make_scheduled_train_step
                init_fn, sfn = make_scheduled_train_step(
                    model.loss_fn, momentum(0.9), icfg, schedule,
                    inconsistent=inconsistent, lr_fn=lr_fn)
            state = init_fn(params)
            sched_state = schedule.init(icfg.n_batches)
            visits = np.zeros(icfg.n_batches, np.int64)
            t0 = time.perf_counter()
            if K > 1:
                for c in range(args.steps // K):
                    state, params, sched_state, ms = sfn(
                        state, params, sched_state, ring.arrays, c * K)
                    log.extend(ms, time.perf_counter() - t0)
                    visits += np.bincount(np.asarray(ms["batch_idx"]),
                                          minlength=icfg.n_batches)
                    print(f"[{name}] step {(c+1)*K:4d} "
                          f"loss={log.losses[-1]:.4f} "
                          f"ψ̄={log.psi_bar[-1]:.4f} "
                          f"accel={log.accelerated[-1]}")
            else:
                for j in range(args.steps):
                    state, params, sched_state, m = sfn(
                        state, params, sched_state, ring.arrays, j)
                    log.append(jax.tree.map(np.asarray, m),
                               time.perf_counter() - t0)
                    visits[int(m["batch_idx"])] += 1
                    if (j + 1) % 20 == 0:
                        print(f"[{name}] step {j+1:4d} "
                              f"loss={log.losses[-1]:.4f} "
                              f"ψ̄={log.psi_bar[-1]:.4f} "
                              f"accel={log.accelerated[-1]}")
            print(f"[{name}] schedule visits per batch: {visits.tolist()}")
        elif K > 1:
            # fused engine: K steps per dispatch, metrics fetched per chunk
            if mesh is not None:
                init_fn, chunk_fn = make_chunked_hybrid_step(
                    model.loss_fn, momentum(0.9), icfg, mesh,
                    chunk_steps=K, inconsistent=inconsistent, lr_fn=lr_fn)
            else:
                init_fn, chunk_fn = make_chunked_train_step(
                    model.loss_fn, momentum(0.9), icfg,
                    chunk_steps=K, inconsistent=inconsistent, lr_fn=lr_fn)
            state = init_fn(params)
            t0 = time.perf_counter()
            for c in range(args.steps // K):
                state, params, ms = chunk_fn(state, params, ring.arrays,
                                             c * K)
                log.extend(ms, time.perf_counter() - t0)
                print(f"[{name}] step {(c+1)*K:4d} loss={log.losses[-1]:.4f} "
                      f"ψ̄={log.psi_bar[-1]:.4f} accel={log.accelerated[-1]}")
        else:
            if mesh is not None:
                init_fn, step_fn = make_hybrid_step(
                    model.loss_fn, momentum(0.9), icfg, mesh,
                    inconsistent=inconsistent, lr_fn=lr_fn)
                feed = ring_or_prefetch(sampler, mesh=mesh,
                                        relayout=not tp) \
                    if args.device_ring else prefetched(sampler, mesh)
            else:
                init_fn, step_fn = make_train_step(
                    model.loss_fn, momentum(0.9), icfg,
                    inconsistent=inconsistent, lr_fn=lr_fn)
                feed = ring_or_prefetch(sampler) if args.device_ring else \
                    (lambda j: {k: jnp.asarray(v)        # noqa: E731
                                for k, v in sampler(j).items()})
            state = init_fn(params)
            t0 = time.perf_counter()
            for j in range(args.steps):
                state, params, m = step_fn(state, params, feed(j))
                log.append(jax.tree.map(np.asarray, m),
                           time.perf_counter() - t0)
                if (j + 1) % 20 == 0:
                    print(f"[{name}] step {j+1:4d} "
                          f"loss={log.losses[-1]:.4f} "
                          f"ψ̄={log.psi_bar[-1]:.4f} "
                          f"accel={log.accelerated[-1]}")
        results[name] = log
        if name == "isgd":
            checkpoints.save(args.ckpt, params,
                             extra={"steps": args.steps, "arch": cfg.name})
            print(f"checkpoint -> {args.ckpt}")

    n_b = sampler.n_batches
    print("\n=== ISGD vs SGD (final epoch mean ψ̄) ===")
    for name, log in results.items():
        print(f"  {name:5s}: ψ̄={np.mean(log.psi_bar[-n_b:]):.4f} "
              f"wall={log.wall[-1]:.1f}s accel={sum(log.accelerated)}")


if __name__ == "__main__":
    main()
