"""Quickstart: train a small CNN with Inconsistent SGD in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax

from repro.configs import CIFAR_QUICK
from repro.core import ISGDConfig
from repro.data import FCPRSampler, make_classification
from repro.models import cnn_accuracy, cnn_loss_fn, init_cnn
from repro.optim import momentum
from repro.train import train

# 1. data: synthetic CIFAR-like classification, FCPR-sampled (paper §3.4)
data = make_classification(seed=0, n=2000, image_size=16, channels=3,
                           num_classes=10, noise=0.7, class_skew=0.3,
                           class_spread=2.0)
sampler = FCPRSampler(data, batch_size=100, seed=1, shuffle_quality=0.5)

# 2. model: the paper's CIFAR-quick CNN (loss = cross entropy + weight decay)
cfg = dataclasses.replace(CIFAR_QUICK, image_size=16)
params = init_cnn(jax.random.PRNGKey(0), cfg)
loss_fn = lambda p, b: cnn_loss_fn(p, cfg, b)   # noqa: E731

# 3. ISGD: momentum base rule + inconsistent training.
#    k_sigma: control-limit multiplier; stop: Alg.2 early-stopping bound.
isgd = ISGDConfig(n_batches=sampler.n_batches, k_sigma=2.0, stop=3)

params, state, log, _ = train(
    params, loss_fn, momentum(0.9), sampler,
    steps=8 * sampler.n_batches, lr=0.05,
    inconsistent=True, isgd_cfg=isgd, log_every=20)

test = make_classification(seed=99, n=500, image_size=16, channels=3,
                           num_classes=10, noise=0.7)
import jax.numpy as jnp
acc = cnn_accuracy(params, cfg, jnp.asarray(test["images"]),
                   jnp.asarray(test["labels"]))
print(f"\nfinal ψ̄={log.psi_bar[-1]:.4f}  test acc={acc:.3f}  "
      f"batches accelerated={int(state.accel_count)} "
      f"(extra subproblem iterations: {int(state.sub_iters)})")
