"""Batched serving demo: prefill + KV-cache decode across architecture
families (dense GQA / MLA / MoE / SSM / hybrid / sliding-window).

  PYTHONPATH=src python examples/serve_demo.py [--archs mamba2-2.7b,...]

``--continuous`` runs the same workload through the continuous-batching
scheduler instead (mixed budgets on fewer slots than requests — requests
join and leave between decode steps; see src/repro/serve/README.md).
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ContinuousScheduler, Request, ServeEngine

DEFAULT = "internlm2-1.8b,deepseek-v2-lite-16b,mamba2-2.7b,gemma3-12b"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default=DEFAULT)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--continuous", action="store_true",
                    help="serve request-by-request through the slot "
                         "scheduler (2 slots, varied budgets)")
    args = ap.parse_args()

    rng = np.random.RandomState(0)
    for arch in args.archs.split(","):
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0), max_seq=64)
        prompts = rng.randint(0, cfg.vocab_size,
                              size=(args.batch, args.prompt_len)).astype(np.int32)
        if args.continuous:
            sched = ContinuousScheduler(model, params, max_batch=2,
                                        max_seq=64)
            reqs = [Request(rid=i, prompt=p,
                            max_new_tokens=max(1, args.steps // (1 + i % 2)))
                    for i, p in enumerate(prompts)]
            t0 = time.perf_counter()
            comps = sched.run(reqs)
            dt = time.perf_counter() - t0
            n = sum(len(c.tokens) for c in comps)
            print(f"{arch:24s} [{cfg.family:7s}] {len(reqs)} reqs / {n} "
                  f"tokens in {dt:5.1f}s ({n/dt:5.1f} tok/s)  "
                  f"sample: {np.asarray(comps[0].tokens[:6])}")
            continue
        engine = ServeEngine(model, params, max_seq=64)
        t0 = time.perf_counter()
        out = engine.generate(prompts, steps=args.steps)
        dt = time.perf_counter() - t0
        print(f"{arch:24s} [{cfg.family:7s}] {args.batch}x{args.steps} tokens "
              f"in {dt:5.1f}s ({args.batch*args.steps/dt:5.1f} tok/s)  "
              f"sample: {out[0, args.prompt_len:args.prompt_len+6]}")


if __name__ == "__main__":
    main()
