"""Mixed-precision contract: bf16 step bodies, f32 ψ statistics.

The SPC control limit (ψ̄ + kσ over the loss queue) and the ψ̄-driven LR
schedule are the paper's decision machinery — if the loss scalar is
computed in bf16, ``control.push``'s f32 cast can't restore the lost
mantissa and the whole acceleration schedule quantises.  The contract
(``models/transformer.lm_loss_fn`` + ``train/trainer.make_loss_and_grad``):
the loss head computes in f32 and the trainer defensively upcasts, so the
queue and every loss metric stay genuinely f32 no matter what dtype the
step body runs in.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ZOO_MODELS, zoo_config
from repro.core import ISGDConfig
from repro.models import build_model
from repro.optim import momentum
from repro.train import make_train_step

KEY = jax.random.PRNGKey(0)
ICFG = ISGDConfig(n_batches=2, k_sigma=1.0, stop=2, zeta=0.01)


def _lr_fn(psi_bar):
    return jnp.asarray(0.05) + 0.0 * psi_bar


def _assert_f32_stats(state, metrics):
    assert state.queue.buf.dtype == jnp.float32
    assert state.queue.total.dtype == jnp.float32
    assert state.queue.total_sq.dtype == jnp.float32
    assert metrics["loss"].dtype == jnp.float32
    assert metrics["psi_bar"].dtype == jnp.float32


def test_queue_stays_f32_under_bf16_loss_fn():
    """A loss_fn whose scalars come back bf16 (the regression: a bf16 step
    body leaking its compute dtype into the loss head) must still produce
    f32 queue statistics and f32 loss metrics."""
    def loss_fn(params, batch):
        pred = batch["x"].astype(jnp.bfloat16) @ params["w"]
        loss = jnp.mean(
            (pred - batch["y"].astype(jnp.bfloat16)) ** 2)    # bf16 scalar
        assert loss.dtype == jnp.bfloat16
        return loss, loss

    rng = np.random.RandomState(0)
    params = {"w": jnp.asarray(rng.randn(4, 2), jnp.bfloat16)}
    batch = {"x": jnp.asarray(rng.randn(8, 4), jnp.float32),
             "y": jnp.asarray(rng.randn(8, 2), jnp.float32)}
    init_fn, step = make_train_step(loss_fn, momentum(0.9), ICFG,
                                    lr_fn=_lr_fn, donate=False)
    state = init_fn(params)
    for _ in range(3):
        state, params, m = step(state, params, batch)
    _assert_f32_stats(state, m)
    assert bool(np.isfinite(np.asarray(m["loss"])))


@pytest.mark.parametrize("name", ZOO_MODELS)
def test_zoo_bf16_policy_keeps_f32_loss(name):
    """The default zoo build is bf16 params / f32 loss head: params carry
    bf16 leaves, yet the loss scalar is f32 *at the source* (not merely
    upcast after the precision is gone) and the SPC queue stays f32."""
    cfg = zoo_config(name, "tiny")
    model = build_model(cfg)
    params = model.init(KEY, max_seq=32)
    assert any(a.dtype == jnp.bfloat16 for a in jax.tree.leaves(params))

    toks = jnp.asarray(
        np.random.RandomState(1).randint(0, cfg.vocab_size, size=(4, 32)),
        jnp.int32)
    loss, aux = model.loss_fn(params, {"tokens": toks})
    assert loss.dtype == jnp.float32
    assert aux.dtype == jnp.float32

    init_fn, step = make_train_step(model.loss_fn, momentum(0.9), ICFG,
                                    lr_fn=_lr_fn, donate=False)
    state = init_fn(params)
    state, params, m = step(state, params, {"tokens": toks})
    _assert_f32_stats(state, m)
