"""Async parameter-server ISGD engine (ISSUE 3 acceptance).

Pinned invariants:

  * **bit-exact parity anchor** — with 1 worker and ``max_staleness=0`` the
    async engine reproduces the synchronous per-step engine EXACTLY
    (losses, control limits, accelerate decisions, sub-iteration counts,
    ψ̄/σ, final params, final counters) over 8 FCPR epochs, driven by a
    ψ̄-dependent ``lr_fn`` so the one-step queue lag is on the tested path;
  * **staleness semantics** — ``w(0) = 1`` for every decay family; the SSP
    gate at ``max_staleness=0`` forces lockstep rounds (the synchronous
    schedule) and version staleness τ never exceeds ``(2s+1)·(N−1)``; a
    τ > 0 push is folded in as ``old + w(τ)·(final − snapshot)``;
  * **convergence** — 2 stale workers reach the synchronous engine's final
    loss (within slack) on the lenet-8x8 config.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ISGDConfig
from repro.core.reduce import StalenessReduce, staleness_reduce_from_spec
from repro.data import FCPRSampler, make_classification
from repro.distributed.async_ps import (AsyncPSCoordinator, ParamServer,
                                        ShardedFeed, StalenessGate,
                                        records_to_trainlog,
                                        run_async_parity)
from repro.optim import momentum
from repro.train import make_train_step


# ---------------------------------------------------------------------------
# acceptance anchor: bit-exact with the synchronous per-step engine
# ---------------------------------------------------------------------------
def test_async_1worker_staleness0_bit_exact_with_sync():
    """steps=32 over n_batches=4 ⇒ 8 FCPR epochs (≥ 4 required), ψ̄-driven
    LR, subproblem firing — and zero deviation anywhere."""
    r = run_async_parity(steps=32, workers=1, max_staleness=0)
    assert r["mode"] == "bitexact"
    assert r["ok"], r
    assert r["accelerations"] > 0, "subproblem never fired; cond path untested"
    assert r["metric_mismatches"] == 0 and r["max_param_dev"] == 0.0
    assert r["max_tau"] == 0


def test_async_multiworker_lockstep_and_convergence_smoke():
    """max_staleness=0 with racing workers: still lockstep rounds, τ ≤ N−1,
    and the final loss tracks the synchronous run on the rigged problem."""
    r = run_async_parity(steps=64, workers=2, max_staleness=0, tol=0.3)
    assert r["mode"] == "convergence"
    assert r["ok"], r
    assert r["max_tau"] <= 1


# ---------------------------------------------------------------------------
# staleness weights + server fold
# ---------------------------------------------------------------------------
def test_staleness_weight_families():
    inv = StalenessReduce(decay="inverse", alpha=1.0)
    assert float(inv.weight(0)) == 1.0
    np.testing.assert_allclose(float(inv.weight(1)), 0.5)
    np.testing.assert_allclose(float(inv.weight(3)), 0.25)
    exp = StalenessReduce(decay="exp", alpha=0.5)
    assert float(exp.weight(0)) == 1.0
    np.testing.assert_allclose(float(exp.weight(2)), np.exp(-1.0), rtol=1e-6)
    none = StalenessReduce(decay="none")
    assert float(none.weight(7)) == 1.0
    with pytest.raises(ValueError):
        StalenessReduce(decay="bogus").weight(1)


def test_staleness_reduce_spec_parser():
    assert staleness_reduce_from_spec("inverse") == StalenessReduce()
    assert staleness_reduce_from_spec("exp:0.5") == StalenessReduce(
        decay="exp", alpha=0.5)
    assert staleness_reduce_from_spec("none") == StalenessReduce(decay="none")
    with pytest.raises(ValueError):
        staleness_reduce_from_spec("bogus")


def test_server_observe_runs_spc_on_canonical_queue():
    """Two racing workers' losses land in ONE queue: the second observe sees
    statistics that include the first worker's loss — the globally
    consistent undertrained-batch detection the subsystem exists for."""
    icfg = ISGDConfig(n_batches=2, k_sigma=0.5)
    srv = ParamServer({"w": jnp.zeros(2)}, (), icfg)
    d1 = srv.observe(jnp.asarray(1.0, jnp.float32))
    assert not d1.accelerated                      # warm-up: limit = +inf
    assert float(d1.limit) == float("inf")
    d2 = srv.observe(jnp.asarray(2.0, jnp.float32))    # queue now full
    np.testing.assert_allclose(float(d2.psi_bar), 1.5)
    assert np.isfinite(float(d2.limit))
    # an outlier against the now-full queue must trip the limit
    d3 = srv.observe(jnp.asarray(50.0, jnp.float32))
    assert d3.accelerated


def test_server_staleness_weighted_fold():
    icfg = ISGDConfig(n_batches=4, k_sigma=1.0)
    p0 = {"w": jnp.asarray([1.0, 2.0], jnp.float32)}
    srv = ParamServer(p0, (), icfg,
                      reduce_ctx=StalenessReduce(decay="inverse", alpha=1.0))
    snap_a = srv.pull()
    snap_b = srv.pull()
    fin_a = {"w": jnp.asarray([2.0, 2.0], jnp.float32)}
    tau_a = srv.push(snap_a, fin_a, (), worker=0, metrics={"loss": 0.0})
    assert tau_a == 0
    np.testing.assert_array_equal(np.asarray(srv.params["w"]), [2.0, 2.0])
    # B pushed one version late: old + w(1)·(final − snapshot), w(1) = 1/2
    fin_b = {"w": jnp.asarray([5.0, 0.0], jnp.float32)}
    tau_b = srv.push(snap_b, fin_b, (), worker=1, metrics={"loss": 0.0})
    assert tau_b == 1
    np.testing.assert_allclose(np.asarray(srv.params["w"]),
                               [2.0 + 0.5 * (5.0 - 1.0),
                                2.0 + 0.5 * (0.0 - 2.0)])
    assert int(srv.isgd_state().iter) == 2


# ---------------------------------------------------------------------------
# bounded-staleness gate
# ---------------------------------------------------------------------------
def test_gate_permits_predicate():
    g0 = StalenessGate(2, max_staleness=0)
    assert g0.permits(0, 0) and not g0.permits(1, 0) and g0.permits(1, 1)
    g3 = StalenessGate(2, max_staleness=3)
    assert g3.permits(3, 0) and not g3.permits(4, 0) and g3.permits(4, 1)


def test_gate_blocks_leader_until_straggler_finishes():
    gate = StalenessGate(2, max_staleness=0)
    order = []

    def leader():
        gate.start(0, 0)
        gate.finish(0)
        gate.start(0, 1)           # must block until worker 1 finishes step 0
        order.append("leader@1")
        gate.finish(0)

    t = threading.Thread(target=leader)
    t.start()
    time.sleep(0.1)
    assert order == []             # still parked at the gate
    gate.start(1, 0)
    order.append("straggler@0")
    gate.finish(1)
    t.join(timeout=10)
    assert not t.is_alive()
    assert order == ["straggler@0", "leader@1"]


def test_gate_abort_unblocks_waiters():
    gate = StalenessGate(2, max_staleness=0)
    err = []

    def blocked():
        try:
            gate.start(0, 1)       # can never proceed: peer is at step 0
        except RuntimeError as e:
            err.append(e)

    t = threading.Thread(target=blocked)
    t.start()
    time.sleep(0.05)
    gate.abort(ValueError("peer died"))
    t.join(timeout=10)
    assert not t.is_alive() and len(err) == 1


def test_lockstep_rounds_at_staleness_zero():
    """With max_staleness=0, every worker pushes round r before any worker
    pushes round r+1 — the synchronous data-parallel schedule."""
    rng = np.random.RandomState(0)
    xs = rng.randn(24, 4).astype(np.float32)
    ys = xs.sum(axis=1).astype(np.float32)

    def loss_fn(params, batch):
        loss = jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)
        return loss, loss

    sampler = FCPRSampler({"x": xs, "y": ys}, batch_size=4, seed=1)
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=1.0, stop=2,
                      zeta=0.01)
    coord = AsyncPSCoordinator(loss_fn, momentum(0.9), icfg, workers=3,
                               max_staleness=0,
                               lr_fn=lambda _: jnp.asarray(0.01))
    _, _, records = coord.run({"w": jnp.zeros(4, jnp.float32)}, sampler, 24)
    counts = [0, 0, 0]
    for r in records:
        counts[r["worker"]] += 1
        # at any prefix no worker is a whole round ahead of another
        assert max(counts) - min(counts) <= 1, counts
        assert r["tau"] <= 2       # within-round racing only (≤ N−1)
    assert counts == [8, 8, 8]


# ---------------------------------------------------------------------------
# per-worker FCPR shards
# ---------------------------------------------------------------------------
def test_sharded_feed_strides_global_cycle():
    rng = np.random.RandomState(0)
    data = {"x": rng.randn(48, 3).astype(np.float32)}
    sampler = FCPRSampler(data, batch_size=4, seed=1)     # 12 batches
    feeds = [ShardedFeed(sampler, w, 3) for w in range(3)]
    assert all(f.n_batches == 4 for f in feeds)
    for k in range(8):                                    # wraps the shard
        for w, f in enumerate(feeds):
            np.testing.assert_array_equal(
                np.asarray(f(k)["x"]), sampler(k * 3 + w)["x"])
    # non-divisible worker counts are legal now (ISSUE 7: re-striping needs
    # them) — the strided indices still enumerate the global cycle exactly
    # once across workers, ownership just rotates
    feeds5 = [ShardedFeed(sampler, w, 5) for w in range(5)]  # 12 % 5 != 0
    assert all(f.n_batches == 3 for f in feeds5)             # ceil(12/5)
    seen = sorted(k * 5 + w for k in range(12) for w in range(5))
    assert [g % 12 for g in seen[:12]] == sorted(g % 12 for g in range(12))
    for k in range(3):
        for w, f in enumerate(feeds5):
            np.testing.assert_array_equal(
                np.asarray(f(k)["x"]), sampler(k * 5 + w)["x"])


def test_sharded_feed_restripe():
    rng = np.random.RandomState(0)
    data = {"x": rng.randn(48, 3).astype(np.float32)}
    sampler = FCPRSampler(data, batch_size=4, seed=1)     # 12 batches
    f = ShardedFeed(sampler, 3, 4)
    np.testing.assert_array_equal(np.asarray(f(2)["x"]), sampler(11)["x"])
    f.restripe(1, 3)                                      # worker 3 → rank 1/3
    assert (f.wid, f.n_workers) == (1, 3)
    np.testing.assert_array_equal(np.asarray(f(2)["x"]), sampler(7)["x"])


def test_records_to_trainlog_wall_semantics():
    rec = {"loss": 1.0, "limit": float("inf"), "psi_bar": 1.0, "psi_std": 0.0,
           "accelerated": False, "sub_iters": 0, "wall": 0.25}
    one = records_to_trainlog([dict(rec, worker=0), dict(rec, worker=0)])
    assert one.wall == [0.25, 0.25]
    assert one.wall_est == [False, False]   # sequential pushes: true walls
    # overlapping workers: push deltas are ~cost/N, not per-update cost
    two = records_to_trainlog([dict(rec, worker=0), dict(rec, worker=1)])
    assert two.wall_est == [True, True]


# ---------------------------------------------------------------------------
# acceptance: multi-worker convergence on the lenet-8x8 config
# ---------------------------------------------------------------------------
def test_async_multiworker_convergence_lenet8x8():
    from repro.configs.paper_cnns import CNNConfig, ConvSpec
    from repro.models import cnn_loss_fn, init_cnn

    cfg = CNNConfig(name="lenet-8x8", image_size=8, channels=1,
                    num_classes=10,
                    convs=(ConvSpec(4, 3, pool=2), ConvSpec(8, 3, pool=2)),
                    hidden=(24,))
    data = make_classification(0, 64, 8, 1, 10, noise=0.2, class_spread=3.0)
    sampler = FCPRSampler(data, batch_size=8, seed=1)
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=1.5, stop=3,
                      zeta=0.02)
    loss_fn = lambda p, b: cnn_loss_fn(p, cfg, b)
    lr_fn = lambda _: jnp.asarray(0.03)
    params0 = init_cnn(jax.random.PRNGKey(0), cfg)
    steps = 320                                   # 40 epochs: both plateau

    init_fn, step = make_train_step(loss_fn, momentum(0.9), icfg,
                                    lr_fn=lr_fn, donate=False)
    p = jax.tree.map(jnp.copy, params0)
    s = init_fn(p)
    psis = []
    for j in range(steps):
        s, p, m = step(s, p, {k: jnp.asarray(v)
                              for k, v in sampler(j).items()})
        psis.append(m["psi_bar"])
    sync_final = float(np.mean([float(x) for x in psis[-16:]]))

    coord = AsyncPSCoordinator(loss_fn, momentum(0.9), icfg, workers=2,
                               max_staleness=1, lr_fn=lr_fn)
    _, state, records = coord.run(params0, sampler, steps)
    async_final = float(np.mean([r["psi_bar"] for r in records[-16:]]))

    # one-sided with slack: async must reach the sync engine's final loss
    # (observed gap ≲ 1e-3; 0.1 absorbs thread-schedule nondeterminism)
    assert async_final <= sync_final + 0.1, (async_final, sync_final)
    assert sync_final < 0.1 and async_final < 0.2, "neither run converged"
    assert int(state.accel_count) > 0
    taus = [r["tau"] for r in records]
    assert max(taus) <= (2 * 1 + 1) * (2 - 1)    # (2s+1)·(N−1), s=1 N=2
    assert sorted({r["worker"] for r in records}) == [0, 1]
