"""Data-parallel ISGD engine: reduction contexts, shard_map parity with the
single-device reference, and the prefetching input pipeline.

The in-process tests run on however many devices this process has (1 under
the plain tier-1 invocation; 8 under the CI matrix entry that sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).  The subprocess
test *always* exercises 8 devices by forcing the flag before jax init in a
child interpreter, so multi-device parity is covered on every run.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ISGDConfig, isgd_init, isgd_step
from repro.core.reduce import LOCAL, AxisReduce
from repro.data import FCPRSampler
from repro.distributed import (PrefetchSampler, make_data_parallel_step,
                               run_parity)
from repro.launch.mesh import make_data_mesh
from repro.optim import momentum, sgd
from repro.train.trainer import make_loss_and_grad

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# reduction contexts
# ---------------------------------------------------------------------------
def test_local_reduce_is_identity():
    lg = make_loss_and_grad(lambda p, b: (jnp.mean((p["w"] - b["t"]) ** 2),) * 2)
    wrapped = LOCAL.wrap_loss_and_grad(lg)
    assert wrapped is lg
    assert LOCAL.axis is None


def test_axis_reduce_means_over_mesh_axis():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = make_data_mesh()
    n = mesh.shape["data"]
    rctx = AxisReduce("data")
    x = jnp.arange(4 * n, dtype=jnp.float32)

    f = shard_map(lambda s: rctx.scalar(jnp.mean(s)), mesh=mesh,
                  in_specs=P("data"), out_specs=P(), check_rep=False)
    np.testing.assert_allclose(float(f(x)), float(jnp.mean(x)), rtol=1e-6)

    g = shard_map(lambda s: rctx.sum_scalar(jnp.sum(s)), mesh=mesh,
                  in_specs=P("data"), out_specs=P(), check_rep=False)
    np.testing.assert_allclose(float(g(x)), float(jnp.sum(x)), rtol=1e-6)

    # hashable + frozen: jit specializes without retracing per call
    assert hash(AxisReduce("data")) == hash(rctx)


def test_reduce_ctx_hashable_and_jit_specializes_without_retrace():
    """Every ReduceCtx flavor is a hashable static jit argument: equal
    contexts hit the jit cache (no retrace), distinct ones retrace once."""
    from functools import partial

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.reduce import StalenessReduce

    from repro.core.reduce import LocalReduce as _LR
    assert hash(LOCAL) == hash(_LR())
    assert hash(AxisReduce("data")) == hash(AxisReduce("data"))
    assert hash(StalenessReduce()) == hash(StalenessReduce())
    assert hash(StalenessReduce(decay="exp", alpha=0.5)) == \
        hash(StalenessReduce(decay="exp", alpha=0.5))
    assert StalenessReduce() == StalenessReduce(decay="inverse", alpha=1.0)
    assert StalenessReduce() != StalenessReduce(decay="exp")

    traces = []

    @partial(jax.jit, static_argnums=(0,))
    def step(ctx, x):
        traces.append(type(ctx).__name__)

        def lg(params, batch):
            loss = jnp.mean(params * batch)
            return (loss, loss), params
        (loss, _), g = ctx.wrap_loss_and_grad(lg)(x, x)
        return loss + jnp.sum(g)

    x = jnp.ones((4,), jnp.float32)
    step(LOCAL, x)
    step(LOCAL, x)                         # same ctx: cache hit
    step(_LR(), x)                         # fresh-but-equal ctx: cache hit
    assert traces == ["LocalReduce"]
    step(StalenessReduce(), x)
    step(StalenessReduce(decay="inverse", alpha=1.0), x)   # equal ⇒ cached
    assert traces == ["LocalReduce", "StalenessReduce"]
    step(StalenessReduce(decay="exp"), x)  # different ctx ⇒ one retrace
    assert traces == ["LocalReduce", "StalenessReduce", "StalenessReduce"]

    # AxisReduce's pmean needs its axis bound: count traces via shard_map
    mesh = make_data_mesh()
    ax_traces = []

    @partial(jax.jit, static_argnums=(0,))
    def ax_step(ctx, x):
        def inner(s):
            ax_traces.append(ctx.axis)
            return ctx.scalar(jnp.mean(s))
        return shard_map(inner, mesh=mesh, in_specs=P("data"), out_specs=P(),
                         check_rep=False)(x)

    n = mesh.shape["data"]
    xx = jnp.arange(4 * n, dtype=jnp.float32)
    ax_step(AxisReduce("data"), xx)
    ax_step(AxisReduce("data"), xx)        # equal ctx ⇒ no retrace
    assert ax_traces == ["data"]


# ---------------------------------------------------------------------------
# shard_map engine parity
# ---------------------------------------------------------------------------
def _parity_problem(batch_size, n_batches, dim=6, seed=0):
    rng = np.random.RandomState(seed)
    xs = rng.randn(batch_size * n_batches, dim).astype(np.float32)
    ys = ((xs @ rng.randn(dim, 1).astype(np.float32)).ravel()
          / np.sqrt(dim)).astype(np.float32)
    ys[:batch_size] += 3.0     # outlier batch so the subproblem fires

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, loss

    params = {"w": jnp.zeros((dim,), jnp.float32),
              "b": jnp.zeros((), jnp.float32)}
    sampler = FCPRSampler({"x": xs, "y": ys}, batch_size=batch_size, seed=1)
    return loss_fn, params, sampler


def test_data_parallel_matches_reference_over_20_steps():
    """Tentpole invariant: params, ψ̄, control limit and the accelerate
    decision agree with the single-device step across ≥20 steps."""
    n_dev = len(jax.devices())
    loss_fn, params0, sampler = _parity_problem(batch_size=8 * n_dev,
                                                n_batches=4)
    rule = momentum(0.9)
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=1.0, stop=3,
                      zeta=0.01)
    lg = make_loss_and_grad(loss_fn)
    ref_step = jax.jit(lambda s, p, b: isgd_step(rule, icfg, lg, s, p, b, 0.01))
    mesh = make_data_mesh()
    init_fn, dp_step = make_data_parallel_step(
        loss_fn, rule, icfg, mesh, lr_fn=lambda _: jnp.asarray(0.01))

    ref_p = jax.tree.map(jnp.copy, params0)
    ref_s = isgd_init(rule, icfg, ref_p)
    dp_p = jax.tree.map(jnp.copy, params0)
    dp_s = init_fn(dp_p)

    accels = 0
    for j in range(22):
        batch = {k: jnp.asarray(v) for k, v in sampler(j).items()}
        ref_s, ref_p, mr = ref_step(ref_s, ref_p, batch)
        dp_s, dp_p, md = dp_step(dp_s, dp_p, batch)
        for a, b in zip(jax.tree.leaves(ref_p), jax.tree.leaves(dp_p)):
            np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(float(mr["psi_bar"]), float(md["psi_bar"]),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(float(mr["limit"]), float(md["limit"]),
                                   atol=1e-5, rtol=1e-5)
        assert bool(mr["accelerated"]) == bool(md["accelerated"])
        accels += int(bool(mr["accelerated"]))
    assert accels > 0, "subproblem never fired; cond path untested"
    assert int(dp_s.accel_count) == accels


def test_data_parallel_consistent_step_runs():
    n_dev = len(jax.devices())
    loss_fn, params0, sampler = _parity_problem(batch_size=8 * n_dev,
                                                n_batches=2)
    icfg = ISGDConfig(n_batches=2)
    mesh = make_data_mesh()
    init_fn, step = make_data_parallel_step(
        loss_fn, sgd(), icfg, mesh, inconsistent=False,
        lr_fn=lambda _: jnp.asarray(0.05))
    p = jax.tree.map(jnp.copy, params0)
    s = init_fn(p)
    for j in range(3):
        batch = {k: jnp.asarray(v) for k, v in sampler(j).items()}
        s, p, m = step(s, p, batch)
    assert not bool(m["accelerated"])
    assert np.isfinite(float(m["loss"]))


def test_run_parity_inprocess():
    r = run_parity(steps=20, tol=1e-5)
    assert r["ok"], r
    assert r["accelerations"] > 0


def test_parity_subprocess_8_devices():
    """The acceptance-criteria check: 8 forced host devices, 20 steps,
    1e-5 agreement, accelerate branch identical — in a fresh interpreter so
    the device count doesn't leak into this process."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)     # parity sets the device-count flag itself
    proc = subprocess.run(
        [sys.executable, "-m", "repro.distributed.parity",
         "--devices", "8", "--steps", "20", "--tol", "1e-5"],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "devices=8" in proc.stdout


# ---------------------------------------------------------------------------
# prefetch pipeline
# ---------------------------------------------------------------------------
def test_prefetch_preserves_fcpr_batches():
    _, _, sampler = _parity_problem(batch_size=8, n_batches=3)
    pf = PrefetchSampler(sampler, depth=2)
    assert (pf.n_batches, pf.batch_size) == (sampler.n_batches, 8)
    for j in range(7):          # wraps the cycle twice
        got = pf(j)
        want = sampler(j)
        assert pf.batch_index(j) == sampler.batch_index(j)
        for k in want:
            assert isinstance(got[k], jax.Array)
            np.testing.assert_array_equal(np.asarray(got[k]), want[k])


def test_prefetch_stages_ahead_and_handles_random_access():
    _, _, sampler = _parity_problem(batch_size=8, n_batches=4)
    pf = PrefetchSampler(sampler, depth=2)
    pf(0)
    assert 1 in pf._staged                 # next batch already in flight
    got = pf(3)                            # random access: cold miss
    np.testing.assert_array_equal(np.asarray(got["y"]), sampler(3)["y"])
    assert all(k > 3 for k in pf._staged)  # stale entries dropped


def test_prefetch_with_mesh_sharding_feeds_dp_step():
    from repro.launch.shardings import data_parallel_shardings

    mesh = make_data_mesh()
    n_dev = mesh.shape["data"]
    loss_fn, params0, sampler = _parity_problem(batch_size=4 * n_dev,
                                                n_batches=2)
    # per-leaf sharding dict (launch path) — same layout as the blanket one
    shs = data_parallel_shardings(mesh, sampler(0))
    assert set(shs) == set(sampler(0))
    for s in shs.values():      # batch dim over 'data', rest unsharded
        assert s.spec[0] == "data" and all(a is None for a in s.spec[1:])
    pf = PrefetchSampler(sampler, sharding=shs)
    icfg = ISGDConfig(n_batches=2)
    init_fn, step = make_data_parallel_step(
        loss_fn, sgd(), icfg, mesh, lr_fn=lambda _: jnp.asarray(0.05))
    p = jax.tree.map(jnp.copy, params0)
    s = init_fn(p)
    s, p, m = step(s, p, pf(0))
    assert np.isfinite(float(m["loss"]))
