"""Serving correctness: prefill + decode must reproduce the full-sequence
forward logits (the strongest end-to-end invariant of the cache path), and
the continuous-batching subsystem (slots / scheduler / snapshot swap) must
match the one-shot engine request-for-request."""
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, zoo_config
from repro.models import build_model
from repro.models import transformer as T
from repro.serve import (ContinuousScheduler, Request, ServeEngine,
                         SnapshotWatcher, merge_prefill_cache, read_pointer)

KEY = jax.random.PRNGKey(0)


def _logits_full(model, params, tokens, fe=None):
    h, _, _ = T.forward(params, model.cfg, tokens, fe, want_cache=False,
                        remat=False)
    return T.logits_head(params, model.cfg, h)


@pytest.mark.parametrize("arch", ["internlm2_1_8b", "mamba2_2_7b",
                                  "gemma3_12b", "deepseek_v2_lite_16b"])
def test_prefill_then_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY, max_seq=32)
    B, Sp, S = 2, 8, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)

    full = _logits_full(model, params, tokens)           # (B, S, Vp)

    batch = {"tokens": tokens[:, :Sp]}
    logits_p, pre = model.prefill_fn(params, batch)
    cache = model.init_cache(B, S)
    cache = merge_prefill_cache(cache, pre)
    cache["t"] = jnp.asarray(Sp, jnp.int32)

    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32),
        np.asarray(full[:, Sp - 1], np.float32), rtol=3e-2, atol=3e-2)

    for t in range(Sp, S):
        logits_d, cache = model.decode_fn(params, cache, tokens[:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(logits_d, np.float32),
            np.asarray(full[:, t], np.float32), rtol=5e-2, atol=5e-2)


def test_engine_generates_greedy_consistent():
    cfg = get_config("internlm2_1_8b").reduced()
    model = build_model(cfg)
    params = model.init(KEY, max_seq=64)
    engine = ServeEngine(model, params, max_seq=64)
    prompts = np.random.RandomState(0).randint(0, cfg.vocab_size,
                                               size=(2, 8)).astype(np.int32)
    out = engine.generate(prompts, steps=4)
    assert out.shape == (2, 12)
    # greedy from the full forward must agree on the first generated token
    full = _logits_full(model, params, jnp.asarray(prompts))
    first = np.argmax(np.asarray(full[:, -1, :cfg.vocab_size]), -1)
    np.testing.assert_array_equal(out[:, 8], first)


def test_sliding_window_cache_decode():
    """gemma3-style local layer: decode with window smaller than context."""
    cfg = get_config("gemma3_12b").reduced()
    model = build_model(cfg)
    params = model.init(KEY, max_seq=64)
    B, S = 1, 48
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full = _logits_full(model, params, tokens)
    batch = {"tokens": tokens[:, :S - 1]}
    _, pre = model.prefill_fn(params, batch)
    cache = model.init_cache(B, S)
    cache = merge_prefill_cache(cache, pre)
    cache["t"] = jnp.asarray(S - 1, jnp.int32)
    logits_d, _ = model.decode_fn(params, cache, tokens[:, -1:])
    np.testing.assert_allclose(np.asarray(logits_d, np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=5e-2, atol=5e-2)


# -- continuous-batching subsystem (slots / scheduler / snapshot swap) -------

def _zoo(family, dtype=jnp.bfloat16, max_seq=48):
    cfg = zoo_config(family, "tiny")
    model = build_model(cfg, param_dtype=dtype)
    return cfg, model, model.init(KEY, max_seq=max_seq)


def test_generate_step_counts():
    """steps=0 -> prompt unchanged; steps=1 -> exactly one token (the
    prefill argmax — it counts toward steps, not on top of them)."""
    cfg, model, params = _zoo("transformer")
    engine = ServeEngine(model, params, max_seq=32)
    prompts = np.random.RandomState(0).randint(
        0, cfg.vocab_size, size=(2, 8)).astype(np.int32)
    np.testing.assert_array_equal(engine.generate(prompts, steps=0), prompts)
    out1 = engine.generate(prompts, steps=1)
    assert out1.shape == (2, 9)
    logits, _ = model.prefill_fn(params, {"tokens": jnp.asarray(prompts)})
    np.testing.assert_array_equal(
        out1[:, -1], np.argmax(np.asarray(logits[:, :cfg.vocab_size]), -1))


@pytest.mark.parametrize("family", ["transformer", "ssm"])
def test_decode_parity_full_forward_argmax(family):
    """Prefill + stepwise cached decode must pick the same greedy token as
    the full no-cache forward at every position (f32: no bf16 argmax
    ties)."""
    cfg, model, params = _zoo(family, dtype=jnp.float32, max_seq=16)
    B, Sp, S = 1, 4, 12
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full = _logits_full(model, params, tokens)
    want = np.argmax(np.asarray(full[..., :cfg.vocab_size], np.float32), -1)

    logits_p, pre = model.prefill_fn(params, {"tokens": tokens[:, :Sp]})
    cache = merge_prefill_cache(model.init_cache(B, S), pre)
    cache["t"] = jnp.asarray(Sp, jnp.int32)
    np.testing.assert_array_equal(
        np.argmax(np.asarray(logits_p[:, :cfg.vocab_size]), -1),
        want[:, Sp - 1])
    for t in range(Sp, S):
        logits_d, cache = model.decode_fn(params, cache, tokens[:, t:t + 1])
        np.testing.assert_array_equal(
            np.argmax(np.asarray(logits_d[:, :cfg.vocab_size]), -1),
            want[:, t])


@pytest.mark.parametrize("arch", ["internlm2_1_8b", "mamba2_2_7b",
                                  "gemma3_12b", "deepseek_v2_lite_16b"])
def test_vector_t_decode_matches_scalar(arch):
    """decode_step with a per-slot (B,) cursor vector must reproduce the
    scalar-cursor decode when all cursors agree — covers GQA, SSM, sliding
    window and MLA cache paths."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY, max_seq=32)
    B, Sp, S = 2, 6, 16
    tokens = jax.random.randint(KEY, (B, Sp + 1), 0, cfg.vocab_size)
    _, pre = model.prefill_fn(params, {"tokens": tokens[:, :Sp]})

    def decode_with(t):
        cache = merge_prefill_cache(model.init_cache(B, S), pre)
        cache["t"] = t
        logits, cache = model.decode_fn(params, cache, tokens[:, -1:])
        return np.asarray(logits, np.float32), cache

    logits_s, _ = decode_with(jnp.asarray(Sp, jnp.int32))
    logits_v, cache_v = decode_with(jnp.full((B,), Sp, jnp.int32))
    np.testing.assert_allclose(logits_v, logits_s, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(cache_v["t"]),
                                  np.full((B,), Sp + 1))


@pytest.mark.parametrize("family", ["transformer", "ssm", "moe"])
def test_scheduler_matches_oneshot_staggered(family):
    """Continuous batching with staggered admits/retires (mixed prompt
    lengths and budgets on fewer slots than requests) must emit exactly
    the tokens the one-shot engine produces per request — and compile the
    fused decode exactly once."""
    cfg, model, params = _zoo(family)
    max_seq = 48
    rng = np.random.RandomState(1)
    reqs = [Request(rid=i, prompt=rng.randint(
                0, cfg.vocab_size, size=(n,)).astype(np.int32),
                max_new_tokens=m)
            for i, (n, m) in enumerate([(6, 8), (10, 3), (6, 5), (14, 8)])]

    engine = ServeEngine(model, params, max_seq=max_seq)
    want = {r.rid: engine.generate(r.prompt[None], steps=r.max_new_tokens)
            [0, len(r.prompt):] for r in reqs}

    sched = ContinuousScheduler(model, params, max_batch=2, max_seq=max_seq)
    comps = sched.run(reqs)
    assert [c.rid for c in comps] == [0, 1, 2, 3]
    for c in comps:
        np.testing.assert_array_equal(np.asarray(c.tokens), want[c.rid])
    counts = sched.kv.compile_counts()
    assert counts["decode"] == 1, counts       # admits/retires never reflush
    # prefill compiles once per distinct prompt length; admit at most that
    # (SSM prefill states are length-free, so its admit compiles just once)
    assert counts["prefill"] == len({6, 10, 14}), counts
    assert counts["admit"] <= counts["prefill"], counts


def test_scheduler_admission_control():
    cfg, model, params = _zoo("transformer")
    prompt = np.arange(4, dtype=np.int32)
    # bounded queue: submits beyond max_queue are shed
    sched = ContinuousScheduler(model, params, max_batch=2, max_seq=16,
                                max_decode_batch=1, max_queue=2)
    assert sched.submit(Request(rid=0, prompt=prompt, max_new_tokens=3))
    assert sched.submit(Request(rid=1, prompt=prompt, max_new_tokens=3))
    assert not sched.submit(Request(rid=2, prompt=prompt, max_new_tokens=3))
    assert sched.rejected == 1
    # max_decode_batch caps concurrency below the slot count
    sched.step()
    assert sched.n_active <= 1
    comps = sched.run()
    assert [c.rid for c in comps] == [0, 1]

    # token budget truncates at max_seq; a prompt filling max_seq yields
    # the steps=0 contract (no slot, no tokens)
    sched2 = ContinuousScheduler(model, params, max_batch=2, max_seq=16)
    long = np.zeros(14, np.int32)
    full = np.zeros(16, np.int32)
    comps = sched2.run([Request(rid=0, prompt=long, max_new_tokens=8),
                        Request(rid=1, prompt=full, max_new_tokens=4)])
    assert comps[0].truncated and len(comps[0].tokens) == 2
    assert comps[1].truncated and comps[1].tokens == []


def test_scheduler_eos_stop():
    cfg, model, params = _zoo("transformer")
    rng = np.random.RandomState(3)
    prompt = rng.randint(0, cfg.vocab_size, size=(6,)).astype(np.int32)
    free = ContinuousScheduler(model, params, max_batch=1, max_seq=32)
    toks = free.run([Request(rid=0, prompt=prompt,
                             max_new_tokens=6)])[0].tokens
    eos = toks[2]                       # greedy is deterministic
    cut = toks.index(eos) + 1           # first occurrence stops the request
    sched = ContinuousScheduler(model, params, max_batch=1, max_seq=32)
    comp = sched.run([Request(rid=0, prompt=prompt, max_new_tokens=6,
                              eos_id=int(eos))])[0]
    assert comp.tokens == toks[:cut] and not comp.truncated


def test_train_and_serve_end_to_end(tmp_path):
    """The full loop: a trainer subprocess publishing snapshots while the
    continuous scheduler serves through them.  Asserts >=2 distinct
    snapshot generations served, zero dropped requests across swaps, and
    the served params bit-identical to the pointed-to checkpoint on
    disk."""
    from repro.train.checkpoints import restore, tree_checksum
    pub = str(tmp_path / "pub")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")

    # serve FIRST, from the freshly-initialized params (generation 0), so
    # completions exist under gen 0 before any snapshot lands — then every
    # later pointer movement gives a second served generation no matter
    # how fast the trainer runs
    cfg, model, template = _zoo("transformer")
    watcher = SnapshotWatcher(pub, params_like=template)
    sched = ContinuousScheduler(model, template, max_batch=2, max_seq=48,
                                watcher=watcher, swap_poll_every=1)
    rng = np.random.RandomState(0)
    rid = 0

    def feed_and_step():
        nonlocal rid
        while sched.pending < 2:
            p = rng.randint(0, cfg.vocab_size, size=(6,)).astype(np.int32)
            assert sched.submit(Request(rid=rid, prompt=p, max_new_tokens=6))
            rid += 1
        sched.step()

    while len(sched.completions) < 4:    # gen-0 traffic, warm jit caches
        feed_and_step()

    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train", "--model", "transformer",
         "--steps", "9", "--batch", "2", "--seq", "32", "--n-seqs", "8",
         "--publish-dir", pub, "--publish-every", "3"],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
    try:
        deadline = time.time() + 240
        while proc.poll() is None and time.time() < deadline:
            feed_and_step()
        sched.poll_snapshot()            # pick up the final snapshot
        while sched.pending:
            sched.step()
        out = proc.communicate(timeout=60)[0]
    finally:
        proc.kill()
    assert proc.returncode == 0, out
    assert len(sched.swap_events) >= 1   # at least one hot swap under load

    comps = sched.completions
    gens = {c.gen_finished for c in comps}
    assert len(gens) >= 2, f"served generations {gens} (swaps "\
                           f"{len(sched.swap_events)})"
    # zero dropped: every submitted request completed with its full budget
    assert sorted(c.rid for c in comps) == list(range(rid))
    assert all(len(c.tokens) == 6 for c in comps)
    # in-flight KV survived the swaps: some request was admitted under one
    # generation and finished under another
    assert any(c.gen_admitted != c.gen_finished for c in comps)
    # the served params are bit-identical to the checkpoint on disk
    disk = restore(read_pointer(pub), {"params": template})
    assert (tree_checksum({"params": disk["params"]})
            == tree_checksum({"params": sched.kv.params}))
