"""Serving correctness: prefill + decode must reproduce the full-sequence
forward logits (the strongest end-to-end invariant of the cache path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models import transformer as T
from repro.serve import ServeEngine, merge_prefill_cache

KEY = jax.random.PRNGKey(0)


def _logits_full(model, params, tokens, fe=None):
    h, _, _ = T.forward(params, model.cfg, tokens, fe, want_cache=False,
                        remat=False)
    return T.logits_head(params, model.cfg, h)


@pytest.mark.parametrize("arch", ["internlm2_1_8b", "mamba2_2_7b",
                                  "gemma3_12b", "deepseek_v2_lite_16b"])
def test_prefill_then_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY, max_seq=32)
    B, Sp, S = 2, 8, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)

    full = _logits_full(model, params, tokens)           # (B, S, Vp)

    batch = {"tokens": tokens[:, :Sp]}
    logits_p, pre = model.prefill_fn(params, batch)
    cache = model.init_cache(B, S)
    cache = merge_prefill_cache(cache, pre)
    cache["t"] = jnp.asarray(Sp, jnp.int32)

    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32),
        np.asarray(full[:, Sp - 1], np.float32), rtol=3e-2, atol=3e-2)

    for t in range(Sp, S):
        logits_d, cache = model.decode_fn(params, cache, tokens[:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(logits_d, np.float32),
            np.asarray(full[:, t], np.float32), rtol=5e-2, atol=5e-2)


def test_engine_generates_greedy_consistent():
    cfg = get_config("internlm2_1_8b").reduced()
    model = build_model(cfg)
    params = model.init(KEY, max_seq=64)
    engine = ServeEngine(model, params, max_seq=64)
    prompts = np.random.RandomState(0).randint(0, cfg.vocab_size,
                                               size=(2, 8)).astype(np.int32)
    out = engine.generate(prompts, steps=4)
    assert out.shape == (2, 12)
    # greedy from the full forward must agree on the first generated token
    full = _logits_full(model, params, jnp.asarray(prompts))
    first = np.argmax(np.asarray(full[:, -1, :cfg.vocab_size]), -1)
    np.testing.assert_array_equal(out[:, 8], first)


def test_sliding_window_cache_decode():
    """gemma3-style local layer: decode with window smaller than context."""
    cfg = get_config("gemma3_12b").reduced()
    model = build_model(cfg)
    params = model.init(KEY, max_seq=64)
    B, S = 1, 48
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full = _logits_full(model, params, tokens)
    batch = {"tokens": tokens[:, :S - 1]}
    _, pre = model.prefill_fn(params, batch)
    cache = model.init_cache(B, S)
    cache = merge_prefill_cache(cache, pre)
    cache["t"] = jnp.asarray(S - 1, jnp.int32)
    logits_d, _ = model.decode_fn(params, cache, tokens[:, -1:])
    np.testing.assert_allclose(np.asarray(logits_d, np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=5e-2, atol=5e-2)
