"""Fault injection + elastic recovery (ISSUE 7).

Covers the three tentpole pieces end to end on tiny problems:

  * ``repro.fault`` — deterministic, seeded, one-shot fault plans;
  * eviction/re-striping — hang-past-deadline and crash faults under an
    elastic gate complete on survivors, with the event log naming who was
    evicted and why; non-elastic gates fail fast with a diagnostic naming
    the stalled worker (the old behavior was a silent 120 s spin);
  * durability — corrupt/transient pushes are absorbed bit-exactly by
    checksum-verify + bounded retry, server snapshots round-trip, and the
    kill/resume parity legs are pinned into the tier-1 suite.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ISGDConfig
from repro.data import FCPRSampler
from repro.distributed.async_ps import (AsyncPSCoordinator, ParamServer,
                                        StalenessGate, WorkerEvicted,
                                        WorkerFailure, WorkerStalled)
from repro.fault import FaultEvent, FaultPlan, InjectedCrash
from repro.optim import momentum


# ---------------------------------------------------------------------------
# FaultPlan: spec grammar, seeded reproducibility, one-shot semantics
# ---------------------------------------------------------------------------
def test_fault_plan_from_spec():
    plan = FaultPlan.from_spec(
        "crash@2:5; hang@1:8:seconds=1.5; slow@0:0:factor=3:until=9")
    kinds = [(e.kind, e.worker, e.step) for e in plan.events]
    assert kinds == [("crash", 2, 5), ("hang", 1, 8), ("slow", 0, 0)]
    assert plan.events[1].seconds == 1.5
    assert plan.events[2].factor == 3.0 and plan.events[2].until == 9
    assert not FaultPlan.from_spec("")          # empty spec = no faults
    with pytest.raises(ValueError, match="bad fault spec"):
        FaultPlan.from_spec("explode@0:1")
    with pytest.raises(ValueError, match="bad fault spec"):
        FaultPlan.from_spec("crash@0:1:wat=2")


def test_fault_plan_random_seeded():
    a = FaultPlan.random(4, 20, seed=7, crashes=1, hangs=1)
    b = FaultPlan.random(4, 20, seed=7, crashes=1, hangs=1)
    assert a.events == b.events                 # reproducible in the seed
    assert len({e.worker for e in a.events}) == 2   # distinct workers
    assert all(4 <= e.step < 16 for e in a.events)  # middle [0.2, 0.8)
    c = FaultPlan.random(4, 20, seed=8, crashes=1, hangs=1)
    assert c.events != a.events
    with pytest.raises(AssertionError, match="survive"):
        FaultPlan.random(2, 20, seed=0, crashes=1, hangs=1)


def test_fault_plan_one_shot_and_reset():
    plan = FaultPlan([FaultEvent(kind="corrupt", worker=0, step=1)])
    tree = {"w": jnp.zeros(3)}
    out1 = plan.on_transit(0, 1, tree)
    assert float(out1["w"][0]) == 1e3           # corrupted once
    out2 = plan.on_transit(0, 1, tree)
    assert float(out2["w"][0]) == 0.0           # one-shot: retry sees clean
    plan.reset()
    out3 = plan.on_transit(0, 1, tree)
    assert float(out3["w"][0]) == 1e3


def test_slow_factor_windows():
    plan = FaultPlan([FaultEvent(kind="slow", worker=1, step=2, factor=2.0,
                                 until=4),
                      FaultEvent(kind="slow", worker=1, step=3, factor=3.0)])
    assert plan.slow_factor(1, 1) == 1.0
    assert plan.slow_factor(1, 2) == 2.0
    assert plan.slow_factor(1, 3) == 6.0        # windows compose
    assert plan.slow_factor(1, 5) == 3.0        # first window closed
    assert plan.slow_factor(0, 3) == 1.0        # per-worker targeting


# ---------------------------------------------------------------------------
# gate: stall diagnostics (non-elastic) and eviction (elastic)
# ---------------------------------------------------------------------------
def test_gate_stall_raises_diagnostic_not_spin():
    """A dead worker no longer deadlocks its peer behind a silent
    cv.wait(120): the waiter gets a WorkerStalled naming the stalled worker
    and its last completed step."""
    gate = StalenessGate(2, max_staleness=0, deadline_s=0.2)
    gate.finish(1)                              # worker 1 completed step 0
    # worker 0 never finishes step 0 and never heartbeats; worker 1 blocks
    # on starting step 1
    err = []
    t = threading.Thread(target=lambda: err.append(
        pytest.raises(WorkerStalled, gate.start, 1, 1)))
    t.start()
    t.join(timeout=10)
    assert not t.is_alive() and len(err) == 1
    msg = str(err[0].value)
    assert "worker 0 stalled" in msg and "last completed step 0" in msg


def test_gate_waiting_worker_is_not_stalled():
    """Waiting at the gate refreshes the waiter's own heartbeat — two
    workers in lockstep never evict each other just for being blocked."""
    gate = StalenessGate(2, max_staleness=0, deadline_s=0.2, elastic=True)
    done = []

    def worker(wid):
        for k in range(6):
            gate.start(wid, k)
            time.sleep(0.08)                    # step > poll interval
            gate.finish(wid)
        done.append(wid)

    ts = [threading.Thread(target=worker, args=(w,)) for w in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=10)
    assert sorted(done) == [0, 1] and gate.evictions() == {}


def test_gate_elastic_evicts_and_unblocks():
    gate = StalenessGate(2, max_staleness=0, deadline_s=0.2, elastic=True)
    evicted = []
    gate._on_evict = lambda wid, last, survivors, reason: \
        evicted.append((wid, last, survivors))
    gate.finish(1)
    gate.start(1, 1)                            # blocks, then evicts worker 0
    assert evicted == [(0, 0, [1])]
    assert 0 in gate.evictions() and gate.active_workers() == [1]
    gate.finish(0)                              # late finish: ignored
    assert gate._done[0] == 0
    with pytest.raises(WorkerEvicted):
        gate.start(0, 1)                        # evictee unwinds at the gate
    with pytest.raises(WorkerEvicted):
        gate.heartbeat(0)                       # ... or at its next heartbeat


# ---------------------------------------------------------------------------
# server: eviction fence, snapshot round-trip
# ---------------------------------------------------------------------------
def _tiny_server(**kw):
    params = {"w": jnp.zeros(3)}
    srv = ParamServer(params, momentum(0.9).init(params),
                      ISGDConfig(n_batches=4), **kw)
    return params, srv


def test_server_fences_evicted_worker():
    params, srv = _tiny_server()
    snap = srv.pull()
    srv.push(snap, {"w": jnp.ones(3)}, snap.base, worker=0, metrics={})
    srv.mark_evicted(1)
    stale = srv.pull()
    with pytest.raises(WorkerEvicted):
        srv.push(stale, {"w": jnp.full(3, 9.0)}, stale.base, worker=1,
                 metrics={})
    np.testing.assert_array_equal(np.asarray(srv.params["w"]), 1.0)
    assert srv.pushed_clocks() == {0: 1}        # the fenced push never landed


def test_server_snapshot_roundtrip():
    params, srv = _tiny_server()
    for i in range(3):
        snap = srv.pull()
        srv.observe(jnp.asarray(float(i)))
        srv.push(snap, {"w": jnp.full(3, float(i))}, snap.base,
                 worker=i % 2, metrics={"accelerated": True, "sub_iters": 2})
    snap = srv.engine_snapshot()
    assert snap["version"] == 3 and snap["pushed"] == {0: 2, 1: 1}
    _, srv2 = _tiny_server()
    srv2.load_snapshot(snap)
    assert srv2.version == 3 and srv2.pushed_clocks() == {0: 2, 1: 1}
    np.testing.assert_array_equal(np.asarray(srv2.params["w"]),
                                  np.asarray(srv.params["w"]))
    s1, s2 = srv.isgd_state(), srv2.isgd_state()
    assert int(s2.accel_count) == int(s1.accel_count) == 3
    np.testing.assert_array_equal(np.asarray(s1.queue.buf),
                                  np.asarray(s2.queue.buf))


# ---------------------------------------------------------------------------
# coordinator end-to-end: crash/hang recovery, retry, tracebacks
# ---------------------------------------------------------------------------
def _coord_problem(n_batches=4, batch=16):
    rng = np.random.RandomState(0)
    dim = 5
    xs = rng.randn(batch * n_batches, dim).astype(np.float32)
    ys = ((xs @ rng.randn(dim, 1).astype(np.float32)).ravel()
          / np.sqrt(dim)).astype(np.float32)
    sampler = FCPRSampler({"x": xs, "y": ys}, batch_size=batch, seed=1)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, loss

    params0 = {"w": jnp.zeros((dim,), jnp.float32),
               "b": jnp.zeros((), jnp.float32)}
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=1.5, stop=3)
    return loss_fn, params0, sampler, icfg


def _coord(loss_fn, icfg, **kw):
    return AsyncPSCoordinator(loss_fn, momentum(0.9), icfg,
                              lr_fn=lambda pb: jnp.asarray(0.01), **kw)


def test_elastic_crash_self_evicts_and_run_completes():
    loss_fn, params0, sampler, icfg = _coord_problem()
    plan = FaultPlan.from_spec("crash@1:2")
    coord = _coord(loss_fn, icfg, workers=2, max_staleness=1, elastic=True,
                   faults=plan)
    params, state, records = coord.run(params0, sampler, 16)
    kinds = [e["event"] for e in coord.events]
    assert kinds == ["evict", "crash"]
    assert coord.events[0]["worker"] == 1
    assert coord.events[0]["survivors"] == [0]
    assert "InjectedCrash" in coord.events[1]["error"]
    assert "before_step" in coord.events[1]["traceback"]
    # worker 1 landed 2 of its 8 pushes; worker 0 all 8
    assert len(records) == 10
    assert int(state.iter) == 10


def test_elastic_hang_past_deadline_evicted_and_restriped():
    loss_fn, params0, sampler, icfg = _coord_problem()
    plan = FaultPlan.from_spec("hang@0:2:seconds=1.0")
    coord = _coord(loss_fn, icfg, workers=2, max_staleness=0, elastic=True,
                   deadline_s=0.25, faults=plan)
    t0 = time.perf_counter()
    params, state, records = coord.run(params0, sampler, 16)
    dt = time.perf_counter() - t0
    evicts = [e for e in coord.events if e["event"] == "evict"]
    assert len(evicts) == 1 and evicts[0]["worker"] == 0
    assert "deadline" in evicts[0]["reason"]
    assert dt < 5.0                             # survivor did not wait out 120s
    # survivor re-striped to stride 1 → it now serves the FULL cycle: its
    # pushes after the eviction cover both parities of the global index
    assert len(records) == 10                   # 2 from w0 + 8 from w1


def test_non_elastic_stall_surfaces_worker_stalled():
    loss_fn, params0, sampler, icfg = _coord_problem()
    plan = FaultPlan.from_spec("hang@0:2:seconds=1.2")
    coord = _coord(loss_fn, icfg, workers=2, max_staleness=0, elastic=False,
                   deadline_s=0.25, faults=plan)
    with pytest.raises(WorkerFailure) as ei:
        coord.run(params0, sampler, 16)
    assert isinstance(ei.value.original, WorkerStalled)
    assert "worker 0 stalled" in str(ei.value)


def test_last_survivor_crash_fails_run_with_traceback():
    loss_fn, params0, sampler, icfg = _coord_problem()
    coord = _coord(loss_fn, icfg, workers=1, elastic=True,
                   faults=FaultPlan.from_spec("crash@0:3"))
    with pytest.raises(WorkerFailure) as ei:
        coord.run(params0, sampler, 8)
    assert ei.value.wid == 0
    assert isinstance(ei.value.original, InjectedCrash)
    assert isinstance(ei.value.__cause__, InjectedCrash)   # chained
    assert "worker thread traceback" in str(ei.value)
    assert "before_step" in str(ei.value)       # the dead thread's frames


def test_corrupt_and_transient_pushes_retry_bit_exact():
    """A corrupted delta is rejected by checksum and resent clean; a
    transient transport failure is retried — neither perturbs the
    trajectory by a single bit."""
    loss_fn, params0, sampler, icfg = _coord_problem()
    clean = _coord(loss_fn, icfg, workers=1, verify_pushes=True)
    p_ref, s_ref, r_ref = clean.run(params0, sampler, 8)

    plan = FaultPlan.from_spec("corrupt@0:1;transient@0:3")
    faulty = _coord(loss_fn, icfg, workers=1, verify_pushes=True, faults=plan)
    p, s, r = faulty.run(params0, sampler, 8)
    assert len(r) == len(r_ref) == 8
    for a, b in zip((p_ref, s_ref.base), (p, s.base)):
        for xa, xb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


def test_retry_exhaustion_surfaces_as_failure():
    loss_fn, params0, sampler, icfg = _coord_problem()
    # corrupt every attempt: 1 + push_retries transits all fire
    plan = FaultPlan([FaultEvent(kind="corrupt", worker=0, step=1)
                      for _ in range(4)])
    coord = _coord(loss_fn, icfg, workers=1, verify_pushes=True, faults=plan,
                   push_retries=2)
    with pytest.raises(WorkerFailure, match="failed after 3 attempts"):
        coord.run(params0, sampler, 4)


# ---------------------------------------------------------------------------
# kill/resume parity pinned into tier-1 (full sweep in CI's fault leg)
# ---------------------------------------------------------------------------
def test_resume_parity_per_step_and_async():
    from repro.train import run_resume_parity
    results = run_resume_parity(18, 6, legs=("per-step", "async-ps"))
    assert all(r["ok"] for r in results), results
    assert sum(r["accelerations"] for r in results) > 0


@pytest.mark.slow
def test_resume_parity_all_engines():
    from repro.train import run_resume_parity
    results = run_resume_parity(30, 10)
    assert all(r["ok"] for r in results), results
