"""Minimal stand-in for the slice of `hypothesis` the test-suite uses.

The `test` extra pins real hypothesis and CI installs it; this fallback
exists so the tier-1 suite still *runs* the property tests (as a seeded
random sweep, no shrinking) on machines where the extra isn't installed —
e.g. the hermetic reproduction container, which cannot pip install.

Supported surface: ``@given`` over ``st.floats``/``st.integers``/
``st.lists`` strategies, and ``@settings(max_examples=..., deadline=...)``.
Anything fancier should import real hypothesis and skip when absent.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

_SEED = 20160318        # arXiv:1603.05544 submission date


@dataclass(frozen=True)
class _Strategy:
    draw: Any           # Callable[[np.random.RandomState], value]


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (imported as ``st``)."""

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, allow_nan=False, width=64):
        def draw(rng):
            x = rng.uniform(min_value, max_value)
            return float(np.float32(x)) if width == 32 else float(x)
        return _Strategy(draw)

    @staticmethod
    def integers(min_value=0, max_value=100):
        return _Strategy(lambda rng: int(rng.randint(min_value,
                                                     max_value + 1)))

    @staticmethod
    def lists(elements: _Strategy, min_size=0, max_size=10):
        def draw(rng):
            size = int(rng.randint(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(size)]
        return _Strategy(draw)


st = strategies


@dataclass
class settings:
    max_examples: int = 100
    deadline: Any = None
    extra: dict = field(default_factory=dict)

    def __init__(self, max_examples=100, deadline=None, **extra):
        self.max_examples = max_examples
        self.deadline = deadline
        self.extra = extra

    def __call__(self, fn):
        fn._fallback_settings = self
        return fn


def given(*strats: _Strategy):
    def deco(fn):
        # NOT functools.wraps: pytest must see a zero-arg signature, or it
        # would try to resolve the property arguments as fixtures.
        def wrapper():
            n = getattr(fn, "_fallback_settings",
                        settings()).max_examples
            rng = np.random.RandomState(_SEED)
            for i in range(n):
                drawn = tuple(s.draw(rng) for s in strats)
                try:
                    fn(*drawn)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (fallback fuzzer, "
                        f"iteration {i}): {drawn!r}") from e
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.hypothesis_fallback = True
        return wrapper
    return deco
