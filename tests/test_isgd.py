"""ISGD core behaviour: subproblem descent, conservative bound, control
flow of the inconsistent step."""
import jax.numpy as jnp
import numpy as np

from repro.core import (ISGDConfig, consistent_step, isgd_init, isgd_step,
                        solve_subproblem)
from repro.optim import momentum, sgd
from repro.train.trainer import make_loss_and_grad


def quad_loss(params, batch):
    w = params["w"]
    loss = 0.5 * jnp.sum((w - batch["target"]) ** 2)
    return loss, loss


LG = make_loss_and_grad(quad_loss)


def test_subproblem_reduces_loss_toward_limit():
    params = {"w": jnp.array([4.0, -4.0])}
    batch = {"target": jnp.zeros(2)}
    (loss0, _), _ = LG(params, batch)
    limit = jnp.asarray(4.0)
    cfg = ISGDConfig(n_batches=4, stop=20, epsilon=0.1, zeta=0.05)

    def lg(w):
        (l, _), g = LG(w, batch)
        return l, g

    w, used = solve_subproblem(lg, params, limit, loss0, 0.05, cfg)
    (loss1, _), _ = LG(w, batch)
    assert float(loss1) < float(loss0)
    assert int(used) > 0
    # early stopping: once under the limit it must stop
    assert float(loss1) <= float(loss0)


def test_subproblem_early_stops_at_stop():
    params = {"w": jnp.array([100.0])}
    batch = {"target": jnp.zeros(1)}
    (loss0, _), _ = LG(params, batch)
    cfg = ISGDConfig(n_batches=4, stop=3, zeta=1e-6)   # tiny steps: never converges

    def lg(w):
        (l, _), g = LG(w, batch)
        return l, g

    _, used = solve_subproblem(lg, params, jnp.asarray(0.0), loss0, 1e-6, cfg)
    assert int(used) == 3


def test_conservative_term_bounds_parameter_change():
    """Larger epsilon ⇒ smaller distance from the entry weights."""
    params = {"w": jnp.full((4,), 1.0)}
    batch = {"target": jnp.zeros(4)}
    (loss0, _), _ = LG(params, batch)          # ψ = 2.0

    def lg(w):
        (l, _), g = LG(w, batch)
        return l, g

    dists = []
    for eps in (0.0, 50.0):                    # ζ·ε/n_w stays contractive
        cfg = ISGDConfig(n_batches=4, stop=10, epsilon=eps, zeta=0.01)
        w, _ = solve_subproblem(lg, params, jnp.asarray(1.0), loss0, 0.01, cfg)
        dists.append(float(jnp.linalg.norm(w["w"] - params["w"])))
    assert dists[1] < dists[0]


def test_isgd_equals_sgd_during_warmup():
    """Before one full epoch the limit is +inf, so ISGD ≡ base rule."""
    rule = momentum(0.9)
    cfg = ISGDConfig(n_batches=8)
    params_a = {"w": jnp.arange(4.0)}
    params_b = {"w": jnp.arange(4.0)}
    state_a = isgd_init(rule, cfg, params_a)
    state_b = isgd_init(rule, cfg, params_b)
    batch = {"target": jnp.ones(4)}
    for _ in range(5):
        state_a, params_a, ma = isgd_step(rule, cfg, LG, state_a, params_a,
                                          batch, 0.1)
        state_b, params_b, mb = consistent_step(rule, LG, state_b, params_b,
                                                batch, 0.1)
    np.testing.assert_allclose(params_a["w"], params_b["w"], rtol=1e-6)
    assert int(state_a.accel_count) == 0


def test_isgd_accelerates_outlier_batch():
    """After warm-up, a batch with an outlier loss triggers the subproblem."""
    rule = sgd()
    cfg = ISGDConfig(n_batches=4, k_sigma=1.0, stop=4, zeta=0.05)
    params = {"w": jnp.zeros(2)}
    state = isgd_init(rule, cfg, params)
    easy = {"target": jnp.zeros(2)}
    for _ in range(4):
        state, params, m = isgd_step(rule, cfg, LG, state, params, easy, 0.01)
    assert int(state.accel_count) == 0
    hard = {"target": jnp.full((2,), 50.0)}
    state, params, m = isgd_step(rule, cfg, LG, state, params, hard, 0.01)
    assert bool(m["accelerated"])
    assert int(state.accel_count) == 1
    assert int(m["sub_iters"]) > 0


def test_metrics_surface_complete():
    rule = sgd()
    cfg = ISGDConfig(n_batches=2)
    params = {"w": jnp.zeros(2)}
    state = isgd_init(rule, cfg, params)
    state, params, m = isgd_step(rule, cfg, LG, state, params,
                                 {"target": jnp.ones(2)}, 0.1)
    for k in ("loss", "psi_bar", "psi_std", "limit", "accelerated",
              "sub_iters"):
        assert k in m
