"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.flash_attention.ops import gqa_flash, gqa_ref
from repro.kernels.fused_xent import fused_xent, xent_ref
from repro.kernels.ssd_scan import ssd_chunked_pallas, ssd_ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# fused cross entropy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("N,d,Vp,V,dtype", [
    (128, 64, 512, 500, jnp.float32),
    (256, 32, 1024, 1024, jnp.float32),
    (128, 64, 768, 700, jnp.bfloat16),
    (64, 128, 256, 256, jnp.float32),
])
def test_fused_xent_sweep(N, d, Vp, V, dtype):
    h = jax.random.normal(KEY, (N, d), jnp.float32).astype(dtype)
    w = (jax.random.normal(jax.random.fold_in(KEY, 1), (d, Vp), jnp.float32)
         * 0.05).astype(dtype)
    labels = jax.random.randint(jax.random.fold_in(KEY, 2), (N,), 0, V)
    out = fused_xent(h, w, labels, vocab_size=V, bn=64, bv=256)
    ref = xent_ref(h, w, labels, vocab_size=V)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol)


def test_fused_xent_gold_never_in_padding():
    N, d, Vp, V = 64, 32, 512, 300
    h = jax.random.normal(KEY, (N, d))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (d, Vp)) * 0.05
    labels = jnp.full((N,), V - 1)
    out = fused_xent(h, w, labels, vocab_size=V, bn=64, bv=128)
    assert bool(jnp.isfinite(out).all())


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("BH,S,hd,causal,window,dtype", [
    (4, 256, 64, True, None, jnp.float32),
    (2, 256, 64, True, 64, jnp.float32),
    (2, 128, 32, False, None, jnp.float32),
    (2, 256, 128, True, None, jnp.bfloat16),
    (1, 512, 64, True, 128, jnp.float32),
])
def test_flash_attention_sweep(BH, S, hd, causal, window, dtype):
    q = jax.random.normal(KEY, (BH, S, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (BH, S, hd),
                          jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (BH, S, hd),
                          jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, bq=64, bk=64)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_gqa_wrapper_matches_ref():
    B, S, H, K, hd = 2, 128, 8, 2, 32
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, K, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, K, hd))
    out = gqa_flash(q, k, v, bq=64, bk=64)
    ref = gqa_ref(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_flash_matches_model_attention_path():
    """Kernel ≡ the chunked-scan XLA path used by the models."""
    from repro.models.layers import _attend_chunked
    B, S, H, hd = 1, 256, 4, 32
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, H, hd))
    model_out = _attend_chunked(q, k, v, causal=True, window=32, q_chunk=64)
    kern_out = gqa_flash(q, k, v, causal=True, window=32, bq=64, bk=64)
    np.testing.assert_allclose(model_out, kern_out, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,S,nh,hd,G,ds,chunk", [
    (2, 128, 4, 32, 1, 16, 32),
    (1, 64, 2, 16, 1, 32, 16),
    (2, 128, 4, 32, 2, 16, 64),
])
def test_ssd_kernel_sweep(b, S, nh, hd, G, ds, chunk):
    x = jax.random.normal(KEY, (b, S, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1), (b, S, nh)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 2), (nh,)) * 0.3)
    B = jax.random.normal(jax.random.fold_in(KEY, 3), (b, S, G, ds))
    C = jax.random.normal(jax.random.fold_in(KEY, 4), (b, S, G, ds))
    y1, s1 = ssd_chunked_pallas(x, dt, A, B, C, chunk=chunk)
    y2, s2 = ssd_ref(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(y1, y2, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(s1, s2, rtol=1e-3, atol=1e-3)


def test_ssd_chunking_invariance():
    """The chunked algorithm must be exact: chunk size cannot change results."""
    b, S, nh, hd, ds = 1, 64, 2, 16, 8
    x = jax.random.normal(KEY, (b, S, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1), (b, S, nh)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 2), (nh,)) * 0.3)
    B = jax.random.normal(jax.random.fold_in(KEY, 3), (b, S, 1, ds))
    C = jax.random.normal(jax.random.fold_in(KEY, 4), (b, S, 1, ds))
    y16, s16 = ssd_ref(x, dt, A, B, C, chunk=16)
    y64, s64 = ssd_ref(x, dt, A, B, C, chunk=64)
    np.testing.assert_allclose(y16, y64, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s16, s64, rtol=1e-4, atol=1e-4)


def test_ssd_matches_naive_recurrence():
    """Oracle of the oracle: step-by-step SSM recurrence."""
    b, S, nh, hd, ds = 1, 32, 2, 8, 4
    x = jax.random.normal(KEY, (b, S, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1), (b, S, nh)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 2), (nh,)) * 0.3)
    B = jax.random.normal(jax.random.fold_in(KEY, 3), (b, S, 1, ds))
    C = jax.random.normal(jax.random.fold_in(KEY, 4), (b, S, 1, ds))
    y_ref, s_ref = ssd_ref(x, dt, A, B, C, chunk=8)

    state = np.zeros((b, nh, hd, ds))
    ys = []
    for t in range(S):
        decay = np.exp(np.asarray(dt[:, t]) * np.asarray(A))      # (b, nh)
        xdt = np.asarray(x[:, t]) * np.asarray(dt[:, t])[..., None]
        state = state * decay[..., None, None] + np.einsum(
            "bhp,bd->bhpd", xdt, np.asarray(B[:, t, 0]))
        ys.append(np.einsum("bhpd,bd->bhp", state, np.asarray(C[:, t, 0])))
    y_naive = np.stack(ys, axis=1)
    np.testing.assert_allclose(y_ref, y_naive, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(s_ref, state, rtol=1e-3, atol=1e-3)


def test_fused_xent_custom_vjp_matches_ref():
    """The kernel is trainable: custom VJP ≡ autodiff of the oracle."""
    from repro.kernels.fused_xent.ops import fused_xent_sum, xent_ref_sum
    B, S, d, Vp, V = 2, 64, 32, 512, 500
    h = jax.random.normal(KEY, (B, S, d))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (d, Vp)) * 0.05
    y = jax.random.randint(jax.random.fold_in(KEY, 2), (B, S), 0, V)
    m = jnp.ones((B, S)).at[:, -1].set(0.0)

    def lf(h, w):
        t, c = fused_xent_sum(h, w, y, m, V)
        return t / c

    def lr(h, w):
        t, c = xent_ref_sum(h, w, y, m, V)
        return t / c

    v1, g1 = jax.value_and_grad(lf, argnums=(0, 1))(h, w)
    v2, g2 = jax.value_and_grad(lr, argnums=(0, 1))(h, w)
    assert abs(float(v1 - v2)) < 1e-5
    np.testing.assert_allclose(g1[0], g2[0], rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(g1[1], g2[1], rtol=1e-3, atol=1e-4)


def test_model_trains_with_fused_xent():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("internlm2_1_8b").reduced()
    m1 = build_model(cfg, use_fused_xent=True)
    m2 = build_model(cfg, use_fused_xent=False)
    params = m1.init(KEY, max_seq=32)
    batch = {"tokens": jnp.ones((2, 32), jnp.int32)}
    (l1, _), g1 = jax.value_and_grad(m1.loss_fn, has_aux=True)(params, batch)
    (l2, _), g2 = jax.value_and_grad(m2.loss_fn, has_aux=True)(params, batch)
    assert abs(float(l1 - l2)) < 5e-3
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        # bf16 grads: atol covers ~2 ulp at magnitude ~2 (bf16 eps 2^-8);
        # fused vs reference accumulate in different orders
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=2e-2)
