"""Loss-driven LR schedule (paper §5.2 AlexNet schedule)."""
import pytest

from repro.core.schedule import ALEXNET_SCHEDULE, constant_lr, loss_driven_lr


def test_alexnet_schedule_bands():
    assert float(ALEXNET_SCHEDULE(3.0)) == pytest.approx(0.015)
    assert float(ALEXNET_SCHEDULE(2.0)) == pytest.approx(0.015)
    assert float(ALEXNET_SCHEDULE(1.5)) == pytest.approx(0.0015)
    assert float(ALEXNET_SCHEDULE(0.5)) == pytest.approx(0.00015)


def test_constant():
    fn = constant_lr(0.3)
    assert float(fn(99.0)) == pytest.approx(0.3)


def test_lr_monotone_in_loss():
    fn = loss_driven_lr([2.0, 1.0], [0.1, 0.01, 0.001])
    assert float(fn(5.0)) > float(fn(1.5)) > float(fn(0.1))
