"""Sharding rule engine: divisibility fallbacks (no real mesh needed)."""
from jax.sharding import PartitionSpec as P

from repro.sharding import rules


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_pick_spec_prefers_first_divisible():
    spec = rules.pick_spec(MESH, (64, 4096),
                           [("data", "model"), (None, "model"), (None, None)])
    assert spec == P("data", "model")


def test_pick_spec_falls_back_on_indivisible():
    # mixtral: 8 experts cannot shard over model=16
    spec = rules.pick_spec(MESH, (8, 6144, 16384),
                           [("model", None, None), (None, "data", "model"),
                            (None, None, None)])
    assert spec == P(None, "data", "model")


def test_pick_spec_replicates_when_nothing_fits():
    spec = rules.pick_spec(MESH, (7, 13), [("data", "model"), ("model", None)])
    assert spec == P()


def test_param_spec_embed_sharded_over_model():
    # padded vocab divides 16
    spec = rules.param_spec(MESH, "embed", (92672, 2048))
    assert "model" in str(spec)


def test_param_spec_small_leaf_replicated():
    assert rules.param_spec(MESH, "blocks/0/ln1", (64,)) == P()


def test_param_spec_moe_expert_parallel_when_divisible():
    # deepseek 64 experts over model=16 ✓
    spec = rules.param_spec(MESH, "blocks/0/mlp/wi", (26, 64, 2048, 1408))
    assert spec[1] == "model"
    # mixtral 8 experts — falls back to d_ff sharding
    spec = rules.param_spec(MESH, "blocks/0/mlp/wi", (56, 8, 6144, 16384))
    assert spec[1] != "model"
    assert "model" in tuple(spec)


def test_pod_axis_in_batch_axes():
    assert rules.batch_axes(POD) == ("pod", "data")
    assert rules.batch_axes(MESH) == ("data",)


def test_activation_table_long_context_falls_back_to_seq():
    t = rules.activation_rule_table(POD, global_batch=1, seq_shard=True)
    assert t["hidden"][1] == "data"          # sequence axis sharded
