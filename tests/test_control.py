"""ISGD loss-queue statistics vs a numpy sliding-window oracle."""
import jax.numpy as jnp  # noqa: F401  (kept: queue ops return jnp scalars)
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # hermetic container: test extra
    from _hypothesis_fallback import given, settings, st   # noqa: F401

from repro.core import control


def _run_queue(losses, n_b):
    q = control.init_queue(n_b)
    out = []
    for x in losses:
        q = control.push(q, x)
        out.append((float(control.mean(q)), float(control.std(q)),
                    float(control.control_limit(q))))
    return q, out


@given(st.lists(st.floats(min_value=0.0, max_value=20.0, allow_nan=False,
                          width=32),
                min_size=1, max_size=60),
       st.integers(min_value=1, max_value=12))
@settings(max_examples=50, deadline=None)
def test_queue_matches_sliding_window(losses, n_b):
    _, out = _run_queue(losses, n_b)
    for t, (m, s, lim) in enumerate(out):
        window = np.array(losses[max(0, t + 1 - n_b):t + 1], np.float32)
        assert m == pytest.approx(float(window.mean()), rel=1e-4, abs=1e-4)
        assert s == pytest.approx(float(window.std()), rel=1e-3, abs=1e-3)
        if t + 1 < n_b:
            assert lim == np.inf          # warm-up: never triggers
        else:
            assert lim == pytest.approx(window.mean() + 3 * window.std(),
                                        rel=1e-3, abs=1e-3)


def test_queue_is_o1_memory():
    q = control.init_queue(8)
    assert q.buf.size == 8
    for x in range(100):
        q = control.push(q, float(x))
    assert q.buf.size == 8                 # fixed, independent of iterations


def test_limit_monotone_in_k():
    q = control.init_queue(4)
    for x in [1.0, 2.0, 3.0, 4.0]:
        q = control.push(q, x)
    l2 = float(control.control_limit(q, 2.0))
    l3 = float(control.control_limit(q, 3.0))
    assert l3 > l2 > float(control.mean(q))


def test_ring_eviction_exact():
    q = control.init_queue(3)
    for x in [10.0, 1.0, 1.0, 1.0]:
        q = control.push(q, x)
    # the 10.0 must have been fully evicted
    assert float(control.mean(q)) == pytest.approx(1.0)
    assert float(control.std(q)) == pytest.approx(0.0, abs=1e-5)
