"""Hybrid DP × TP engine (ISSUE 4).

Acceptance invariants:

  * **ψ̄-regression (the headline bugfix)** — the old pjit runner evaluated
    ``lr_fn(0.0)`` instead of ``lr_fn(ψ̄)``, silently freezing the paper's
    loss-driven schedule (Alg.1 line 19) on the tensor-parallel path.  A
    ψ̄-dependent ``lr_fn`` driven through the hybrid engine must reproduce
    ``make_train_step`` bit-exactly over ≥ 2 FCPR epochs — and must differ
    from a deliberately frozen ``lr_fn(0.0)`` run, proving the comparison
    can catch the bug;
  * **engine unification** — the hybrid engine at ``model=1`` is the pure
    data-parallel engine (bit-exact, same shard_map program), and its GSPMD
    strategy at ``data=1`` is the reference program;
  * **mesh hygiene** — ``make_host_mesh`` rejects non-divisible
    model-parallel degrees with a clear ``SystemExit`` instead of an opaque
    ``jax.make_mesh`` error.

The full matrix (including the forced-8-device legs CI pins) lives in
``repro.distributed.hybrid_parity``; the subprocess test below runs it.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ISGDConfig
from repro.data import DeviceRing, FCPRSampler
from repro.distributed import (make_chunked_hybrid_step, make_hybrid_step,
                               run_hybrid_parity, tensor_axes)
from repro.launch.mesh import make_data_mesh, make_host_mesh
from repro.optim import momentum
from repro.train import make_train_step

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
STEPS = 32                      # n_batches=4 -> 8 FCPR epochs


def _problem(batch_size, n_batches=4, dim=6, seed=0):
    rng = np.random.RandomState(seed)
    xs = rng.randn(batch_size * n_batches, dim).astype(np.float32)
    ys = ((xs @ rng.randn(dim, 1).astype(np.float32)).ravel()
          / np.sqrt(dim)).astype(np.float32)
    ys[:batch_size] += 3.0      # outlier batch: the subproblem must fire

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, loss

    params = {"w": jnp.zeros((dim,), jnp.float32),
              "b": jnp.zeros((), jnp.float32)}
    sampler = FCPRSampler({"x": xs, "y": ys}, batch_size=batch_size, seed=1)
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=1.0, stop=3,
                      zeta=0.01)
    return loss_fn, params, sampler, icfg


def _lr_fn(psi_bar):
    # ψ̄-dependent on purpose: regresses the pjit lr_fn(0.0) freeze
    return jnp.asarray(0.01) + 0.001 * jnp.minimum(psi_bar, 1.0)


def _run(step_fn, init_fn, params0, feed, steps=STEPS):
    p = jax.tree.map(jnp.copy, params0)
    s = init_fn(p)
    ms = []
    for j in range(steps):
        s, p, m = step_fn(s, p, feed(j))
        ms.append(jax.tree.map(np.asarray, m))
    stacked = {k: np.stack([m[k] for m in ms]) for k in ms[0]}
    return s, p, stacked


def _assert_bit_exact(ref, got, ref_p, got_p):
    for key in ("loss", "limit", "psi_bar", "accelerated", "sub_iters"):
        np.testing.assert_array_equal(ref[key], got[key], err_msg=key)
    for a, b in zip(jax.tree.leaves(ref_p), jax.tree.leaves(got_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ref["accelerated"].sum() > 0, "subproblem never fired"


# ---------------------------------------------------------------------------
# the headline regression: ψ̄-driven LR through the hybrid engine
# ---------------------------------------------------------------------------
def test_hybrid_psi_lr_bit_exact_vs_per_step_and_catches_freeze():
    """hybrid(1,1) ≡ make_train_step under a ψ̄-dependent lr_fn, and a
    lr_fn(0.0)-frozen run differs — the exact bug the old run_pjit had."""
    loss_fn, params0, sampler, icfg = _problem(batch_size=8)
    rule = momentum(0.9)
    feed = lambda j: {k: jnp.asarray(v)            # noqa: E731
                      for k, v in sampler(j).items()}

    init_fn, step = make_train_step(loss_fn, rule, icfg, lr_fn=_lr_fn,
                                    donate=False)
    ref_s, ref_p, ref = _run(step, init_fn, params0, feed)

    mesh = make_host_mesh(model=1, devices=[jax.devices()[0]])
    assert tensor_axes(mesh) == ()
    hinit, hstep = make_hybrid_step(loss_fn, rule, icfg, mesh, lr_fn=_lr_fn,
                                    donate=False)
    got_s, got_p, got = _run(hstep, hinit, params0, feed)
    _assert_bit_exact(ref, got, ref_p, got_p)
    assert int(ref_s.accel_count) == int(got_s.accel_count)

    # the trap the matrix must catch: a frozen schedule diverges
    finit, fstep = make_hybrid_step(loss_fn, rule, icfg, mesh,
                                    lr_fn=lambda _: _lr_fn(0.0),
                                    donate=False)
    _, froz_p, _ = _run(fstep, finit, params0, feed)
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(ref_p), jax.tree.leaves(froz_p)))


def test_hybrid_model1_bit_exact_vs_data_parallel():
    """The unification claim: hybrid on (data=n, model=1) IS the pure
    data-parallel engine (same manual shard_map program)."""
    n_dev = len(jax.devices())
    loss_fn, params0, sampler, icfg = _problem(batch_size=8 * n_dev)
    rule = momentum(0.9)
    feed = lambda j: {k: jnp.asarray(v)            # noqa: E731
                      for k, v in sampler(j).items()}

    dinit, dstep = make_hybrid_step(loss_fn, rule, icfg, make_data_mesh(),
                                    lr_fn=_lr_fn, donate=False)
    ref_s, ref_p, ref = _run(dstep, dinit, params0, feed)

    hinit, hstep = make_hybrid_step(loss_fn, rule, icfg,
                                    make_host_mesh(model=1),
                                    lr_fn=_lr_fn, donate=False)
    got_s, got_p, got = _run(hstep, hinit, params0, feed)
    _assert_bit_exact(ref, got, ref_p, got_p)


def test_hybrid_pure_tp_gspmd_bit_exact_vs_per_step():
    """hybrid on (data=1, model=n): the GSPMD strategy.  With the tiny
    test params replicated the global program is the reference program."""
    n_dev = len(jax.devices())
    loss_fn, params0, sampler, icfg = _problem(batch_size=8)
    rule = momentum(0.9)
    feed = lambda j: {k: jnp.asarray(v)            # noqa: E731
                      for k, v in sampler(j).items()}

    init_fn, step = make_train_step(loss_fn, rule, icfg, lr_fn=_lr_fn,
                                    donate=False)
    _, ref_p, ref = _run(step, init_fn, params0, feed)

    mesh = make_host_mesh(model=n_dev)
    assert tensor_axes(mesh) == (() if n_dev == 1 else ("model",))
    hinit, hstep = make_hybrid_step(loss_fn, rule, icfg, mesh, lr_fn=_lr_fn,
                                    donate=False)
    _, got_p, got = _run(hstep, hinit, params0, feed)
    _assert_bit_exact(ref, got, ref_p, got_p)


def test_chunked_hybrid_bit_exact_vs_per_step_hybrid():
    """The fused K=4 leg on the hybrid mesh (manual strategy): scan over
    the data-sub-axis-sharded ring ≡ the per-step hybrid engine."""
    n_dev = len(jax.devices())
    loss_fn, params0, sampler, icfg = _problem(batch_size=8 * n_dev)
    rule = momentum(0.9)
    mesh = make_host_mesh(model=1)
    ring = DeviceRing(sampler.epoch_arrays(), sampler.batch_size, mesh=mesh)

    hinit, hstep = make_hybrid_step(loss_fn, rule, icfg, mesh, lr_fn=_lr_fn,
                                    donate=False)
    _, ref_p, ref = _run(hstep, hinit, params0, ring)

    cinit, chunk = make_chunked_hybrid_step(loss_fn, rule, icfg, mesh,
                                            chunk_steps=4, lr_fn=_lr_fn,
                                            donate=False)
    p = jax.tree.map(jnp.copy, params0)
    s = cinit(p)
    outs = []
    for c in range(STEPS // 4):
        s, p, ms = chunk(s, p, ring.arrays, c * 4)
        outs.append(jax.tree.map(np.asarray, ms))
    got = {k: np.concatenate([o[k] for o in outs]) for k in outs[0]}
    _assert_bit_exact(ref, got, ref_p, p)


# ---------------------------------------------------------------------------
# mesh hygiene + ring on a 2-D mesh
# ---------------------------------------------------------------------------
def test_make_host_mesh_rejects_non_divisible_model_parallel():
    """Library code raises MeshError (a ValueError) — never SystemExit;
    only the CLI boundary in launch/train.py translates to an exit code."""
    from repro.launch.mesh import MeshError
    n = len(jax.devices())
    with pytest.raises(MeshError, match=f"n={n} devices, M={2 * n}"):
        make_host_mesh(model=2 * n)
    assert issubclass(MeshError, ValueError)
    with pytest.raises(MeshError, match="M=0"):
        make_host_mesh(model=0)
    mesh = make_host_mesh(model=n)          # every divisor is fine
    assert dict(mesh.shape) == {"data": 1, "model": n}


def test_device_ring_on_2d_mesh_serves_global_batches():
    """Both ring layouts on the hybrid (data, model) mesh reproduce the
    host sampler: the relayout keys on the data sub-axis only."""
    mesh = make_host_mesh(model=1)
    n_data = mesh.shape["data"]
    _, _, sampler, _ = _problem(batch_size=4 * n_data, n_batches=3)
    for relayout in (True, False):
        ring = DeviceRing(sampler.epoch_arrays(), sampler.batch_size,
                          mesh=mesh, relayout=relayout)
        assert ring.n_devices == n_data
        for j in range(7):                  # wraps the cycle twice
            got, want = ring(j), sampler(j)
            for k in want:
                np.testing.assert_array_equal(np.asarray(got[k]), want[k])


# ---------------------------------------------------------------------------
# the full matrix: in-process + forced 8 devices
# ---------------------------------------------------------------------------
def test_hybrid_parity_inprocess():
    r = run_hybrid_parity(steps=STEPS, K=4)
    assert r["ok"], r
    assert r["accelerations"] > 0


def test_hybrid_parity_subprocess_8_devices():
    """The acceptance-criteria check: the whole parity matrix under 8
    forced host devices — (8,1) vs data-parallel, (1,8) GSPMD, chunked
    K=4, the genuinely model-sharded leg — in a fresh interpreter."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)   # parity sets the device-count flag itself
    proc = subprocess.run(
        [sys.executable, "-m", "repro.distributed.hybrid_parity",
         "--devices", "8", "--steps", "32"],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "devices=8" in proc.stdout
