"""Per-architecture smoke tests (deliverable f): each assigned arch's REDUCED
variant (2 layers, d_model<=256, <=4 experts) runs one forward/train step and
one decode step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import (ARCH_IDS, ZOO_MODELS, ZOO_TIERS, get_config,
                           zoo_config)
from repro.core import ISGDConfig
from repro.models import build_model
from repro.optim import momentum
from repro.train import make_step_core

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    batch = {"tokens": jnp.clip(jnp.arange(B * S).reshape(B, S) % 97, 0,
                                cfg.vocab_size - 1).astype(jnp.int32)}
    if cfg.family == "vlm":
        batch["frontend_embeds"] = 0.01 * jnp.ones(
            (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frontend_embeds"] = 0.01 * jnp.ones(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= max(2, cfg.block_size())
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    model = build_model(cfg)
    params = model.init(KEY, max_seq=64)
    batch = _batch(cfg)

    (loss, data_loss), grads = jax.value_and_grad(
        model.loss_fn, has_aux=True)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    assert float(data_loss) > 0.0
    # gradient must reach every trainable leaf
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.all(jnp.isfinite(g))), path


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY, max_seq=64)
    B, S = 2, 16
    cache = model.init_cache(B, S)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache = model.decode_fn(params, cache, tok)
    logits2, cache = model.decode_fn(params, cache, tok)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache["t"]) == 2


ZOO_CASES = [
    pytest.param(m, t, marks=[pytest.mark.slow] if t == "base" else [],
                 id=f"{m}-{t}")
    for m in ZOO_MODELS for t in ZOO_TIERS
]


@pytest.mark.parametrize("model_name,tier", ZOO_CASES)
def test_zoo_step_core(model_name, tier):
    """One full ISGD forward+backward per zoo body through make_step_core
    (the shared contract every engine wraps) — finite loss, f32 ψ stats,
    gradient reaching every leaf.  ``base`` tiers are real single-host
    configs (0.1–0.5B params) and run only under the ``slow`` marker."""
    cfg = zoo_config(model_name, tier)
    model = build_model(cfg)
    B, S = (2, 32) if tier == "tiny" else (1, 16)
    params = model.init(KEY, max_seq=S)
    batch = {"tokens": jnp.clip(jnp.arange(B * S).reshape(B, S) % 97, 0,
                                cfg.vocab_size - 1).astype(jnp.int32)}
    icfg = ISGDConfig(n_batches=2, k_sigma=1.0, stop=2, zeta=0.01)
    init_fn, step_fn = make_step_core(
        model.loss_fn, momentum(0.9), icfg,
        lr_fn=lambda p: jnp.asarray(0.05) + 0.0 * p)
    state = init_fn(params)
    state, params, m = jax.jit(step_fn)(state, params, batch)
    assert m["loss"].dtype == jnp.float32
    assert bool(jnp.isfinite(m["loss"]))
    assert state.queue.buf.dtype == jnp.float32
    for path, w in jax.tree_util.tree_flatten_with_path(params)[0]:
        assert bool(jnp.all(jnp.isfinite(w))), path


@pytest.mark.parametrize("arch", ["internlm2_1_8b", "mamba2_2_7b",
                                  "mixtral_8x22b"])
def test_two_train_steps_reduce_loss(arch):
    """A couple of SGD steps on a fixed batch must descend."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY, max_seq=64)
    batch = _batch(cfg)
    vag = jax.jit(jax.value_and_grad(model.loss_fn, has_aux=True))
    (l0, _), g = vag(params, batch)
    for _ in range(3):
        params = jax.tree.map(
            lambda w, d: (w.astype(jnp.float32)
                          - 0.5 * d.astype(jnp.float32)).astype(w.dtype),
            params, g)
        (l1, _), g = vag(params, batch)
    assert float(l1) < float(l0)
