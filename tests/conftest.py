import os
import sys

# Tests run single-device CPU (do NOT set xla_force_host_platform_device_count
# here — only the dry-run uses 512 placeholder devices).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
