"""Pallas-vs-reference numerics gate as a pytest surface.

One test per (kernel, dtype, shape) cell of ``repro.kernels.numerics`` —
the same matrix CI runs standalone (``python -m repro.kernels.numerics``);
here each cell is an individually reportable/deselectable test, and the
tolerance table lives in exactly one place (``numerics.TOLERANCES``).
"""
import pytest

from repro.kernels.numerics import check_case, iter_cases

CASES = list(iter_cases())


@pytest.mark.parametrize(
    "kernel,dtype,shape", CASES,
    ids=[f"{k}-{d}-{'x'.join(str(s) for s in shape)}"
         for k, d, shape in CASES])
def test_kernel_matches_reference(kernel, dtype, shape):
    r = check_case(kernel, dtype, shape)
    assert r["ok"], (
        f"{kernel} {dtype} {shape}: max_abs={r['max_abs']:.3e} "
        f"max_rel={r['max_rel']:.3e} exceeds "
        f"tol=({r['rtol']:g},{r['atol']:g})")
