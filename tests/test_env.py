"""launch/env.py: XLA flag composition + process-topology wiring.

The three guarantees the module docstring promises — append-never-clobber,
idempotent re-entry, single init — plus the CLI argument surface.  All
flag tests run against an explicit ``env=`` dict so nothing here touches
the real ``os.environ`` or initializes a jax backend.
"""
import argparse

import pytest

from repro.launch import env as ENV


# ---------------------------------------------------------------------------
# apply_xla_flags: append, never clobber
# ---------------------------------------------------------------------------
def test_apply_appends_after_user_flags():
    e = {"XLA_FLAGS": "--xla_user_thing=7"}
    out = ENV.apply_xla_flags(["--xla_new=1"], env=e)
    assert out == "--xla_user_thing=7 --xla_new=1"
    assert e["XLA_FLAGS"] == out


def test_user_set_flag_wins_by_default():
    e = {"XLA_FLAGS": "--xla_knob=user"}
    ENV.apply_xla_flags(["--xla_knob=ours", "--xla_other=1"], env=e)
    assert e["XLA_FLAGS"] == "--xla_knob=user --xla_other=1"


def test_override_replaces_in_place():
    e = {"XLA_FLAGS": "--xla_a=1 --xla_knob=old --xla_b=2"}
    ENV.apply_xla_flags(["--xla_knob=new"], env=e, override=True)
    # the stale occurrence is removed (not shadowed) and others survive
    assert e["XLA_FLAGS"] == "--xla_a=1 --xla_b=2 --xla_knob=new"


def test_apply_is_idempotent():
    e = {"XLA_FLAGS": "--xla_user_thing=7"}
    once = ENV.apply_xla_flags(list(ENV.GPU_ASYNC_FLAGS), env=e)
    twice = ENV.apply_xla_flags(list(ENV.GPU_ASYNC_FLAGS), env=e)
    assert once == twice == e["XLA_FLAGS"]


def test_apply_from_empty_env():
    e = {}
    ENV.apply_xla_flags(["--xla_a=1"], env=e)
    assert e["XLA_FLAGS"] == "--xla_a=1"


def test_flag_name_strips_value():
    assert ENV._flag_name("--xla_foo=3") == "--xla_foo"
    assert ENV._flag_name("--xla_bar") == "--xla_bar"


# ---------------------------------------------------------------------------
# platform-specific composition
# ---------------------------------------------------------------------------
def test_async_flags_gpu_appends_group():
    e = {"XLA_FLAGS": "--xla_user_thing=7"}
    ENV.apply_async_collective_flags("gpu", env=e)
    for flag in ENV.GPU_ASYNC_FLAGS:
        assert flag in e["XLA_FLAGS"].split()
    assert e["XLA_FLAGS"].split()[0] == "--xla_user_thing=7"


def test_async_flags_cpu_is_noop():
    e = {"XLA_FLAGS": "--xla_user_thing=7"}
    ENV.apply_async_collective_flags("cpu", env=e)
    assert e["XLA_FLAGS"] == "--xla_user_thing=7"


def test_async_flags_platform_from_env_var():
    e = {"JAX_PLATFORMS": "gpu,cpu"}
    ENV.apply_async_collective_flags(env=e)
    assert ENV.GPU_ASYNC_FLAGS[0] in e["XLA_FLAGS"].split()


def test_force_host_device_count_overrides_but_preserves():
    e = {"XLA_FLAGS":
         "--xla_user_thing=7 --xla_force_host_platform_device_count=2"}
    ENV.force_host_device_count(8, env=e)
    assert e["XLA_FLAGS"] == (
        "--xla_user_thing=7 --xla_force_host_platform_device_count=8")
    before = e["XLA_FLAGS"]
    ENV.force_host_device_count(8, env=e)           # idempotent re-entry
    assert e["XLA_FLAGS"] == before


# ---------------------------------------------------------------------------
# topology + CLI surface
# ---------------------------------------------------------------------------
def test_topology_coordinator_is_process_zero():
    assert ENV.ProcessTopology().is_coordinator
    assert ENV.ProcessTopology(process_id=0, num_processes=4).is_coordinator
    assert not ENV.ProcessTopology(process_id=3,
                                   num_processes=4).is_coordinator


def test_add_process_args_roundtrip_single_process():
    ap = argparse.ArgumentParser()
    ENV.add_process_args(ap)
    args = ap.parse_args([])
    topo = ENV.initialize_from_args(args)    # no coordinator -> no-op
    assert topo.num_processes == 1 and topo.is_coordinator


def test_initialize_requires_full_process_spec():
    with pytest.raises(ValueError, match="--num-processes"):
        ENV.initialize_distributed("127.0.0.1:1234")


def test_initialize_rejects_conflicting_reinit(monkeypatch):
    recorded = ENV.ProcessTopology(process_id=0, num_processes=2,
                                   coordinator="127.0.0.1:1234")
    monkeypatch.setattr(ENV, "_TOPOLOGY", recorded)
    # same args: returns the recorded topology, never re-initializes
    assert ENV.initialize_distributed("127.0.0.1:1234", 2, 0) is recorded
    with pytest.raises(RuntimeError, match="already initialized"):
        ENV.initialize_distributed("127.0.0.1:1234", 2, 1)


def test_single_process_call_respects_recorded_topology(monkeypatch):
    recorded = ENV.ProcessTopology(process_id=1, num_processes=2,
                                   coordinator="127.0.0.1:1234")
    monkeypatch.setattr(ENV, "_TOPOLOGY", recorded)
    assert ENV.initialize_distributed() is recorded
