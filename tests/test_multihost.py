"""Multi-host scale-out: process-aware mesh factory + the 2-process
parity harness (ROADMAP: multi-host 3-D mesh).

The headline acceptance check spawns real cooperating jax processes
(gloo CPU collectives) and asserts the 2-proc × 2-device run is bit-exact
with the 1-proc × 4-device reference on every engine leg — see
``repro.distributed.multihost_parity`` for what exactly is compared.
"""
import os
import subprocess
import sys

import pytest

from repro.launch.mesh import (MeshError, data_axes, local_data_block,
                               make_training_mesh)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# process-aware mesh factory (single-process paths; the multi-process paths
# are exercised for real inside the parity subprocesses below)
# ---------------------------------------------------------------------------
def test_training_mesh_single_process_is_2d():
    mesh = make_training_mesh()
    assert mesh.axis_names == ("data", "model")
    assert data_axes(mesh) == ("data",)


def test_training_mesh_rejects_non_divisible():
    with pytest.raises(MeshError, match="n=1 devices, M=7"):
        make_training_mesh(model=7)
    assert issubclass(MeshError, ValueError)     # library raises, CLI exits


def test_local_data_block_single_process_spans_all():
    mesh = make_training_mesh()
    lo, hi, total = local_data_block(mesh)
    assert (lo, hi) == (0, total)
    assert total == mesh.shape["data"]


def test_explicit_pod_must_match_process_count():
    with pytest.raises(MeshError, match="pod"):
        make_training_mesh(pod=2)       # single process cannot fake a pod


# ---------------------------------------------------------------------------
# the acceptance check: 2 procs × 2 devices vs 1 proc × 4 devices
# ---------------------------------------------------------------------------
def test_multihost_parity_2proc_vs_singlehost():
    """Bit-exact params/ψ-queue/accelerate counters on per-step, chunked
    K=32 and sched-fcpr legs; union of per-process DeviceRing stripes ==
    the single-host permuted epoch; SPC queue identical after one epoch."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)   # the harness sets device counts itself
    proc = subprocess.run(
        [sys.executable, "-m", "repro.distributed.multihost_parity",
         "--procs", "2", "--devices-per-proc", "2",
         "--steps", "32", "--chunk-steps", "32"],
        capture_output=True, text=True, env=env, timeout=580)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "-> OK" in proc.stdout
    assert "accelerations=0" not in proc.stdout
