"""Fused multi-step engine + device-resident FCPR ring.

Acceptance invariants for the chunked trainer (ISSUE 2):

  * **bit-exact parity** — the ``lax.scan`` engine reproduces the per-step
    engine's losses, control limits, accelerate decisions, sub-iteration
    counts and final params EXACTLY (``assert_array_equal``, not allclose)
    for K ∈ {1, 4, 32} over ≥ 2 FCPR epochs, single-device and (under the
    CI matrix's XLA_FLAGS) 8 forced devices;
  * **ring equivalence** — a ``DeviceRing`` serves bit-identical batches to
    the host ``FCPRSampler`` across epoch wrap-around, in both unsharded
    and mesh-sharded layouts, and ``ring_or_prefetch`` degrades to the
    ``PrefetchSampler`` (same batches) when the epoch busts the byte budget.

The ψ̄-dependent ``lr_fn`` below is deliberate: it makes the loss-driven LR
read the *previous* step's queue, so any off-by-one in how the scan carries
the queue breaks parity loudly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ISGDConfig
from repro.data import DeviceRing, FCPRSampler, ring_or_prefetch
from repro.data.device_ring import _shard_layout
from repro.distributed import (PrefetchSampler,
                               make_chunked_data_parallel_step,
                               make_data_parallel_step)
from repro.launch.mesh import make_data_mesh
from repro.optim import momentum
from repro.train import TrainLog, make_chunked_train_step, make_train_step

STEPS = 32                      # n_batches=4 -> 8 FCPR epochs


def _problem(batch_size, n_batches=4, dim=6, seed=0):
    rng = np.random.RandomState(seed)
    xs = rng.randn(batch_size * n_batches, dim).astype(np.float32)
    ys = ((xs @ rng.randn(dim, 1).astype(np.float32)).ravel()
          / np.sqrt(dim)).astype(np.float32)
    ys[:batch_size] += 3.0      # outlier batch: the subproblem must fire

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, loss

    params = {"w": jnp.zeros((dim,), jnp.float32),
              "b": jnp.zeros((), jnp.float32)}
    sampler = FCPRSampler({"x": xs, "y": ys}, batch_size=batch_size, seed=1)
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=1.0, stop=3,
                      zeta=0.01)
    return loss_fn, params, sampler, icfg


def _lr_fn(psi_bar):
    # ψ̄-dependent on purpose: catches queue-lag regressions (see module doc)
    return jnp.asarray(0.01) + 0.001 * jnp.minimum(psi_bar, 1.0)


def _run_per_step(step_fn, init_fn, params0, feed, steps):
    p = jax.tree.map(jnp.copy, params0)
    s = init_fn(p)
    ms = []
    for j in range(steps):
        s, p, m = step_fn(s, p, feed(j))
        ms.append(jax.tree.map(np.asarray, m))
    stacked = {k: np.stack([m[k] for m in ms]) for k in ms[0]}
    return s, p, stacked


def _run_chunked(chunk_fn, init_fn, params0, ring_arrays, steps, K):
    assert steps % K == 0
    p = jax.tree.map(jnp.copy, params0)
    s = init_fn(p)
    outs = []
    for c in range(steps // K):
        s, p, ms = chunk_fn(s, p, ring_arrays, c * K)
        outs.append(jax.tree.map(np.asarray, ms))
    stacked = {k: np.concatenate([o[k] for o in outs]) for k in outs[0]}
    return s, p, stacked


def _assert_bit_exact(ref, got, ref_p, got_p, ref_s, got_s):
    for key in ("loss", "limit", "psi_bar", "accelerated", "sub_iters"):
        np.testing.assert_array_equal(ref[key], got[key], err_msg=key)
    for a, b in zip(jax.tree.leaves(ref_p), jax.tree.leaves(got_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(ref_s.accel_count) == int(got_s.accel_count)
    assert int(ref_s.sub_iters) == int(got_s.sub_iters)
    assert ref["accelerated"].sum() > 0, "subproblem never fired"


# ---------------------------------------------------------------------------
# bit-exact parity: single-device engine
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("K", [1, 4, 32])
def test_chunked_bit_exact_vs_per_step(K):
    loss_fn, params0, sampler, icfg = _problem(batch_size=8)
    rule = momentum(0.9)
    init_fn, step = make_train_step(loss_fn, rule, icfg, lr_fn=_lr_fn,
                                    donate=False)
    ref_s, ref_p, ref = _run_per_step(
        step, init_fn, params0,
        lambda j: {k: jnp.asarray(v) for k, v in sampler(j).items()}, STEPS)

    ring = DeviceRing(sampler.epoch_arrays(), sampler.batch_size)
    cinit, chunk = make_chunked_train_step(loss_fn, rule, icfg,
                                           chunk_steps=K, lr_fn=_lr_fn,
                                           donate=False)
    got_s, got_p, got = _run_chunked(chunk, cinit, params0, ring.arrays,
                                     STEPS, K)
    _assert_bit_exact(ref, got, ref_p, got_p, ref_s, got_s)


# ---------------------------------------------------------------------------
# bit-exact parity: shard_map engine (1 device under tier-1, 8 under CI)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("K", [1, 4, 32])
def test_chunked_data_parallel_bit_exact_vs_per_step(K):
    n_dev = len(jax.devices())
    loss_fn, params0, sampler, icfg = _problem(batch_size=8 * n_dev)
    rule = momentum(0.9)
    mesh = make_data_mesh()
    ring = DeviceRing(sampler.epoch_arrays(), sampler.batch_size, mesh=mesh)

    init_fn, step = make_data_parallel_step(loss_fn, rule, icfg, mesh,
                                            lr_fn=_lr_fn, donate=False)
    ref_s, ref_p, ref = _run_per_step(step, init_fn, params0, ring, STEPS)

    cinit, chunk = make_chunked_data_parallel_step(
        loss_fn, rule, icfg, mesh, chunk_steps=K, lr_fn=_lr_fn, donate=False)
    got_s, got_p, got = _run_chunked(chunk, cinit, params0, ring.arrays,
                                     STEPS, K)
    _assert_bit_exact(ref, got, ref_p, got_p, ref_s, got_s)


def test_chunked_consistent_step_runs():
    loss_fn, params0, sampler, icfg = _problem(batch_size=8)
    ring = DeviceRing(sampler.epoch_arrays(), sampler.batch_size)
    cinit, chunk = make_chunked_train_step(
        loss_fn, momentum(0.9), icfg, chunk_steps=4, inconsistent=False,
        lr_fn=_lr_fn, donate=False)
    s, p, ms = _run_chunked(chunk, cinit, params0, ring.arrays, 8, 4)
    assert not ms["accelerated"].any()
    assert np.isfinite(ms["loss"]).all()


def test_chunked_donation_across_chunks():
    """The production configuration: donated (state, params) carried chunk
    to chunk — donated inputs must not be reused by the caller."""
    loss_fn, params0, sampler, icfg = _problem(batch_size=8)
    ring = DeviceRing(sampler.epoch_arrays(), sampler.batch_size)
    cinit, chunk = make_chunked_train_step(loss_fn, momentum(0.9), icfg,
                                           chunk_steps=4, lr_fn=_lr_fn)
    p = jax.tree.map(jnp.copy, params0)
    s = cinit(p)
    for c in range(4):
        s, p, ms = chunk(s, p, ring.arrays, c * 4)
    assert np.isfinite(np.asarray(ms["loss"])).all()


# ---------------------------------------------------------------------------
# ring vs host sampler
# ---------------------------------------------------------------------------
def test_ring_matches_host_sampler_across_epochs():
    _, _, sampler, _ = _problem(batch_size=8, n_batches=3)
    ring = DeviceRing(sampler.epoch_arrays(), sampler.batch_size)
    assert (ring.n_batches, ring.batch_size) == (3, 8)
    for j in range(8):                      # wraps the cycle twice
        got, want = ring(j), sampler(j)
        assert ring.batch_index(j) == sampler.batch_index(j)
        for k in want:
            assert isinstance(got[k], jax.Array)
            np.testing.assert_array_equal(np.asarray(got[k]), want[k])


def test_sharded_ring_matches_host_sampler():
    mesh = make_data_mesh()
    n_dev = mesh.shape["data"]
    _, _, sampler, _ = _problem(batch_size=4 * n_dev, n_batches=3)
    ring = DeviceRing(sampler.epoch_arrays(), sampler.batch_size, mesh=mesh)
    assert ring.local_batch_size == 4
    for j in range(7):
        got, want = ring(j), sampler(j)
        for k in want:
            np.testing.assert_array_equal(np.asarray(got[k]), want[k])


def test_shard_layout_roundtrip():
    """Device d's contiguous block holds its slice of every batch in cycle
    order — the invariant the in-scan local dynamic_slice depends on."""
    n_b, n_dev, bsl = 3, 4, 2
    v = np.arange(n_b * n_dev * bsl * 5).reshape(n_b * n_dev * bsl, 5)
    out = _shard_layout(v, n_b, n_dev)
    bs = n_dev * bsl
    for d in range(n_dev):
        block = out[d * n_b * bsl:(d + 1) * n_b * bsl]
        for t in range(n_b):
            np.testing.assert_array_equal(
                block[t * bsl:(t + 1) * bsl],
                v[t * bs + d * bsl: t * bs + (d + 1) * bsl])


def test_ring_or_prefetch_fallback_and_promotion():
    _, _, sampler, _ = _problem(batch_size=8, n_batches=3)
    fb = ring_or_prefetch(sampler, byte_budget=16)       # epoch >> 16 bytes
    assert isinstance(fb, PrefetchSampler)
    ring = ring_or_prefetch(sampler, byte_budget=None)   # None = always fits
    assert isinstance(ring, DeviceRing)
    big = ring_or_prefetch(sampler,
                           byte_budget=sampler.epoch_nbytes())
    assert isinstance(big, DeviceRing)
    # the budget is per replica: a sharded ring only needs 1/n_dev per device
    mesh = make_data_mesh()
    n_dev = mesh.shape["data"]
    per_replica = -(-sampler.epoch_nbytes() // n_dev)
    assert isinstance(
        ring_or_prefetch(sampler, mesh=mesh, byte_budget=per_replica),
        DeviceRing)
    assert isinstance(
        ring_or_prefetch(sampler, mesh=mesh,
                         byte_budget=(sampler.epoch_nbytes() - n_dev) // n_dev),
        PrefetchSampler)
    for j in range(5):                     # both paths: identical batches
        want = sampler(j)
        for k in want:
            np.testing.assert_array_equal(np.asarray(fb(j)[k]), want[k])
            np.testing.assert_array_equal(np.asarray(ring(j)[k]), want[k])


# ---------------------------------------------------------------------------
# zero-copy sampler contract + TrainLog.extend
# ---------------------------------------------------------------------------
def test_fcpr_batches_are_contiguous_zero_copy_views():
    _, _, sampler, _ = _problem(batch_size=8)
    epoch = sampler.epoch_arrays()
    for v in epoch.values():
        assert v.flags["C_CONTIGUOUS"]
    b = sampler(1)
    for k, v in b.items():
        assert v.flags["C_CONTIGUOUS"]
        assert np.shares_memory(v, epoch[k])            # view, not copy
    assert sampler.epoch_nbytes() == sum(v.nbytes for v in epoch.values())


def test_explicit_batches_epoch_arrays():
    from repro.data import ExplicitBatches
    batches = [{"x": np.full((2, 3), i, np.float32)} for i in range(3)]
    eb = ExplicitBatches(batches)
    epoch = eb.epoch_arrays()
    assert epoch["x"].shape == (6, 3)
    ring = DeviceRing(epoch, eb.batch_size)
    for j in range(5):
        np.testing.assert_array_equal(np.asarray(ring(j)["x"]),
                                      eb(j)["x"])


def test_trainlog_extend_matches_append():
    loss_fn, params0, sampler, icfg = _problem(batch_size=8)
    init_fn, step = make_train_step(loss_fn, momentum(0.9), icfg,
                                    lr_fn=_lr_fn, donate=False)
    _, _, stacked = _run_per_step(
        step, init_fn, params0,
        lambda j: {k: jnp.asarray(v) for k, v in sampler(j).items()}, 8)

    ref = TrainLog()
    for i in range(8):
        ref.append({k: v[i] for k, v in stacked.items() if k != "aux"}, 0.5)
    got = TrainLog()
    got.extend(stacked, 0.5)
    assert got.losses == ref.losses
    assert got.limits == ref.limits
    assert got.psi_bar == ref.psi_bar
    assert got.accelerated == ref.accelerated
    assert got.sub_iters == ref.sub_iters
    assert got.wall == [0.5] * 8
    # chunk-end walls are estimates; per-step appends default to real walls
    assert got.wall_est == [True] * 8
    assert ref.wall_est == [False] * 8
