"""Unified telemetry subsystem (``repro.obs``).

Acceptance invariants:

  * **zero-sync hot path** — enabling obs on the fused chunked engine adds
    ZERO host dispatches (counting-wrapper proof, the ``compile_counts``
    style) and costs < 3% steps/s on a smoke bench;
  * **bit-exact SPC reconcile** — the exported control chart (per-batch ψ
    table, Σ, Σ², count, ring index — f32 bit patterns) and the
    accelerate-event records reconcile exactly with the final
    ``ISGDState`` for the per-step, fused-chunk and scheduled (table-mode)
    engines;
  * **schema round-trip** — every emitted record passes
    ``validate_record`` and survives the JSONL round-trip;
  * **process tagging** — a real ``launch.train --obs-dir`` run under 8
    forced devices writes schema-valid, process-tagged JSONL whose
    ``spc.final`` verdict is reconciled (the acceptance smoke).
"""
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ISGDConfig
from repro.data import DeviceRing, FCPRSampler
from repro.obs import (CONSOLE, Console, JsonlSink, MemorySink,
                       MetricsRecorder, StepTimer, TrainObserver,
                       jsonl_path, percentile, read_jsonl,
                       require_measured_walls, summarize, validate_record,
                       write_merged_summary)
from repro.obs.timing import EstimatedWallError
from repro.optim import momentum
from repro.sched import LossPropSchedule
from repro.train import (make_chunked_train_step, make_scheduled_train_step,
                         make_train_step)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
STEPS = 32


def _problem(batch_size, n_batches=4, dim=6, seed=0):
    """test_sched's linear-regression fixture: one outlier batch so the
    accelerate subproblem fires inside the window."""
    rng = np.random.RandomState(seed)
    xs = rng.randn(batch_size * n_batches, dim).astype(np.float32)
    ys = ((xs @ rng.randn(dim, 1).astype(np.float32)).ravel()
          / np.sqrt(dim)).astype(np.float32)
    ys[:batch_size] += 3.0

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, loss

    params = {"w": jnp.zeros((dim,), jnp.float32),
              "b": jnp.zeros((), jnp.float32)}
    sampler = FCPRSampler({"x": xs, "y": ys}, batch_size=batch_size, seed=1)
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=1.0, stop=3,
                      zeta=0.01)
    return loss_fn, params, sampler, icfg


def _lr_fn(psi_bar):
    return jnp.asarray(0.01) + 0.001 * jnp.minimum(psi_bar, 1.0)


def _observer(**kw):
    sink = MemorySink()
    rec = MetricsRecorder([sink], tags={"process_id": 0, "engine": "test"})
    return TrainObserver(rec, **kw), sink


# ------------------------------------------------------ SPC reconcile

def test_spc_reconciles_per_step_engine():
    loss_fn, params0, sampler, icfg = _problem(8)
    init_fn, step = make_train_step(loss_fn, momentum(0.9), icfg, lr_fn=_lr_fn)
    p = jax.tree.map(jnp.copy, params0)
    s = init_fn(p)
    obs, sink = _observer(n_batches=icfg.n_batches, k_sigma=icfg.k_sigma)
    for j in range(STEPS):
        s, p, m = step(s, p, sampler(j))
        obs.defer(j, m)
    obs.flush()
    verdict = obs.spc.reconcile(s)
    assert verdict["reconciled"], verdict["mismatches"]
    # the window saw the outlier: accelerations happened and every exported
    # event is engine-reported, so they sum to the engine counters exactly
    assert obs.spc.accel_count == int(np.asarray(s.accel_count)) > 0
    assert obs.spc.sub_iters == int(np.asarray(s.sub_iters))
    assert len(sink.by_name("spc.accelerate")) == obs.spc.accel_count


def test_spc_reconciles_chunked_engine_bitwise():
    loss_fn, params0, sampler, icfg = _problem(8)
    K = 8
    init_fn, chunk = make_chunked_train_step(loss_fn, momentum(0.9), icfg,
                                             chunk_steps=K, lr_fn=_lr_fn)
    ring = DeviceRing(sampler.epoch_arrays(), sampler.batch_size)
    p = jax.tree.map(jnp.copy, params0)
    s = init_fn(p)
    obs, _ = _observer(n_batches=icfg.n_batches, k_sigma=icfg.k_sigma)
    for c in range(STEPS // K):
        s, p, ms = chunk(s, p, ring.arrays, c * K)
        obs.chunk(c * K, ms)
    verdict = obs.spc.reconcile(s)
    assert verdict["reconciled"], verdict["mismatches"]
    # bitwise: the f32 mirror's ring buffer equals the device queue's
    np.testing.assert_array_equal(
        obs.spc.buf.view(np.uint32),
        np.asarray(s.queue.buf, np.float32).view(np.uint32))
    assert int(obs.recorder.total("train/dispatches")) == STEPS // K
    assert int(obs.recorder.total("train/steps")) == STEPS


def test_spc_reconciles_sched_table_engine():
    """uses_table policies re-key the queue per batch (control.push_at);
    the table-mode mirror replays that discipline bit-exactly."""
    loss_fn, params0, sampler, icfg = _problem(8)
    lp = LossPropSchedule(eps=0.2)
    ring = DeviceRing(sampler.epoch_arrays(), sampler.batch_size)
    init_fn, sfn = make_scheduled_train_step(loss_fn, momentum(0.9), icfg, lp,
                                             lr_fn=_lr_fn)
    p = jax.tree.map(jnp.copy, params0)
    s = init_fn(p)
    ss = lp.init(icfg.n_batches)
    obs, sink = _observer(n_batches=icfg.n_batches, k_sigma=icfg.k_sigma,
                          table=True)
    for j in range(STEPS):
        s, p, ss, m = sfn(s, p, ss, ring.arrays, j)
        obs.defer(j, m)
    obs.flush()
    verdict = obs.spc.reconcile(s)
    assert verdict["reconciled"], verdict["mismatches"]
    # selection histogram covers every batch (loss-prop's ε-mix) and the
    # visit counts sum to the step count
    payload = obs.finalize(s, steps=STEPS, wall=1.0)
    assert payload["reconciled"]
    ev = sink.by_name("sched.visits")
    assert len(ev) == 1
    counts = ev[0]["data"]["counts"]
    assert sum(counts) == STEPS and all(c > 0 for c in counts)


def test_finalize_idempotent_and_final_event():
    loss_fn, params0, sampler, icfg = _problem(8)
    init_fn, step = make_train_step(loss_fn, momentum(0.9), icfg, lr_fn=_lr_fn)
    p = jax.tree.map(jnp.copy, params0)
    s = init_fn(p)
    obs, sink = _observer(n_batches=icfg.n_batches, k_sigma=icfg.k_sigma,
                          examples_per_step=8)
    for j in range(8):
        s, p, m = step(s, p, sampler(j))
        obs.defer(j, m)
    payload = obs.finalize(s, steps=8, wall=2.0)
    assert payload is obs.finalize(s, steps=8, wall=2.0)   # idempotent
    final = sink.by_name("spc.final")
    assert len(final) == 1
    data = final[0]["data"]
    assert data["reconciled"] and data["steps"] == 8
    assert data["engine_counters"]["iter"] == 8
    assert data["throughput"]["steps_per_s"] == pytest.approx(4.0)


# ---------------------------------------------------- zero-sync hot path

def test_chunked_obs_adds_zero_dispatches():
    """The compile_counts-style proof: with obs enabled, K=32 steps still
    run in exactly one host dispatch per chunk."""
    loss_fn, params0, sampler, icfg = _problem(8)
    K = 32
    init_fn, chunk = make_chunked_train_step(loss_fn, momentum(0.9), icfg,
                                             chunk_steps=K, lr_fn=_lr_fn)
    ring = DeviceRing(sampler.epoch_arrays(), sampler.batch_size)
    calls = [0]

    def counting(*a):
        calls[0] += 1
        return chunk(*a)

    p = jax.tree.map(jnp.copy, params0)
    s = init_fn(p)
    obs, _ = _observer(n_batches=icfg.n_batches, k_sigma=icfg.k_sigma)
    steps = 64
    for c in range(steps // K):
        s, p, ms = counting(s, p, ring.arrays, c * K)
        obs.chunk(c * K, ms)
    assert calls[0] == steps // K             # 64 steps -> 2 dispatches
    assert int(obs.recorder.total("train/dispatches")) == steps // K
    assert obs.spc.reconcile(s)["reconciled"]


def test_chunked_obs_overhead_under_3_percent():
    """Smoke bench: obs-enabled steps/s within 3% of obs-off (best of 3
    runs each, same compiled fn, warmup excluded).  The model is sized so
    the chunk dispatch carries real compute (a 256x256 layer) — obs
    ingestion is a fixed ~µs/step host cost, so the trivial-matvec fixture
    would measure only that constant, not the hot-path contract."""
    rng = np.random.RandomState(0)
    n_batches, bs, dim = 4, 256, 256
    xs = rng.randn(bs * n_batches, dim).astype(np.float32)
    ys = rng.randn(bs * n_batches, dim).astype(np.float32)

    def loss_fn(params, batch):
        loss = jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)
        return loss, loss

    params0 = {"w": jnp.zeros((dim, dim), jnp.float32)}
    sampler = FCPRSampler({"x": xs, "y": ys}, batch_size=bs, seed=1)
    icfg = ISGDConfig(n_batches=n_batches, k_sigma=1.0, stop=3, zeta=0.01)
    K = 32
    init_fn, chunk = make_chunked_train_step(loss_fn, momentum(0.9), icfg,
                                             chunk_steps=K, lr_fn=_lr_fn,
                                             donate=False)
    ring = DeviceRing(sampler.epoch_arrays(), sampler.batch_size)
    chunks = 4

    def run(with_obs):
        p = jax.tree.map(jnp.copy, params0)
        s = init_fn(p)
        obs = None
        if with_obs:
            obs, _ = _observer(n_batches=icfg.n_batches,
                               k_sigma=icfg.k_sigma)
        t0 = time.perf_counter()
        for c in range(chunks):
            s, p, ms = chunk(s, p, ring.arrays, c * K)
            if obs is not None:
                obs.chunk(c * K, ms)
            else:
                jax.block_until_ready(ms["loss"])
        return time.perf_counter() - t0

    run(False)                                 # compile off the clock
    base = min(run(False) for _ in range(3))
    with_obs = min(run(True) for _ in range(3))
    # min-of-3 vs min-of-3 + a 1ms absolute floor keeps CI timer noise out
    assert with_obs <= base * 1.03 + 1e-3, \
        f"obs overhead: {with_obs:.4f}s vs {base:.4f}s baseline"


# ------------------------------------------------------- schema round-trip

def test_record_schema_roundtrip(tmp_path):
    path = str(tmp_path / "m.jsonl")
    mem = MemorySink()
    rec = MetricsRecorder([mem, JsonlSink(path)],
                          tags={"process_id": 3, "engine": "e", "model": "m"})
    rec.counter("train/steps", 5)
    rec.gauge("lr", 0.05)
    rec.observe("lat", 0.1)
    rec.observe("lat", 0.3)
    rec.event("spc.accelerate", step=4, batch=np.int32(2),
              psi_before=np.float32(1.5))
    rec.close()                                # flushes counters/histograms

    disk = read_jsonl(path)
    assert [r["name"] for r in disk] == [r["name"] for r in mem.records]
    for r in disk:
        assert validate_record(r) == [], (r, validate_record(r))
        assert r["tags"] == {"process_id": 3, "engine": "e", "model": "m"}
    kinds = {r["name"]: r["kind"] for r in disk}
    assert kinds == {"train/steps": "counter", "lr": "gauge",
                     "lat": "histogram", "spc.accelerate": "event"}
    lat = next(r for r in disk if r["name"] == "lat")
    assert lat["stats"]["count"] == 2
    assert lat["stats"]["p50"] == pytest.approx(0.2)
    ev = next(r for r in disk if r["name"] == "spc.accelerate")
    assert ev["data"]["batch"] == 2            # numpy scalars JSON-ified
    # seq strictly increasing = a merge key across sinks
    assert [r["seq"] for r in disk] == sorted(r["seq"] for r in disk)


def test_validate_record_rejects_malformed():
    assert validate_record("nope")
    assert validate_record({"v": 1})
    bad = {"v": 2, "kind": "counter", "name": "x", "wall": 0.0, "seq": 0,
           "tags": {"process_id": 0}, "value": 1, "total": 1}
    assert any("v !=" in e for e in validate_record(bad))
    no_total = {"v": 1, "kind": "counter", "name": "x", "wall": 0.0,
                "seq": 0, "tags": {"process_id": 0}, "value": 1}
    assert any("total" in e for e in validate_record(no_total))


def test_merged_summary_sums_processes(tmp_path):
    d = str(tmp_path)
    for pid, n in ((0, 10), (1, 7)):
        rec = MetricsRecorder([JsonlSink(jsonl_path(d, pid))],
                              tags={"process_id": pid})
        rec.counter("train/steps", n)
        rec.flush()
        rec.counter("train/steps", n)          # second interval
        rec.event("noted", pid=pid)
        rec.close()
    out = write_merged_summary(d)
    assert out["counters"]["train/steps"] == 34   # final totals, summed
    assert out["events"]["noted"] == 2
    assert {p["process_id"] for p in out["processes"].values()} == {0, 1}
    with open(os.path.join(d, "summary.json")) as fh:
        assert json.load(fh) == out


# --------------------------------------------------------- stats / timing

def test_percentile_matches_numpy():
    rng = np.random.RandomState(0)
    for n in (1, 2, 5, 100):
        xs = rng.randn(n).tolist()
        for q in (0, 25, 50, 95, 100):
            assert percentile(xs, q) == pytest.approx(
                float(np.percentile(xs, q)))
    assert np.isnan(percentile([], 50))
    s = summarize([1.0, 3.0])
    assert s["count"] == 2 and s["mean"] == 2.0 and s["p95"] == pytest.approx(2.9)
    assert summarize([]) == {"count": 0}


def test_require_measured_walls():
    require_measured_walls([False, False])
    require_measured_walls([])
    with pytest.raises(EstimatedWallError, match="2/3"):
        require_measured_walls([True, False, True], context="unit")


def test_step_timer_spans_and_throughput():
    t = [0.0]
    timer = StepTimer(clock=lambda: t[0])
    with timer.span("train"):
        t[0] += 2.0
    with timer.span("train"):                  # re-entry accumulates
        t[0] += 2.0
    out = timer.throughput("train", steps=16, examples=128, dispatches=4)
    assert out["wall_s"] == 4.0 and not out["wall_est"]
    assert out["steps_per_s"] == 4.0 and out["examples_per_s"] == 32.0
    assert out["dispatches"] == 4
    timer.add("est", 1.0, estimated=True)
    assert timer.throughput("est", steps=1)["wall_est"]
    with pytest.raises(EstimatedWallError):
        require_measured_walls([timer.estimated("est")])


# ---------------------------------------------------------------- console

def test_console_warn_once_gating(recwarn):
    con = Console(active_fn=lambda: True)
    assert con.warn_once("k", "first") is True
    assert con.warn_once("k", "again") is False      # once per key
    assert len([w for w in recwarn.list]) == 1
    con.reset()
    assert con.warn_once("k", "after reset") is True

    quiet = Console(active_fn=lambda: False)         # non-coordinator
    n0 = len(recwarn.list)
    assert quiet.warn_once("q", "silent") is True    # first fire, but quiet
    assert len(recwarn.list) == n0                   # no warning emitted
    CONSOLE.reset()                                  # don't leak keys


# ------------------------------------------------- acceptance smoke (CLI)

@pytest.mark.slow
def test_launch_train_obs_dir_end_to_end(tmp_path):
    """Real launcher, 8 forced devices, fused chunks: the emitted JSONL is
    schema-valid (the validate CLI exits 0), every record carries the
    process tag, and the spc.final verdict is reconciled — the ISSUE's
    acceptance smoke."""
    obs_dir = str(tmp_path / "obs")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--model", "transformer",
         "--tier", "tiny", "--steps", "16", "--batch", "8", "--seq", "32",
         "--n-seqs", "32", "--chunk-steps", "8", "--obs-dir", obs_dir,
         "--obs-console-every", "8"],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "spc_reconciled=True" in proc.stdout

    val = subprocess.run(
        [sys.executable, "-m", "repro.obs.validate", obs_dir],
        capture_output=True, text=True, env=env, timeout=120)
    assert val.returncode == 0, val.stdout + val.stderr

    records = read_jsonl(jsonl_path(obs_dir, 0))
    assert records, "no obs records written"
    for r in records:
        assert validate_record(r) == []
        assert r["tags"]["process_id"] == 0
        assert r["tags"]["engine"] == "hybrid"
    final = [r for r in records if r["name"] == "spc.final"]
    assert len(final) == 1
    data = final[0]["data"]
    assert data["reconciled"] is True
    assert data["steps"] == 16
    assert data["accel_events"] == data["accel_count"]
    # chunked: one dispatch per K=8 chunk, counted not estimated
    counters = {r["name"]: r["total"] for r in records
                if r["kind"] == "counter"}
    assert counters["train/dispatches"] == 2
    assert counters["train/steps"] == 16
    assert os.path.exists(os.path.join(obs_dir, "summary.json"))
