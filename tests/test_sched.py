"""Batch-scheduling subsystem (ISSUE 5, ``repro.sched``).

Acceptance invariants:

  * **fcpr bit-exactness** — the FCPR policy threaded through the scheduled
    engines (per-step, chunked K ∈ {1, 32}, data-parallel) reproduces the
    hard-wired engines EXACTLY under a ψ̄-dependent ``lr_fn``; the full
    matrix incl. the hybrid strategies lives in ``repro.sched.parity`` /
    ``repro.distributed.hybrid_parity`` (subprocess-pinned at 8 devices);
  * **no starvation** — for any ε > 0, ``loss-prop`` keeps visiting every
    batch (P(pick i) ≥ ε/n_b per draw) even when one batch dominates the
    table — a property test over adversarial tables;
  * **cross-shard determinism** — every data shard draws the same batch
    index at every step (subprocess leg under 8 forced devices);
  * **device residency** — the chunked ``loss-prop`` engine makes exactly
    steps/K host dispatches, selection and table updates never leave the
    device;
  * **SPC-table coupling** — under a ``uses_table`` policy the control
    queue holds the latest loss *per batch* (ψ-window caveat: "one window
    = one epoch" restored as one-entry-per-batch statistics).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except Exception:                                  # pragma: no cover
    from _hypothesis_fallback import given, settings, st   # noqa: F401

from repro.core import ISGDConfig
from repro.core import control
from repro.data import DeviceRing, FCPRSampler
from repro.distributed import (make_chunked_data_parallel_step,
                               make_data_parallel_step)
from repro.launch.mesh import make_data_mesh
from repro.optim import momentum
from repro.sched import (FCPRSchedule, LossPropSchedule, RankSchedule,
                         run_sched_parity, schedule_from_spec)
from repro.train import (make_chunked_train_step, make_scheduled_train_step,
                         make_train_step)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
STEPS = 32                      # n_batches=4 -> 8 FCPR epochs


def _problem(batch_size, n_batches=4, dim=6, seed=0):
    rng = np.random.RandomState(seed)
    xs = rng.randn(batch_size * n_batches, dim).astype(np.float32)
    ys = ((xs @ rng.randn(dim, 1).astype(np.float32)).ravel()
          / np.sqrt(dim)).astype(np.float32)
    ys[:batch_size] += 3.0      # outlier batch: the subproblem must fire

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, loss

    params = {"w": jnp.zeros((dim,), jnp.float32),
              "b": jnp.zeros((), jnp.float32)}
    sampler = FCPRSampler({"x": xs, "y": ys}, batch_size=batch_size, seed=1)
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=1.0, stop=3,
                      zeta=0.01)
    return loss_fn, params, sampler, icfg


def _lr_fn(psi_bar):
    # ψ̄-dependent on purpose: schedule drift moves the LR trajectory
    return jnp.asarray(0.01) + 0.001 * jnp.minimum(psi_bar, 1.0)


def _run_per_step(step_fn, init_fn, params0, feed, steps=STEPS):
    p = jax.tree.map(jnp.copy, params0)
    s = init_fn(p)
    ms = []
    for j in range(steps):
        s, p, m = step_fn(s, p, feed(j))
        ms.append(jax.tree.map(np.asarray, m))
    return s, p, {k: np.stack([m[k] for m in ms]) for k in ms[0]}


def _run_sched(fn, init_fn, schedule, params0, ring_arrays, n_batches,
               steps=STEPS, K=None):
    p = jax.tree.map(jnp.copy, params0)
    s = init_fn(p)
    ss = schedule.init(n_batches)
    out = []
    if K is None:
        for j in range(steps):
            s, p, ss, m = fn(s, p, ss, ring_arrays, j)
            out.append(jax.tree.map(np.asarray, m))
        return s, p, ss, {k: np.stack([m[k] for m in out]) for k in out[0]}
    for c in range(steps // K):
        s, p, ss, ms = fn(s, p, ss, ring_arrays, c * K)
        out.append(jax.tree.map(np.asarray, ms))
    return s, p, ss, {k: np.concatenate([o[k] for o in out])
                      for k in out[0]}


def _assert_bit_exact(ref, got, ref_p, got_p):
    for key in ("loss", "limit", "psi_bar", "accelerated", "sub_iters"):
        np.testing.assert_array_equal(ref[key], got[key], err_msg=key)
    for a, b in zip(jax.tree.leaves(ref_p), jax.tree.leaves(got_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ref["accelerated"].sum() > 0, "subproblem never fired"


# ---------------------------------------------------------------------------
# fcpr policy: bit-exact with the pre-scheduler engines
# ---------------------------------------------------------------------------
def test_sched_fcpr_per_step_bit_exact_vs_train_step():
    loss_fn, params0, sampler, icfg = _problem(batch_size=8)
    rule = momentum(0.9)
    init_fn, step = make_train_step(loss_fn, rule, icfg, lr_fn=_lr_fn,
                                    donate=False)
    _, ref_p, ref = _run_per_step(
        step, init_fn, params0,
        lambda j: {k: jnp.asarray(v) for k, v in sampler(j).items()})

    fcpr = FCPRSchedule()
    ring = DeviceRing(sampler.epoch_arrays(), sampler.batch_size)
    sinit, sstep = make_scheduled_train_step(loss_fn, rule, icfg, fcpr,
                                             lr_fn=_lr_fn, donate=False)
    _, got_p, _, got = _run_sched(sstep, sinit, fcpr, params0, ring.arrays,
                                  icfg.n_batches)
    _assert_bit_exact(ref, got, ref_p, got_p)
    # the policy's realized picks ARE the fixed cycle
    np.testing.assert_array_equal(
        got["batch_idx"], np.arange(STEPS) % icfg.n_batches)


@pytest.mark.parametrize("K", [1, 32])
def test_sched_fcpr_chunked_bit_exact_vs_per_step(K):
    loss_fn, params0, sampler, icfg = _problem(batch_size=8)
    rule = momentum(0.9)
    init_fn, step = make_train_step(loss_fn, rule, icfg, lr_fn=_lr_fn,
                                    donate=False)
    _, ref_p, ref = _run_per_step(
        step, init_fn, params0,
        lambda j: {k: jnp.asarray(v) for k, v in sampler(j).items()})

    fcpr = FCPRSchedule()
    ring = DeviceRing(sampler.epoch_arrays(), sampler.batch_size)
    cinit, chunk = make_chunked_train_step(loss_fn, rule, icfg,
                                           chunk_steps=K, lr_fn=_lr_fn,
                                           donate=False, schedule=fcpr)
    _, got_p, _, got = _run_sched(chunk, cinit, fcpr, params0, ring.arrays,
                                  icfg.n_batches, K=K)
    _assert_bit_exact(ref, got, ref_p, got_p)


def test_sched_fcpr_data_parallel_bit_exact(K=4):
    """Scheduled fcpr on the shard_map engine (1 device under tier-1, 8
    under the CI matrix) ≡ the hard-wired data-parallel engine."""
    n_dev = len(jax.devices())
    loss_fn, params0, sampler, icfg = _problem(batch_size=8 * n_dev)
    rule = momentum(0.9)
    mesh = make_data_mesh()
    ring = DeviceRing(sampler.epoch_arrays(), sampler.batch_size, mesh=mesh)

    init_fn, step = make_data_parallel_step(loss_fn, rule, icfg, mesh,
                                            lr_fn=_lr_fn, donate=False)
    _, ref_p, ref = _run_per_step(step, init_fn, params0, ring)

    fcpr = FCPRSchedule()
    cinit, chunk = make_chunked_data_parallel_step(
        loss_fn, rule, icfg, mesh, chunk_steps=K, lr_fn=_lr_fn,
        donate=False, schedule=fcpr)
    _, got_p, _, got = _run_sched(chunk, cinit, fcpr, params0, ring.arrays,
                                  icfg.n_batches, K=K)
    _assert_bit_exact(ref, got, ref_p, got_p)


# ---------------------------------------------------------------------------
# loss-prop: no starvation (property), device residency, determinism
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(st.floats(min_value=0.05, max_value=0.9),
       st.integers(min_value=0, max_value=10_000),
       st.floats(min_value=1.0, max_value=1e4))
def test_loss_prop_no_starvation(eps, seed, hot_loss):
    """For any ε>0: even with one batch dominating the table, every batch
    is selected within a bounded number of draws (P(miss) ≤ (1-ε/n_b)^T)."""
    n_b = 8
    bound = 600                           # (1 - 0.05/8)^600 < 2.4e-2 worst ε
    lp = LossPropSchedule(eps=eps)
    # adversarial post-warm-up state: batch 0 dwarfs the rest
    state = {"table": jnp.full((n_b,), 1e-6).at[0].set(hot_loss),
             "visits": jnp.ones((n_b,), jnp.int32)}
    base = jax.random.PRNGKey(seed)

    @jax.jit
    def draw_many(state):
        def body(carry, j):
            t, _ = lp.select(state, n_b + j, jax.random.fold_in(base, j))
            return carry, t
        _, ts = jax.lax.scan(body, 0, jnp.arange(bound))
        return ts

    visited = np.unique(np.asarray(draw_many(state)))
    assert len(visited) == n_b, f"starved batches: " \
        f"{sorted(set(range(n_b)) - set(visited.tolist()))} (eps={eps})"


def test_loss_prop_chunked_device_resident_one_dispatch_per_chunk():
    """Selection is fully on device: K=32 steps run in ONE host dispatch,
    metrics (incl. the batch_idx sequence) arrive stacked in one fetch."""
    loss_fn, params0, sampler, icfg = _problem(batch_size=8)
    lp = LossPropSchedule(eps=0.2)
    ring = DeviceRing(sampler.epoch_arrays(), sampler.batch_size)
    cinit, chunk = make_chunked_train_step(
        loss_fn, momentum(0.9), icfg, chunk_steps=32, lr_fn=_lr_fn,
        donate=False, schedule=lp)
    calls = [0]

    def counting(*a):
        calls[0] += 1
        return chunk(*a)

    _, _, ss, got = _run_sched(counting, cinit, lp, params0, ring.arrays,
                               icfg.n_batches, steps=64, K=32)
    assert calls[0] == 2                      # 64 steps -> 2 dispatches
    assert got["batch_idx"].shape == (64,)
    assert int(np.asarray(ss["visits"]).sum()) == 64
    # warm-up sweep then sampling; ε-mix keeps everyone in rotation
    np.testing.assert_array_equal(got["batch_idx"][:icfg.n_batches],
                                  np.arange(icfg.n_batches))
    assert (np.bincount(got["batch_idx"],
                        minlength=icfg.n_batches) > 0).all()


def test_uses_table_spc_reads_per_batch_losses():
    """ψ-window caveat: under a table policy the control queue holds the
    latest loss per *batch* (not the last n_b visits), so ψ̄/limit are
    one-entry-per-batch statistics."""
    loss_fn, params0, sampler, icfg = _problem(batch_size=8)
    lp = LossPropSchedule(eps=0.3)
    ring = DeviceRing(sampler.epoch_arrays(), sampler.batch_size)
    sinit, sstep = make_scheduled_train_step(loss_fn, momentum(0.9), icfg,
                                             lp, lr_fn=_lr_fn, donate=False)
    s, _, _, got = _run_sched(sstep, sinit, lp, params0, ring.arrays,
                              icfg.n_batches, steps=STEPS)
    last = {}
    for t, loss in zip(got["batch_idx"], got["loss"]):
        last[int(t)] = float(loss)
    want = np.array([last[t] for t in range(icfg.n_batches)], np.float32)
    np.testing.assert_allclose(np.asarray(s.queue.buf), want, rtol=0, atol=0)
    assert float(np.asarray(s.queue.total)) == pytest.approx(want.sum(),
                                                             rel=1e-5)


def test_rank_prefers_high_loss_batches():
    n_b = 8
    rk = RankSchedule(pressure=100.0)
    table = jnp.arange(n_b, dtype=jnp.float32)          # batch 7 hottest
    state = {"table": table, "visits": jnp.ones((n_b,), jnp.int32)}
    draws = []
    for j in range(400):
        t, _ = rk.select(state, n_b + j,
                         jax.random.fold_in(jax.random.PRNGKey(0), j))
        draws.append(int(t))
    counts = np.bincount(draws, minlength=n_b)
    assert counts[n_b - 1] > counts[0] * 3              # pressure visible
    assert (counts > 0).all()                           # exp decay: no zeros


# ---------------------------------------------------------------------------
# spec parser + sampler-drop satellite
# ---------------------------------------------------------------------------
def test_schedule_from_spec():
    assert schedule_from_spec("fcpr") == FCPRSchedule()
    lp = schedule_from_spec("loss-prop:eps=0.25,beta=0.75")
    assert (lp.eps, lp.beta) == (0.25, 0.75)
    rk = schedule_from_spec("rank:pressure=42")
    assert isinstance(rk, RankSchedule) and rk.pressure == 42.0
    with pytest.raises(ValueError, match="unknown schedule"):
        schedule_from_spec("lifo")
    with pytest.raises(ValueError, match="malformed"):
        schedule_from_spec("rank:pressure")
    with pytest.raises(TypeError):
        schedule_from_spec("fcpr:eps=0.1")   # fcpr takes no options


def test_fcpr_sampler_reports_dropped_rows():
    xs = {"x": np.arange(10, dtype=np.float32)}
    with pytest.warns(UserWarning, match="drops 2 of 10 rows"):
        s = FCPRSampler(xs, batch_size=4)
    assert s.n_dropped == 2
    assert s.n_batches * s.batch_size == 8
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # divisible: no warning
        s = FCPRSampler(xs, batch_size=5)
    assert s.n_dropped == 0


# ---------------------------------------------------------------------------
# the full matrix: in-process + forced 8 devices (cross-shard determinism)
# ---------------------------------------------------------------------------
def test_sched_parity_inprocess():
    r = run_sched_parity(steps=STEPS)
    assert r["ok"], r
    assert r["accelerations"] > 0


def test_sched_parity_subprocess_8_devices():
    """Acceptance check: fcpr bit-exactness + loss-prop cross-shard
    selection determinism under 8 forced host devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)   # parity sets the device-count flag itself
    proc = subprocess.run(
        [sys.executable, "-m", "repro.sched.parity", "--devices", "8",
         "--steps", "32"],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "devices=8" in proc.stdout


# ---------------------------------------------------------------------------
# control.push_at: the per-batch table write the scheduled step uses
# ---------------------------------------------------------------------------
def test_push_at_replaces_slot_and_tracks_stats():
    q = control.init_queue(3)
    for slot, loss in ((0, 2.0), (1, 4.0), (2, 6.0)):    # warm-up sweep
        q = control.push_at(q, slot, loss)
        assert float(control.control_limit(q)) == (
            pytest.approx(float(control.mean(q) + 3 * control.std(q)))
            if slot == 2 else np.inf)
    assert float(control.mean(q)) == pytest.approx(4.0)
    q = control.push_at(q, 1, 1.0)                       # replace, not FIFO
    np.testing.assert_allclose(np.asarray(q.buf), [2.0, 1.0, 6.0])
    assert float(control.mean(q)) == pytest.approx(3.0)
    assert float(q.total_sq) == pytest.approx(4 + 1 + 36)
    assert int(q.count) == 3                             # stays saturated
