"""Eq.21–24 time-domain batch-size model."""
import numpy as np

from repro.core import batch_model as bm


def test_iter_time_linear_in_batch():
    assert bm.iter_time(1000, 1000.0, 0.1) == np.asarray(1.1)


def test_loss_bound_decreases_in_T():
    assert bm.loss_bound(100, 1000) < bm.loss_bound(100, 100)


def test_predicted_time_has_interior_optimum():
    """Fig.5: fast system (C1 high) with sync cost C2 has optimum at a
    moderate batch, and performance deteriorates for huge batches."""
    cand = np.arange(50, 3050, 50)
    times = bm.predicted_time_to_loss(cand, psi=0.02, c1=3000.0, c2=0.5)
    i = int(np.argmin(times))
    assert 0 < i < len(cand) - 1                       # interior optimum
    assert times[-1] > times[i]                        # unwieldy batch is slower


def test_faster_system_prefers_larger_batch():
    """The paper's Fig.5 observation: a faster system needs a larger batch."""
    b_slow = bm.optimal_batch_size(0.02, c1=1000.0, c2=0.5)
    b_fast = bm.optimal_batch_size(0.02, c1=6000.0, c2=0.5)
    assert b_fast >= b_slow
