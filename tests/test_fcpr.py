"""FCPR sampling invariants (paper §3.4), property-based."""
import numpy as np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # hermetic container: test extra
    from _hypothesis_fallback import given, settings, st   # noqa: F401

from repro.data import FCPRSampler


def _make(n, bs, seed=0, q=1.0):
    data = {"x": np.arange(n * 3, dtype=np.float32).reshape(n, 3),
            "labels": np.arange(n, dtype=np.int32)}
    return FCPRSampler(data, batch_size=bs, seed=seed, shuffle_quality=q)


@given(st.integers(min_value=2, max_value=64), st.integers(min_value=1, max_value=8),
       st.integers(min_value=0, max_value=5))
@settings(max_examples=40, deadline=None)
def test_every_batch_exactly_once_per_epoch(n_batches, bs, seed):
    s = _make(n_batches * bs, bs, seed)
    assert s.n_batches == n_batches
    seen = [s.batch_index(j) for j in range(n_batches)]
    assert sorted(seen) == list(range(n_batches))          # ring covers all


@given(st.integers(min_value=1, max_value=40))
@settings(max_examples=30, deadline=None)
def test_fixed_cycle_identity(j):
    """Iteration j and j+epoch fetch the SAME batch (paper: t = j mod n_d/n_b)."""
    s = _make(24, 4)
    b1 = s(j)
    b2 = s(j + s.n_batches)
    np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_batches_are_disjoint_cover():
    s = _make(30, 5)
    all_labels = np.concatenate([s(j)["labels"] for j in range(s.n_batches)])
    assert sorted(all_labels.tolist()) == sorted(
        s.arrays["labels"].tolist())


def test_shuffle_quality_zero_keeps_order():
    s = _make(20, 5, q=0.0)
    np.testing.assert_array_equal(s.arrays["labels"], np.arange(20))


def test_shuffle_quality_one_permutes():
    s = _make(200, 5, q=1.0)
    assert not np.array_equal(s.arrays["labels"], np.arange(200))
