"""Layer-level properties: causality, sliding window, RoPE, MoE routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # hermetic container: test extra
    from _hypothesis_fallback import given, settings, st   # noqa: F401

from repro.configs import get_config
from repro.models import moe as M
from repro.models.layers import _attend_chunked, apply_rope, rms_norm

KEY = jax.random.PRNGKey(0)


def test_causality_future_token_cannot_leak():
    B, S, H, hd = 1, 64, 2, 16
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, hd))
    out1 = _attend_chunked(q, k, v, causal=True, window=None, q_chunk=16)
    # perturb the LAST key/value; outputs at positions < S-1 must not change
    k2 = k.at[:, -1].add(100.0)
    v2 = v.at[:, -1].add(100.0)
    out2 = _attend_chunked(q, k2, v2, causal=True, window=None, q_chunk=16)
    np.testing.assert_allclose(out1[:, :-1], out2[:, :-1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(out1[:, -1], out2[:, -1])


def test_sliding_window_drops_distant_context():
    B, S, H, hd, W = 1, 64, 1, 8, 8
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, hd))
    out1 = _attend_chunked(q, k, v, causal=True, window=W, q_chunk=16)
    # perturbing a key more than W before the last query changes nothing there
    k2 = k.at[:, 0].add(100.0)
    v2 = v.at[:, 0].add(100.0)
    out2 = _attend_chunked(q, k2, v2, causal=True, window=W, q_chunk=16)
    np.testing.assert_allclose(out1[:, W:], out2[:, W:], rtol=1e-5, atol=1e-5)


@given(st.integers(min_value=1, max_value=64))
@settings(max_examples=20, deadline=None)
def test_rope_preserves_norm(pos):
    """Rotations preserve per-head vector norms."""
    x = jax.random.normal(KEY, (1, 1, 2, 32))
    p = jnp.full((1, 1), pos)
    y = apply_rope(x, p, theta=1e4)
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                               jnp.linalg.norm(y, axis=-1), rtol=1e-4)


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    q = jax.random.normal(KEY, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, 16))

    def dot(i, j):
        qi = apply_rope(q, jnp.full((1, 1), i), 1e4)
        kj = apply_rope(k, jnp.full((1, 1), j), 1e4)
        return float(jnp.sum(qi * kj))

    assert dot(5, 3) == pytest.approx(dot(10, 8), rel=1e-4)
    assert dot(7, 7) == pytest.approx(dot(0, 0), rel=1e-4)


def test_rms_norm_unit_scale():
    x = jax.random.normal(KEY, (4, 64)) * 10
    y = rms_norm(x, jnp.zeros(64))
    rms = jnp.sqrt(jnp.mean(y.astype(jnp.float32) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-2)


# ---------------------------------------------------------------------------
# MoE routing
# ---------------------------------------------------------------------------
def _moe_cfg():
    return get_config("mixtral_8x22b").reduced()


def test_moe_forward_shapes_and_aux():
    cfg = _moe_cfg()
    p = M.init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 64, cfg.d_model),
                          jnp.bfloat16)
    y, aux = M.moe_forward(p, cfg, x)
    assert y.shape == x.shape
    assert jnp.isfinite(aux)
    assert float(aux) >= 0.99            # Switch aux loss lower bound ≈ 1


def test_moe_capacity_drops_are_bounded():
    """With uniform random routing, most tokens must be kept."""
    cfg = _moe_cfg()
    p = M.init_moe(KEY, cfg)
    x = 0.02 * jax.random.normal(jax.random.fold_in(KEY, 2),
                                 (1, 128, cfg.d_model), jnp.bfloat16)
    y, _ = M.moe_forward(p, cfg, x)
    # dropped tokens produce zero routed output; require <30% zeros
    routed_norm = jnp.linalg.norm(
        y.astype(jnp.float32)
        - (jax.nn.silu(x @ p["swg"]) * (x @ p["swi"]) @ p["swo"]).astype(jnp.float32)
        if cfg.num_shared_experts else y.astype(jnp.float32), axis=-1)
    frac_zero = float((routed_norm < 1e-6).mean())
    assert frac_zero < 0.3


def test_moe_decode_matches_forward_single_position():
    cfg = _moe_cfg()
    p = M.init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (4, 1, cfg.d_model),
                          jnp.bfloat16)
    y_dec, _ = M.moe_decode(p, cfg, x)
    # forward path with S=1 groups over batch… compare against groupwise route
    y_fwd, _ = M.moe_forward(p, cfg, x.transpose(1, 0, 2))
    np.testing.assert_allclose(np.asarray(y_dec[:, 0], np.float32),
                               np.asarray(y_fwd[0], np.float32),
                               rtol=5e-2, atol=5e-2)
