import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ISGDConfig, isgd_init
from repro.optim import momentum
from repro.train import checkpoints


def test_roundtrip(tmp_path):
    params = {"a": jnp.arange(6.0).reshape(2, 3),
              "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
              "list": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}
    path = str(tmp_path / "ckpt.npz")
    checkpoints.save(path, params, extra={"step": 7})
    like = jax.tree.map(lambda x: jnp.zeros_like(x), params)
    restored = checkpoints.restore(path, like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
    assert checkpoints.load_extra(path)["step"] == 7


def test_isgd_state_roundtrip(tmp_path):
    """The control queue must survive a restart (resume with limit intact)."""
    params = {"w": jnp.ones((3,))}
    state = isgd_init(momentum(0.9), ISGDConfig(n_batches=4), params)
    from repro.core import control
    for x in (1.0, 2.0, 3.0, 4.0):
        state = state._replace(queue=control.push(state.queue, x))
    path = str(tmp_path / "state.npz")
    checkpoints.save(path, state)
    restored = checkpoints.restore(path, jax.tree.map(jnp.zeros_like, state))
    assert float(control.mean(restored.queue)) == float(control.mean(state.queue))
    assert float(control.control_limit(restored.queue)) == \
        float(control.control_limit(state.queue))
