import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ISGDConfig, isgd_init
from repro.optim import momentum
from repro.train import checkpoints
from repro.train.checkpoints import CheckpointError, Checkpointer


def test_roundtrip(tmp_path):
    params = {"a": jnp.arange(6.0).reshape(2, 3),
              "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
              "list": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}
    path = str(tmp_path / "ckpt.npz")
    checkpoints.save(path, params, extra={"step": 7})
    like = jax.tree.map(lambda x: jnp.zeros_like(x), params)
    restored = checkpoints.restore(path, like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
    assert checkpoints.load_extra(path)["step"] == 7


def test_isgd_state_roundtrip(tmp_path):
    """The control queue must survive a restart (resume with limit intact)."""
    params = {"w": jnp.ones((3,))}
    state = isgd_init(momentum(0.9), ISGDConfig(n_batches=4), params)
    from repro.core import control
    for x in (1.0, 2.0, 3.0, 4.0):
        state = state._replace(queue=control.push(state.queue, x))
    path = str(tmp_path / "state.npz")
    checkpoints.save(path, state)
    restored = checkpoints.restore(path, jax.tree.map(jnp.zeros_like, state))
    assert float(control.mean(restored.queue)) == float(control.mean(state.queue))
    assert float(control.control_limit(restored.queue)) == \
        float(control.control_limit(state.queue))


# ---------------------------------------------------------------------------
# suffix normalization (ISSUE 7 satellite: save appended .npz, restore
# didn't — the pre-fix pair failed with FileNotFoundError)
# ---------------------------------------------------------------------------
def test_suffix_normalized_both_directions(tmp_path):
    tree = {"w": jnp.ones((2,))}
    out = checkpoints.save(str(tmp_path / "bare"), tree)   # no .npz suffix
    assert out.endswith("bare.npz") and os.path.exists(out)
    for spec in ("bare", "bare.npz"):                      # restore either way
        r = checkpoints.restore(str(tmp_path / spec), {"w": jnp.zeros((2,))})
        np.testing.assert_array_equal(np.asarray(r["w"]), 1.0)
    assert checkpoints.save(str(tmp_path / "full.npz"), tree) == \
        str(tmp_path / "full.npz")


def test_save_is_atomic_no_tmp_residue(tmp_path):
    checkpoints.save(str(tmp_path / "a"), {"w": jnp.ones(3)})
    names = os.listdir(tmp_path)
    assert names == ["a.npz"], names                       # no *.tmp-* left


# ---------------------------------------------------------------------------
# restore failure modes: each a clear CheckpointError, not a numpy stack
# ---------------------------------------------------------------------------
def _save_simple(tmp_path, name="c"):
    path = str(tmp_path / name)
    return checkpoints.save(path, {"w": jnp.arange(4.0), "b": jnp.ones(())})


def test_restore_missing_file(tmp_path):
    with pytest.raises(CheckpointError, match="no checkpoint at"):
        checkpoints.restore(str(tmp_path / "nope"), {"w": jnp.zeros(4)})


def test_restore_missing_key(tmp_path):
    path = _save_simple(tmp_path)
    with pytest.raises(CheckpointError, match="no entry for .*extra_key"):
        checkpoints.restore(path, {"w": jnp.zeros(4), "b": jnp.zeros(()),
                                   "extra_key": jnp.zeros(2)})
    # the other direction — file keys absent from the template — is ignored
    r = checkpoints.restore(path, {"w": jnp.zeros(4)})
    assert set(r) == {"w"}


def test_restore_shape_mismatch(tmp_path):
    path = _save_simple(tmp_path)
    with pytest.raises(CheckpointError, match="shape"):
        checkpoints.restore(path, {"w": jnp.zeros((2, 2)), "b": jnp.zeros(())})


def test_restore_dtype_mismatch(tmp_path):
    path = _save_simple(tmp_path)
    with pytest.raises(CheckpointError, match="dtype"):
        checkpoints.restore(path, {"w": jnp.zeros(4, jnp.int32),
                                   "b": jnp.zeros(())})


def test_restore_truncated_file(tmp_path):
    path = _save_simple(tmp_path)
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size // 2)
    with pytest.raises(CheckpointError, match="truncated or corrupt"):
        checkpoints.restore(path, {"w": jnp.zeros(4), "b": jnp.zeros(())})


def test_restore_corrupt_payload_fails_checksum(tmp_path):
    path = _save_simple(tmp_path)
    # flip bytes in the middle of the zip payload without breaking the
    # container structure badly enough for numpy to notice
    with open(path, "r+b") as f:
        f.seek(os.path.getsize(path) // 3)
        f.write(b"\xff\xff\xff\xff")
    with pytest.raises(CheckpointError,
                       match="checksum|truncated or corrupt"):
        checkpoints.restore(path, {"w": jnp.zeros(4), "b": jnp.zeros(())})


def test_bf16_roundtrip_lossless(tmp_path):
    """bf16 leaves are stored as their exact f32 image (npz has no bf16)."""
    vals = jnp.asarray([1.0, 3.140625, -2.5e4, 6.1e-5], jnp.bfloat16)
    path = checkpoints.save(str(tmp_path / "bf16"), {"w": vals})
    r = checkpoints.restore(path, {"w": jnp.zeros(4, jnp.bfloat16)})
    assert r["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(r["w"], np.float32),
                                  np.asarray(vals, np.float32))
    # a bf16 template refuses a file whose leaf was not stored as f32
    checkpoints.save(str(tmp_path / "f64"), {"w": np.zeros(4, np.float64)})
    with pytest.raises(CheckpointError, match="bf16 leaves are stored"):
        checkpoints.restore(str(tmp_path / "f64"),
                            {"w": jnp.zeros(4, jnp.bfloat16)})


# ---------------------------------------------------------------------------
# full-engine pack/unpack + the periodic Checkpointer
# ---------------------------------------------------------------------------
def test_engine_checkpoint_roundtrip(tmp_path):
    params = {"w": jnp.full((3,), 2.0)}
    state = isgd_init(momentum(0.9), ISGDConfig(n_batches=4), params)
    sched = {"table": jnp.arange(4.0)}
    path = checkpoints.save_engine(
        str(tmp_path / "eng"), params=params, state=state, step=17,
        sched_state=sched, server={"version": 17, "pushed": {0: 9, 1: 8}})
    ck = checkpoints.restore_engine(
        path, params_like=jax.tree.map(jnp.zeros_like, params),
        state_like=jax.tree.map(jnp.zeros_like, state),
        sched_like={"table": jnp.zeros(4)})
    assert ck.step == 17
    assert ck.server == {"version": 17, "pushed": {0: 9, 1: 8}}
    np.testing.assert_array_equal(np.asarray(ck.params["w"]), 2.0)
    np.testing.assert_array_equal(np.asarray(ck.sched_state["table"]),
                                  np.arange(4.0))
    assert int(ck.state.iter) == int(state.iter)


def test_restore_engine_rejects_plain_checkpoint(tmp_path):
    path = checkpoints.save(str(tmp_path / "plain"), {"w": jnp.ones(2)})
    with pytest.raises(CheckpointError, match="not a full-engine"):
        checkpoints.restore_engine(path, params_like={"w": jnp.zeros(2)},
                                   state_like={})


def test_checkpointer_cadence_latest_prune(tmp_path):
    params = {"w": jnp.ones(2)}
    state = isgd_init(momentum(0.9), ISGDConfig(n_batches=4), params)
    ck = Checkpointer(str(tmp_path), every=5, keep=2)
    for step in range(1, 23):
        ck.maybe_save(step, params=params, state=state)
    # boundary crossings at 5, 10, 15, 20; keep=2 prunes to the last two
    assert ck.steps() == [15, 20]
    assert ck.latest().endswith("ckpt_00000020.npz")
    # chunked cadence: chunk boundaries cross marks even when every does
    # not divide the chunk size
    ck2 = Checkpointer(str(tmp_path / "chunky"), every=6, keep=0)
    for step in (4, 8, 12, 16):
        ck2.maybe_save(step, params=params, state=state)
    assert ck2.steps() == [8, 12]           # marks 6 and 12, first boundary past
    # mark() anchors a resumed run so the next boundary is measured from it
    ck3 = Checkpointer(str(tmp_path / "resumed"), every=5)
    ck3.mark(16)
    assert ck3.maybe_save(17, params=params, state=state) is None
    assert ck3.maybe_save(21, params=params, state=state) is not None
