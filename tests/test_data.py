"""Synthetic data: learnability + the Fig.1 controlled-batch constructions."""
import numpy as np

from repro.data import (iid_batches, make_classification, single_class_batches)


def test_classification_linearly_separable():
    d = make_classification(0, 400, 16, 1, 5, noise=0.3)
    X = d["images"].reshape(400, -1)
    y = d["labels"]
    # nearest-class-mean classifier should be near-perfect
    means = np.stack([X[y == c].mean(0) for c in range(5)])
    pred = np.argmin(((X[:, None] - means[None]) ** 2).sum(-1), axis=1)
    assert (pred == y).mean() > 0.95


def test_class_skew_biases_frequencies():
    d = make_classification(0, 5000, 8, 1, 10, class_skew=0.5)
    counts = np.bincount(d["labels"], minlength=10)
    assert counts[0] > 2 * counts[9]


def test_single_class_batches_are_pure():
    batches = single_class_batches(0, 32, num_classes=4, image_size=8)
    assert len(batches) == 4
    for c, b in enumerate(batches):
        assert (b["labels"] == c).all()
        assert len(b["labels"]) == 32


def test_iid_batches_have_identical_class_histograms():
    batches = iid_batches(0, 3, per_class=5, num_classes=4, image_size=8)
    assert len(batches) == 3
    ref = np.bincount(batches[0]["labels"], minlength=4)
    for b in batches:
        np.testing.assert_array_equal(np.bincount(b["labels"], minlength=4), ref)
        assert (ref == 5).all()
    # but pixels differ (intrinsic image difference)
    assert not np.allclose(batches[0]["images"], batches[1]["images"])
