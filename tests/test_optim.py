"""Base update rules: exact single-step math + convergence on a quadratic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import momentum, nesterov, sgd


def test_sgd_single_step():
    rule = sgd()
    params = {"w": jnp.array([1.0, 2.0])}
    grads = {"w": jnp.array([0.5, -0.5])}
    st = rule.init(params)
    _, new = rule.apply(st, params, grads, 0.1)
    np.testing.assert_allclose(new["w"], [0.95, 2.05], rtol=1e-6)


def test_momentum_matches_paper_eq19():
    """v' = mu v - lr g ; w' = w + v'."""
    mu, lr = 0.9, 0.1
    rule = momentum(mu)
    params = {"w": jnp.array([1.0])}
    st = rule.init(params)
    g1 = {"w": jnp.array([1.0])}
    st, p1 = rule.apply(st, params, g1, lr)
    assert float(p1["w"][0]) == pytest.approx(1.0 - lr)
    g2 = {"w": jnp.array([1.0])}
    st, p2 = rule.apply(st, p1, g2, lr)
    # v2 = mu*(-lr) - lr; w2 = w1 + v2
    assert float(p2["w"][0]) == pytest.approx((1.0 - lr) + (mu * (-lr) - lr))


def test_weight_decay_shrinks_params():
    rule = sgd(weight_decay=0.1)
    params = {"w": jnp.array([1.0])}
    _, new = rule.apply(rule.init(params), params, {"w": jnp.array([0.0])}, 0.1)
    assert float(new["w"][0]) < 1.0


@pytest.mark.parametrize("make_rule", [sgd, lambda: momentum(0.9),
                                       lambda: nesterov(0.9)])
def test_converges_on_quadratic(make_rule):
    rule = make_rule()
    target = jnp.array([3.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    st = rule.init(params)
    grad = jax.grad(lambda p: 0.5 * jnp.sum((p["w"] - target) ** 2))
    for _ in range(200):
        st, params = rule.apply(st, params, grad(params), 0.05)
    np.testing.assert_allclose(params["w"], target, atol=1e-3)


def test_nesterov_faster_than_momentum_on_illconditioned():
    """Sanity: NAG should not be slower on a convex ill-conditioned quadratic."""
    A = jnp.array([10.0, 1.0])
    loss = lambda p: 0.5 * jnp.sum(A * p["w"] ** 2)   # noqa: E731
    grad = jax.grad(loss)
    errs = {}
    for name, rule in [("momentum", momentum(0.95)), ("nesterov", nesterov(0.95))]:
        params = {"w": jnp.array([1.0, 1.0])}
        st = rule.init(params)
        for _ in range(60):
            st, params = rule.apply(st, params, grad(params), 0.02)
        errs[name] = float(loss(params))
    assert errs["nesterov"] <= errs["momentum"] * 1.5


def test_adagrad_shrinks_effective_lr():
    from repro.optim.base import adagrad
    rule = adagrad()
    params = {"w": jnp.array([1.0])}
    st = rule.init(params)
    g = {"w": jnp.array([1.0])}
    st, p1 = rule.apply(st, params, g, 0.1)
    d1 = float(params["w"][0] - p1["w"][0])
    st, p2 = rule.apply(st, p1, g, 0.1)
    d2 = float(p1["w"][0] - p2["w"][0])
    assert 0 < d2 < d1                      # accumulated sq-grads damp steps


def test_adam_converges_on_quadratic():
    from repro.optim.base import adam
    rule = adam()
    target = jnp.array([3.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    st = rule.init(params)
    grad = jax.grad(lambda p: 0.5 * jnp.sum((p["w"] - target) ** 2))
    for _ in range(400):
        st, params = rule.apply(st, params, grad(params), 0.05)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_isgd_wraps_adaptive_rules():
    """Paper §4.3: inconsistent training composes with any base rule."""
    from repro.core import ISGDConfig, isgd_init, isgd_step
    from repro.optim.base import adam, adagrad
    from repro.train.trainer import make_loss_and_grad

    def loss(params, batch):
        l = 0.5 * jnp.sum((params["w"] - batch["t"]) ** 2)
        return l, l

    lg = make_loss_and_grad(loss)
    for rule in (adam(), adagrad()):
        cfg = ISGDConfig(n_batches=4, k_sigma=1.0, stop=2, zeta=0.05)
        params = {"w": jnp.zeros(2)}
        state = isgd_init(rule, cfg, params)
        for _ in range(4):
            state, params, m = isgd_step(rule, cfg, lg, state, params,
                                         {"t": jnp.zeros(2)}, 0.05)
        state, params, m = isgd_step(rule, cfg, lg, state, params,
                                     {"t": jnp.full((2,), 30.0)}, 0.05)
        assert bool(m["accelerated"])
        assert int(m["sub_iters"]) > 0
