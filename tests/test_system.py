"""End-to-end system behaviour: the paper's qualitative claims reproduced at
CPU scale (small synthetic data + CIFAR-quick CNN).

These mirror EXPERIMENTS.md E4/E5 but at smoke scale, so the claims are
guarded by CI rather than only by the benchmark harness.
"""
import jax
import numpy as np
import pytest

from repro.configs import CIFAR_QUICK
from repro.core import ISGDConfig
from repro.data import FCPRSampler, make_classification
from repro.models import cnn_loss_fn, init_cnn
from repro.optim import momentum
from repro.train import train


@pytest.fixture(scope="module")
def setup():
    data = make_classification(0, 800, 16, 3, 10, noise=0.6, class_skew=0.3,
                               class_spread=2.0)
    sampler = FCPRSampler(data, batch_size=80, seed=1, shuffle_quality=0.5)
    import dataclasses
    cfg = dataclasses.replace(CIFAR_QUICK, image_size=16, channels=3, num_classes=10)
    loss_fn = lambda p, b: cnn_loss_fn(p, cfg, b)    # noqa: E731
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    return sampler, loss_fn, params


def _run(setup, inconsistent, steps=60, k_sigma=1.5):
    sampler, loss_fn, params = setup
    icfg = ISGDConfig(n_batches=sampler.n_batches, k_sigma=k_sigma, stop=3,
                      zeta=0.02)
    return train(params, loss_fn, momentum(0.9), sampler, steps=steps,
                 lr=0.05, inconsistent=inconsistent, isgd_cfg=icfg)


def test_training_descends(setup):
    _, _, log, _ = _run(setup, inconsistent=False)
    assert log.psi_bar[-1] < log.psi_bar[10]


def test_isgd_triggers_and_tracks_limit(setup):
    _, state, log, _ = _run(setup, inconsistent=True)
    assert int(state.accel_count) > 0, "control limit never triggered"
    warm = [i for i in range(len(log.losses)) if np.isfinite(log.limits[i])]
    assert warm, "limit never became finite"
    for i in warm:
        assert log.limits[i] >= log.psi_bar[i]


def test_isgd_average_loss_not_worse(setup):
    """The paper's headline: ISGD converges at least as fast (avg loss)."""
    _, _, log_sgd, _ = _run(setup, inconsistent=False)
    _, _, log_isgd, _ = _run(setup, inconsistent=True)
    a = np.mean(log_isgd.psi_bar[-10:])
    b = np.mean(log_sgd.psi_bar[-10:])
    assert a <= b * 1.05, (a, b)


def test_isgd_subproblem_respects_stop(setup):
    _, state, log, _ = _run(setup, inconsistent=True)
    per_accel = [s for s, a in zip(log.sub_iters, log.accelerated) if a]
    assert per_accel and all(1 <= s <= 3 for s in per_accel), per_accel
