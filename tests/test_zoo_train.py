"""Zoo bodies through the ISGD engines: quick chunked-parity regressions.

The full matrix (ψ̄-lag control leg, sched composition, hybrid engine,
kernel leg, K∈{1,32}) lives in ``repro.train.zoo_parity`` and runs as a
CI step; these are the fast per-commit versions — per-step vs fused
chunked scan must stay bit-exact on every zoo step body, accelerations
included.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ZOO_MODELS, zoo_config
from repro.core import ISGDConfig
from repro.data import DeviceRing, FCPRSampler
from repro.models import build_model
from repro.optim import momentum
from repro.train import make_chunked_train_step, make_train_step

STEPS, K, N_BATCHES, BATCH, SEQ = 8, 4, 2, 4, 32


def _skewed_tokens(vocab, rng):
    """Batch 0 uniform-random (hard), batch 1 repeated 4-grams (easy) —
    skewed enough that the subproblem fires within an epoch or two."""
    hard = rng.randint(0, vocab, size=(BATCH, SEQ))
    easy = np.tile(rng.randint(0, vocab, size=(1, 4)), (BATCH, SEQ // 4))
    return np.concatenate([hard, easy], 0).astype(np.int32)


@pytest.mark.parametrize("name", ZOO_MODELS)
def test_zoo_chunked_parity(name):
    cfg = zoo_config(name, "tiny")
    model = build_model(cfg)
    params0 = model.init(jax.random.PRNGKey(0), max_seq=SEQ)
    toks = _skewed_tokens(cfg.vocab_size, np.random.RandomState(0))
    sampler = FCPRSampler({"tokens": toks}, batch_size=BATCH, seed=1)
    icfg = ISGDConfig(n_batches=N_BATCHES, k_sigma=1.0, stop=2, zeta=0.01)
    rule = momentum(0.9)
    lr_fn = lambda p: jnp.asarray(0.05) + 0.005 * jnp.minimum(p, 1.0)  # noqa: E731

    init_fn, step = make_train_step(model.loss_fn, rule, icfg,
                                    lr_fn=lr_fn, donate=False)
    p = jax.tree.map(jnp.copy, params0)
    s = init_fn(p)
    losses = []
    for j in range(STEPS):
        b = {k: jnp.asarray(v) for k, v in sampler(j).items()}
        s, p, m = step(s, p, b)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses)), losses

    ring = DeviceRing(sampler.epoch_arrays(), BATCH)
    cinit, chunk = make_chunked_train_step(model.loss_fn, rule, icfg,
                                           chunk_steps=K, lr_fn=lr_fn,
                                           donate=False)
    pc = jax.tree.map(jnp.copy, params0)
    sc = cinit(pc)
    closs = []
    for c in range(STEPS // K):
        sc, pc, ms = chunk(sc, pc, ring.arrays, c * K)
        closs.extend(np.asarray(ms["loss"]).tolist())

    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(pc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(losses, np.float32),
                                  np.asarray(closs, np.float32))
    assert int(s.accel_count) == int(sc.accel_count)
